"""Compare a pytest -rf run against scripts/known_failures.txt: exit 1
only on NEW failures (pre-existing jax-version breakage is tolerated).

    python scripts/filter_failures.py /tmp/pytest.out

Shared by scripts/smoke.sh and scripts/ci.sh.
"""
import pathlib
import re
import sys


def main(out_path: str, known_path: str = "scripts/known_failures.txt") -> int:
    out = pathlib.Path(out_path).read_text()
    if not re.search(r"\d+ passed", out):
        print("pytest reported no passing tests — suite never ran?")
        return 1
    failed = set(re.findall(r"^FAILED (\S+)", out, re.M))
    errored = set(re.findall(r"^ERROR (\S+)", out, re.M))
    known = {ln.strip() for ln in pathlib.Path(known_path)
             .read_text().splitlines()
             if ln.strip() and not ln.startswith("#")}
    new = (failed | errored) - known
    fixed = known - failed - errored
    if fixed:
        print(f"note: {len(fixed)} known failure(s) now passing: "
              f"{sorted(fixed)}")
    if new:
        print(f"NEW test failures: {sorted(new)}")
        return 1
    print(f"tier-1 OK ({len(failed)} known pre-existing failure(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main(*sys.argv[1:]))
