#!/usr/bin/env python
"""Docs consistency check (scripts/ci.sh):

1. README.md exists and is non-trivial.
2. Every `DESIGN.md §N` / `DESIGN §N` reference — in README.md, docs/,
   benchmarks/, tests/, and the source tree — resolves to a real `## §N`
   section of DESIGN.md (stale section numbers after a renumbering are
   exactly the rot this catches; PR 3 renumbered §4→§5 once already).
3. Every repo-relative path README.md mentions in backticks exists.
4. `python -m compileall` on examples/ (and scripts/) — docs-adjacent
   code that the test suite does not import must still parse.

Exit 0 = clean; prints every violation otherwise.
"""
from __future__ import annotations

import compileall
import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent

SCAN_GLOBS = ["README.md", "docs/*.md", "benchmarks/*.py", "tests/*.py",
              "src/repro/**/*.py", "examples/*.py"]
REF_RE = re.compile(r"DESIGN(?:\.md)?\s+§(\d+)")
SECTION_RE = re.compile(r"^##\s+§(\d+)\b", re.MULTILINE)
# backticked tokens that look like repo paths (contain / or end in .md/.py/.sh)
PATH_RE = re.compile(r"`([A-Za-z0-9_.\-/]+?\.(?:py|md|sh|json|csv))`")


def main() -> int:
    errors = []

    design = REPO / "DESIGN.md"
    readme = REPO / "README.md"
    if not readme.exists() or len(readme.read_text()) < 500:
        errors.append("README.md missing or trivially short")
    sections = set(SECTION_RE.findall(design.read_text()))

    for pattern in SCAN_GLOBS:
        for path in sorted(REPO.glob(pattern)):
            text = path.read_text(errors="replace")
            for num in REF_RE.findall(text):
                if num not in sections:
                    errors.append(
                        f"{path.relative_to(REPO)}: references DESIGN.md "
                        f"§{num}, but DESIGN.md has only "
                        f"§{{{', '.join(sorted(sections))}}}")

    if readme.exists():
        for ref in PATH_RE.findall(readme.read_text()):
            # artifacts are generated, not committed — existence optional
            if ref.startswith("artifacts/"):
                continue
            if not (REPO / ref).exists():
                errors.append(f"README.md: mentioned path `{ref}` "
                              "does not exist")

    for d in ("examples", "scripts"):
        if not compileall.compile_dir(str(REPO / d), quiet=1, force=True):
            errors.append(f"compileall failed under {d}/")

    if errors:
        for e in errors:
            print(f"DOCS CHECK FAIL: {e}")
        return 1
    print(f"docs check OK ({len(sections)} DESIGN sections, "
          "README paths + §-references resolve, examples compile)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
