#!/usr/bin/env bash
# CI gate — fails only on regressions introduced by the change under test:
#
#   scripts/ci.sh             # from anywhere
#
# 1. tier-1: the full pytest suite filtered against
#    scripts/known_failures.txt (pre-existing jax-version breakage); any
#    NEW failure fails CI.
# 2. adaptive-backend smoke: regret vs. best fixed backend <= 10% on the
#    three core workload scenarios (benchmarks/adaptive_bench.py), which
#    also refreshes artifacts/bench/BENCH_adaptive.json.
# 3. attentiveness smoke: seeded fast path asserting the Fig. 6 structure
#    (AM latency grows with target busy time).
# 4. pipeline smoke: depth-2 overlap >= 1.25x over depth-1 on the P=8
#    insert+find mix (DESIGN.md §7), refreshing
#    artifacts/bench/BENCH_pipeline.json.
# 5. cache-tier smoke: read-heavy zipfian find >= 5x over the
#    fused+coalesced path with >= 0.9 hit rate, zero-exchange steady
#    state, and bit-exact results (DESIGN.md §8), refreshing the cache
#    row of artifacts/bench/BENCH_components.json.
# 6. chaos soak smoke: seeded drops + duplicates + one permanently dead
#    owner at P=8 stay conformant with the fault-free oracle on every
#    arm, and a dead deferred queue raises RemoteTimeout inside the
#    retry deadline (DESIGN.md §10); also refreshes
#    artifacts/bench/BENCH_faults.json via the fault sweep.
# 7. docs check: README exists, DESIGN §-references and README paths
#    resolve, examples/ compiles (scripts/check_docs.py).
# 8. trajectory regression gate: the entry collected from the artifacts
#    the smokes just refreshed must not be > 20% worse than the previous
#    PR's entry on any key (benchmarks/trajectory.py --check, with its
#    CHECK_OPT_OUT list); on pass, the entry is folded into
#    BENCH_trajectory.json.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== dev deps: hypothesis (property tests skip when unavailable) =="
python -c "import hypothesis" 2>/dev/null \
  || pip install --quiet hypothesis \
  || echo "hypothesis unavailable (offline container); property tests stay skipped"

echo "== tier-1 tests (new failures only fail CI) =="
set +e
python -m pytest -q --tb=no -rfE | tee /tmp/ci_pytest.out
set -e
python scripts/filter_failures.py /tmp/ci_pytest.out

echo "== adaptive backend smoke (regret <= 10% on core scenarios) =="
python -m benchmarks.adaptive_bench --smoke

echo "== attentiveness smoke (Fig. 6 structure) =="
python -m benchmarks.attentiveness --smoke

echo "== pipeline overlap smoke (DESIGN.md §7, depth-2 >= 1.25x) =="
python -m benchmarks.pipeline_bench --smoke

echo "== cache-tier smoke (DESIGN.md §8, read-heavy find >= 5x) =="
python -m benchmarks.components --smoke-cache

echo "== chaos soak smoke (DESIGN.md §10, conformance under faults) =="
python -m benchmarks.attentiveness --smoke-chaos
python -m benchmarks.attentiveness --faults

echo "== docs check (README / DESIGN references, examples compile) =="
python scripts/check_docs.py

echo "== trajectory regression gate (no key > 20% worse than last PR) =="
python -m benchmarks.trajectory --check
python -m benchmarks.trajectory

echo "ci OK"
