#!/usr/bin/env bash
# Smoke check: tier-1 tests + the fused-engine acceptance benchmark.
#
#   scripts/smoke.sh            # from anywhere
#
# 1. tier-1: the full pytest suite, compared against the known
#    pre-existing failure set (scripts/known_failures.txt — jax-version
#    breakage present since the seed). Any NEW failure fails the smoke.
# 2. one fused benchmark config: hashtable planned+fused vs seed path at
#    P=8, n=64 (target: >= 1.3x median speedup), which also refreshes
#    artifacts/bench/BENCH_components.json for the perf trajectory.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests (new failures only fail the smoke) =="
set +e
python -m pytest -q --tb=no -rf | tee /tmp/smoke_pytest.out
set -e
python - <<'EOF'
import pathlib, re, sys
out = pathlib.Path("/tmp/smoke_pytest.out").read_text()
failed = set(re.findall(r"^FAILED (\S+)", out, re.M))
known = {l.strip() for l in pathlib.Path("scripts/known_failures.txt")
         .read_text().splitlines() if l.strip() and not l.startswith("#")}
new = failed - known
fixed = known - failed
if fixed:
    print(f"note: {len(fixed)} known failure(s) now passing: {sorted(fixed)}")
if new:
    print(f"NEW test failures: {sorted(new)}")
    sys.exit(1)
print(f"tier-1 OK ({len(failed)} known pre-existing failure(s))")
EOF

echo "== fused benchmark config (P=8, n=64) =="
python -m benchmarks.hashtable_bench --smoke

echo "== component latencies -> artifacts/bench/BENCH_components.json =="
python - <<'EOF'
from benchmarks import components
rows = components.bench_components(P=8, iters=7)
components.emit_json({8: rows})
EOF

echo "smoke OK"
