#!/usr/bin/env bash
# Smoke check: tier-1 tests + the fused-engine acceptance benchmark.
#
#   scripts/smoke.sh                    # from anywhere: the full smoke
#   scripts/smoke.sh --smoke-pipeline   # ONLY the §7 pipeline overlap gate
#   scripts/smoke.sh --smoke-cache      # ONLY the §8 cache-tier gate
#   scripts/smoke.sh --smoke-chaos      # ONLY the §10 chaos soak gate
#
# 1. tier-1: the full pytest suite, compared against the known
#    pre-existing failure set (scripts/known_failures.txt — jax-version
#    breakage present since the seed). Any NEW failure fails the smoke.
# 2. one fused benchmark config: hashtable planned+fused vs seed path at
#    P=8, n=64 (target: >= 1.3x median speedup), which also refreshes
#    artifacts/bench/BENCH_components.json for the perf trajectory.
# 3. attentiveness fast path (seeded, seconds-scale Fig. 6 structure).
# 4. coalescing gate (DESIGN.md §6, after the JSON artifact refresh it
#    amends): hot-owner zipfian insert+find, coalesced vs the
#    planned/fused path — >= 1.3x speedup, engine-logged wire rows
#    matching the coalescing structure's dedup ratio.
#
# 5. pipeline overlap gate (DESIGN.md §7): depth-2 >= 1.25x over depth-1
#    on the P=8 insert+find mix -> artifacts/bench/BENCH_pipeline.json.
#
# 6. cache-tier gate (DESIGN.md §8, after the JSON artifact refresh it
#    amends): read-heavy zipfian find, hot-bucket cache vs the
#    fused+coalesced path — >= 5x median find-batch speedup, hit rate
#    >= 0.9, zero exchanges on a steady-state batch, bit-exact results.
#
# 7. chaos soak gate (DESIGN.md §10): seeded drops + duplicates + one
#    permanently dead owner at P=8 — every arm must stay conformant with
#    the fault-free oracle (exactly-once under retry + dedup), no row
#    may exhaust its retry budget, and a permanently stalled deferred
#    queue must raise RemoteTimeout inside the retry deadline.
#
# scripts/ci.sh is the CI-facing gate (tier-1 + adaptive + attentiveness
# + pipeline + docs check).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--smoke-pipeline" ]]; then
  echo "== pipeline overlap gate only (DESIGN.md §7) =="
  python -m benchmarks.pipeline_bench --smoke
  echo "smoke-pipeline OK"
  exit 0
fi

if [[ "${1:-}" == "--smoke-cache" ]]; then
  echo "== cache-tier gate only (DESIGN.md §8) =="
  python -m benchmarks.components --smoke-cache
  echo "smoke-cache OK"
  exit 0
fi

if [[ "${1:-}" == "--smoke-chaos" ]]; then
  echo "== chaos soak gate only (DESIGN.md §10) =="
  python -m benchmarks.attentiveness --smoke-chaos
  echo "smoke-chaos OK"
  exit 0
fi

echo "== tier-1 tests (new failures only fail the smoke) =="
set +e
python -m pytest -q --tb=no -rfE | tee /tmp/smoke_pytest.out
set -e
python scripts/filter_failures.py /tmp/smoke_pytest.out

echo "== fused benchmark config (P=8, n=64) =="
python -m benchmarks.hashtable_bench --smoke

echo "== attentiveness fast path =="
python -m benchmarks.attentiveness --smoke

echo "== component latencies -> artifacts/bench/BENCH_components.json =="
python - <<'EOF'
from benchmarks import components
rows = components.bench_components(P=8, iters=7)
components.emit_json({8: rows})
EOF

echo "== coalescing gate (hot-owner insert+find, dedup ratio reported) =="
# runs the workload ONCE: gates the speedup + wire-row cross-check, then
# folds its row into the JSON artifact written above
python -m benchmarks.components --smoke-coalesce

echo "== pipeline overlap gate (DESIGN.md §7, depth-2 >= 1.25x) =="
python -m benchmarks.pipeline_bench --smoke

echo "== cache-tier gate (DESIGN.md §8, read-heavy find >= 5x) =="
# runs the workload ONCE: gates speedup + hit rate + zero-exchange
# steady state + bit-exactness, then folds its row into the JSON artifact
python -m benchmarks.components --smoke-cache

echo "== chaos soak gate (DESIGN.md §10, conformance under faults) =="
python -m benchmarks.attentiveness --smoke-chaos

echo "smoke OK"
