"""Shared benchmark utilities: timed jit loops + CSV emission.

Measured numbers on this container are *CPU-emulation* latencies of the
batched phase engine: they validate the cost model's ORDERING claims
(its real claim, paper §IV) and calibrate its parameters; the absolute
Cray-Aries microseconds of Table I are reproduced through the model's
CORI_PHASE1 constants.
"""
from __future__ import annotations

import time
from typing import Callable, Dict

import jax


def time_op(fn: Callable, *args, iters: int = 20, warmup: int = 3,
            ops_per_call: int = 1) -> float:
    """Median wall time per logical op, in microseconds."""
    fn_j = jax.jit(fn) if not hasattr(fn, "lower") else fn
    out = fn_j(*args)
    jax.block_until_ready(out)
    for _ in range(warmup - 1):
        jax.block_until_ready(fn_j(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_j(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    med = times[len(times) // 2]
    return med / ops_per_call * 1e6


class Csv:
    def __init__(self, header):
        self.header = header
        self.rows = []

    def add(self, *row):
        self.rows.append(row)
        print(",".join(str(x) for x in row), flush=True)

    def dump(self, path):
        import pathlib
        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        with open(p, "w") as f:
            f.write(",".join(self.header) + "\n")
            for r in self.rows:
                f.write(",".join(str(x) for x in r) + "\n")
        return str(p)
