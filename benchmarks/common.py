"""Shared benchmark utilities: timed jit loops + CSV emission.

Measured numbers on this container are *CPU-emulation* latencies of the
batched phase engine: they validate the cost model's ORDERING claims
(its real claim, paper §IV) and calibrate its parameters; the absolute
Cray-Aries microseconds of Table I are reproduced through the model's
CORI_PHASE1 constants.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional, Set

import numpy as np

import jax


def time_op(fn: Callable, *args, iters: int = 20, warmup: int = 3,
            ops_per_call: int = 1) -> float:
    """Median wall time per logical op, in microseconds."""
    fn_j = jax.jit(fn) if not hasattr(fn, "lower") else fn
    out = fn_j(*args)
    jax.block_until_ready(out)
    for _ in range(warmup - 1):
        jax.block_until_ready(fn_j(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_j(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    med = times[len(times) // 2]
    return med / ops_per_call * 1e6


def git_label() -> tuple:
    """(short HEAD label, dirty flag) at *this instant* — called by the
    JSON emitters so every BENCH_*.json records the commit it was
    measured under, not whatever HEAD trajectory.py later sees."""
    import pathlib
    import subprocess
    repo = pathlib.Path(__file__).resolve().parent.parent
    label, dirty = "unknown", False
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True, cwd=repo,
                             timeout=10)
        if out.returncode == 0:
            label = out.stdout.strip()
        st = subprocess.run(["git", "status", "--porcelain"],
                            capture_output=True, text=True, cwd=repo,
                            timeout=10)
        dirty = st.returncode == 0 and bool(st.stdout.strip())
    except Exception:
        pass
    return label, dirty


def stamp_label(report: dict) -> dict:
    """Stamp the current git label into a bench report in-place (and
    return it). Emitters call this right before json.dump."""
    label, dirty = git_label()
    report["label"] = label
    report["git_dirty"] = dirty
    if dirty:
        print(f"# WARNING: dirty tree — artifact stamped {label}+dirty")
    return report


def busy_wait(us: float) -> int:
    """Spin for `us` microseconds of real compute — the attentiveness
    emulation's interspersed target work (paper Fig. 6)."""
    t_end = time.perf_counter() + us * 1e-6
    x = 0
    while time.perf_counter() < t_end:
        x += 1
    return x


# ---------------------------------------------------------------------------
# Skew-aware workload generation (DESIGN.md §4): batches of hash-table keys
# whose OWNER distribution follows a named scenario. Owner placement must
# match the engine's (hash_mix(key) % P), so keys are rejection-sampled
# against a numpy mirror of core.hashtable.hash_mix.
# ---------------------------------------------------------------------------
SCENARIOS = ("uniform", "zipfian", "hot")


def np_hash_mix(k: np.ndarray) -> np.ndarray:
    """Numpy mirror of core.hashtable.hash_mix — delegates to the single
    copy of the constants in core.hashtable.hash_mix_np."""
    from repro.core.hashtable import hash_mix_np
    return hash_mix_np(k)


def owner_of(keys: np.ndarray, nranks: int) -> np.ndarray:
    return (np_hash_mix(keys) % np.uint32(nranks)).astype(np.int32)


def gen_owner_targets(P: int, n: int, scenario: str,
                      rng: np.random.Generator) -> np.ndarray:
    """(P, n) target owner per op. uniform: flat over P owners (skew ~1);
    zipfian: p(owner r) ∝ 1/(r+1)^1.5 (moderate skew); hot: every op
    targets owner 0 (skew = P — the Fig. 3 single-variable pathology)."""
    if scenario == "uniform":
        return rng.integers(0, P, (P, n))
    if scenario == "zipfian":
        probs = 1.0 / np.arange(1, P + 1) ** 1.5
        probs /= probs.sum()
        return rng.choice(P, size=(P, n), p=probs)
    if scenario == "hot":
        return np.zeros((P, n), np.int64)
    raise ValueError(f"unknown scenario {scenario!r}; one of {SCENARIOS}")


def keys_for_targets(targets: np.ndarray, nranks: int,
                     rng: np.random.Generator,
                     used: Optional[Set[int]] = None) -> np.ndarray:
    """Distinct int32 keys whose engine owner equals each target.

    Rejection-samples random keys and buckets them by owner_of(). `used`
    (mutated in place when given) excludes keys across batches so a stream
    of batches never repeats a key."""
    if used is None:
        used = set()
    flat = targets.ravel()
    need = np.bincount(flat, minlength=nranks)
    buckets: list = [[] for _ in range(nranks)]
    while any(len(b) < c for b, c in zip(buckets, need)):
        cand = rng.integers(1, (1 << 31) - 2, size=8192, dtype=np.int64)
        owners = owner_of(cand, nranks)
        for k, o in zip(cand.tolist(), owners.tolist()):
            if len(buckets[o]) < need[o] and k not in used:
                used.add(k)
                buckets[o].append(k)
    taken = [0] * nranks
    out = np.empty(flat.shape, np.int32)
    for i, o in enumerate(flat.tolist()):
        out[i] = buckets[o][taken[o]]
        taken[o] += 1
    return out.reshape(targets.shape)


def gen_batch_keys(P: int, n: int, scenario: str, rng: np.random.Generator,
                   used: Optional[Set[int]] = None, *,
                   read_frac: Optional[float] = None):
    """One (P, n) batch of distinct keys following a skew scenario.

    read_frac=None (default) returns just the keys. read_frac=f also
    returns a (P, n) bool mask marking ~f of the rows as READS — the
    mixed read/write stream generator the cache-tier bench (DESIGN.md §8)
    uses to split one batch into a find subset (mask True) and an insert
    subset (mask False)."""
    keys = keys_for_targets(gen_owner_targets(P, n, scenario, rng), P, rng,
                            used)
    if read_frac is None:
        return keys
    reads = rng.random((P, n)) < float(read_frac)
    return keys, reads


def gen_zipf_dup_keys(P: int, n: int, rng: np.random.Generator,
                      alpha: float = 1.1, nkeys: int = 48,
                      hot_owner: Optional[int] = None) -> np.ndarray:
    """One (P, n) batch of keys drawn zipfian(alpha) over a fixed key
    universe — the DUPLICATE-heavy counterpart of gen_batch_keys (which
    skews owners but keeps keys distinct). p(rank-r key) ∝ 1/r^alpha, so a
    batch repeats its hot keys many times: the traffic sender-side
    coalescing (DESIGN.md §6) collapses. hot_owner pins every universe key
    to one owner rank (hot-owner AND duplicate-heavy — the acceptance
    workload for the coalescing benchmark)."""
    if hot_owner is not None:
        targets = np.full((1, nkeys), hot_owner, np.int64)
        universe = keys_for_targets(targets, P, rng).ravel()
    else:
        universe = np.array(sorted(
            {int(k) for k in rng.integers(1, (1 << 31) - 2, 4 * nkeys)}
        )[:nkeys], np.int64)
        rng.shuffle(universe)
    probs = 1.0 / np.arange(1, nkeys + 1, dtype=np.float64) ** alpha
    probs /= probs.sum()
    return rng.choice(universe, size=(P, n), p=probs).astype(np.int32)


class Csv:
    def __init__(self, header):
        self.header = header
        self.rows = []

    def add(self, *row):
        self.rows.append(row)
        print(",".join(str(x) for x in row), flush=True)

    def dump(self, path):
        import pathlib
        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        with open(p, "w") as f:
            f.write(",".join(self.header) + "\n")
            for r in self.rows:
                f.write(",".join(str(x) for x in r) + "\n")
        return str(p)
