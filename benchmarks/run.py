"""Benchmark driver: one section per paper table/figure + the roofline
report. Prints CSV; artifacts land in artifacts/bench/, including the
machine-readable artifacts/bench/BENCH_components.json (per-op µs,
exchange counts, fused-vs-unfused speedups — the cross-PR perf
trajectory; see also scripts/smoke.sh for the quick config)."""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (adaptive_bench, attentiveness, components,
                   hashtable_bench, pipeline_bench, queue_bench, roofline,
                   trajectory)
    sections = [
        ("components (paper Fig. 3 / Table I)", components.main),
        ("queue push (paper Fig. 4)", queue_bench.main),
        ("hash table (paper Fig. 5)", hashtable_bench.main),
        ("attentiveness (paper Fig. 6)", attentiveness.main),
        ("adaptive backend selection (DESIGN.md §4)", adaptive_bench.main),
        ("pipelined batch engine (DESIGN.md §7)", pipeline_bench.main),
        ("roofline (assignment §Roofline)", roofline.main),
        ("perf trajectory (BENCH_trajectory.json)", trajectory.main),
    ]
    failures = 0
    for title, fn in sections:
        print(f"\n=== {title} ===", flush=True)
        try:
            fn()
        except Exception:
            traceback.print_exc()
            failures += 1
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
