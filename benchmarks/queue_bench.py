"""Paper Fig. 4: queue push latencies — AM push, RDMA C_W, RDMA C_RW,
checksum C_RW — measured on the phase engine vs the analytical model's
prediction from calibrated component costs. The validation target is the
model's ORDERING of implementations (paper §IV)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import am as am_mod
from repro.core import costmodel as cm
from repro.core import queue as q_mod
from repro.core.types import Backend, Promise

from . import components
from .common import Csv, time_op


def bench_queue(P: int = 8, n: int = 32, iters: int = 15):
    ops = P * n
    vals = jnp.ones((P, n, 2), jnp.int32)

    def push_cw(data, planned=False):
        q = q_mod.DQueue(win=q_mod.Window(data=data), host=0,
                         capacity=1 << 16, val_words=2)
        q, _ = q_mod.push_rdma(q, vals, promise=Promise.CW, planned=planned)
        return q.win.data

    def push_crw(data, planned=False):
        q = q_mod.DQueue(win=q_mod.Window(data=data), host=0,
                         capacity=1 << 16, val_words=2)
        q, _ = q_mod.push_rdma(q, vals, promise=Promise.CRW,
                               planned=planned)
        return q.win.data

    def push_cw_planned(data):
        return push_cw(data, planned=True)

    def push_crw_planned(data):
        return push_crw(data, planned=True)

    def push_csum(data):
        q = q_mod.DQueue(win=q_mod.Window(data=data), host=0,
                         capacity=1 << 16, val_words=2, checksum=True)
        q, _ = q_mod.push_rdma(q, vals, promise=Promise.CRW)
        return q.win.data

    qa = q_mod.make_queue(P, 0, 1 << 16, 2)
    qc = q_mod.make_queue(P, 0, 1 << 16, 2, checksum=True)
    eng = am_mod.AMEngine(P)
    q_mod.build_am_handlers(q_mod.make_queue(P, 0, 1 << 16, 2), eng)

    def push_am(data):
        q = q_mod.DQueue(win=q_mod.Window(data=data), host=0,
                         capacity=1 << 16, val_words=2)
        q, _ = q_mod.push_rpc(q, eng, vals)
        return q.win.data

    return {
        "am_push": time_op(push_am, qa.win.data, iters=iters,
                           ops_per_call=ops),
        "rdma_push_cw": time_op(push_cw, qa.win.data, iters=iters,
                                ops_per_call=ops),
        "rdma_push_cw_planned": time_op(push_cw_planned, qa.win.data,
                                        iters=iters, ops_per_call=ops),
        "rdma_push_crw": time_op(push_crw, qa.win.data, iters=iters,
                                 ops_per_call=ops),
        "rdma_push_crw_planned": time_op(push_crw_planned, qa.win.data,
                                         iters=iters, ops_per_call=ops),
        "rdma_checksum_push_crw": time_op(push_csum, qc.win.data,
                                          iters=iters, ops_per_call=ops),
    }


PRED = {
    "am_push": (cm.DSOp.Q_PUSH, Promise.CW, Backend.RPC),
    "rdma_push_cw": (cm.DSOp.Q_PUSH, Promise.CW, Backend.RDMA),
    "rdma_push_cw_planned": (cm.DSOp.Q_PUSH, Promise.CW, Backend.RDMA),
    "rdma_push_crw": (cm.DSOp.Q_PUSH, Promise.CRW, Backend.RDMA),
    "rdma_push_crw_planned": (cm.DSOp.Q_PUSH, Promise.CRW, Backend.RDMA),
}


def main(out="artifacts/bench"):
    csv = Csv(["benchmark", "nranks", "impl", "measured_us",
               "predicted_us"])
    comp = components.bench_components(P=8)
    params = components.calibrated_costs(comp)
    ordering_ok = []
    for P in (2, 4, 8):
        rows = bench_queue(P=P)
        preds = {}
        for impl, us in rows.items():
            if impl in PRED:
                op, promise, backend = PRED[impl]
                pred = cm.predict(op, promise, backend, params=params)
            else:
                pred = cm.predict_checksum_push(params=params)
            preds[impl] = pred
            csv.add("queue_push(fig4)", P, impl, f"{us:.3f}", f"{pred:.3f}")
        # ordering validation (the model's real claim) — over the paper's
        # impl set; planned rows share predictions so they would tie
        base_impls = [i for i in rows if not i.endswith("_planned")]
        m_order = sorted(base_impls, key=rows.get)
        p_order = sorted(base_impls, key=preds.get)
        ordering_ok.append(m_order == p_order)
        print(f"# P={P} measured order {m_order}")
        print(f"# P={P} predicted order {p_order}")
        for promise in ("cw", "crw"):
            seed = rows[f"rdma_push_{promise}"]
            planned = rows[f"rdma_push_{promise}_planned"]
            print(f"# P={P} push_{promise} planned speedup: "
                  f"{seed / planned:.2f}x")
    csv.dump(f"{out}/queue.csv")
    print(f"# ordering agreement: {sum(ordering_ok)}/{len(ordering_ok)}")
    return csv


if __name__ == "__main__":
    main()
