"""Adaptive backend selection: regret vs. the best fixed backend
(DESIGN.md §4).

For each workload scenario (uniform / zipfian / single-hot-owner, plus an
`inattentive` bonus where the AM target interposes busy compute), a stream
of hash-table batches runs

  * once per FIXED arm (rdma, rdma_fused, am, am_pt) — all arms jitted and
    pre-compiled, accounted per-batch in µs/op, with the attentiveness
    emulation of benchmarks/attentiveness.py (the `am` arm waits half the
    busy window; `am_pt` pays the pt_overhead contention factor instead);
  * once ADAPTIVELY: the same jitted executors, but core.adaptive's
    AdaptiveEngine picks the arm per batch (decision time is charged to the
    adaptive total). EWMAs are seeded from one calibration pass per arm
    (setup, like the paper's component calibration) and updated online.

Regret = median(adaptive per-batch µs) / median(best-fixed per-batch µs)
- 1 per scenario (medians so one contended-CI spike cannot dominate; the
per-batch decision time is charged to the adaptive side). The artifact
artifacts/bench/BENCH_adaptive.json records per-arm costs, the decision
trace (which arm each batch took), and the regret; `--smoke` gates
regret <= 0.10 on the three core scenarios (ISSUE 3 acceptance).

  python -m benchmarks.adaptive_bench            # full run
  python -m benchmarks.adaptive_bench --smoke    # CI gate
Env overrides: REPRO_ADAPT_BATCHES, REPRO_ADAPT_N.
"""
from __future__ import annotations

import json
import os
import pathlib
import sys
import time
import zlib
from typing import Dict, List

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import adaptive as ad_mod
from repro.core import am as am_mod
from repro.core import costmodel as cm
from repro.core import hashtable as ht_mod
from repro.core import window
from repro.core.types import OpStats, Promise

from .common import Csv, busy_wait, gen_batch_keys, stamp_label

NSLOTS = 4096
VAL_WORDS = 1
MAX_PROBES = 8
REGRET_TARGET = 0.10
CORE_SCENARIOS = ("uniform", "zipfian", "hot")


def scenario_specs(P: int):
    # busy_us on the bonus scenario is sized to dominate a CPU-emulated
    # batch, so the AM arm demonstrably loses and the chooser must flip.
    return [
        {"name": "uniform", "owners": "uniform", "busy_us": 0.0},
        {"name": "zipfian", "owners": "zipfian", "busy_us": 0.0},
        {"name": "hot", "owners": "hot", "busy_us": 0.0},
        {"name": "inattentive", "owners": "uniform", "busy_us": 20000.0,
         "bonus": True},
    ]


def _wrap(data):
    return ht_mod.DHashTable(win=window.Window(data=data), nslots=NSLOTS,
                             val_words=VAL_WORDS)


def build_executors(P: int, eng: am_mod.AMEngine):
    """Jitted per-(op, arm) executors sharing one signature per op.
    insert: (data, keys, vals) -> (data', ok); find: (data, keys) -> found.
    """
    def rdma_insert(fused):
        @jax.jit
        def f(data, keys, vals):
            t, ok, _ = ht_mod.insert_rdma(_wrap(data), keys, vals,
                                          promise=Promise.CRW,
                                          max_probes=MAX_PROBES, fused=fused)
            return t.win.data, ok
        return f

    def rdma_find(fused):
        @jax.jit
        def f(data, keys):
            _, found, _ = ht_mod.find_rdma(_wrap(data), keys,
                                           promise=Promise.CR,
                                           max_probes=MAX_PROBES,
                                           fused=fused)
            return found
        return f

    @jax.jit
    def am_insert(data, keys, vals):
        t, ok, _ = ht_mod.insert_rpc(_wrap(data), eng, keys, vals)
        return t.win.data, ok

    @jax.jit
    def am_find(data, keys):
        found, _ = ht_mod.find_rpc(_wrap(data), eng, keys)
        return found

    return {
        "insert": {"rdma": rdma_insert(False),
                   "rdma_fused": rdma_insert(True),
                   "am": am_insert, "am_pt": am_insert},
        "find": {"rdma": rdma_find(False), "rdma_fused": rdma_find(True),
                 "am": am_find, "am_pt": am_find},
    }


def accounted_us(arm: str, busy_us: float, pt_overhead: float, fn) -> float:
    """Run fn() and return the accounted wall µs for one batch under the
    attentiveness emulation (see module docstring)."""
    t0 = time.perf_counter()
    if arm == "am" and busy_us:
        busy_wait(busy_us / 2.0)
    jax.block_until_ready(fn())
    us = (time.perf_counter() - t0) * 1e6
    if arm == "am_pt":
        us *= pt_overhead
    return us


def gen_stream(P: int, n: int, batches: int, owners: str, seed: int):
    """[(keys, vals, owners_np)] — owners precomputed host-side so the
    adaptive loop's skew statistic costs a bincount, not a device read."""
    from .common import owner_of
    rng = np.random.default_rng(seed)
    used: set = set()
    stream = []
    for _ in range(batches):
        keys = gen_batch_keys(P, n, owners, rng, used)
        kj = jnp.asarray(keys, jnp.int32)
        stream.append((kj, (kj * 7 + 3)[..., None], owner_of(keys, P)))
    return stream


def _batch_us(arm, execs, data0, keys, vals, busy, pt):
    """Accounted µs of one insert+find batch pair on one arm."""
    out = {}
    us = accounted_us(
        arm, busy, pt,
        lambda: out.setdefault(
            "d", execs["insert"][arm](data0, keys, vals)[0]))
    return us, accounted_us(
        arm, busy, pt, lambda: execs["find"][arm](out["d"], keys))


def run_scenario(spec: dict, P: int, n: int, batches: int,
                 execs, eng: am_mod.AMEngine, data0) -> dict:
    # crc32, not hash(): str hash is salted per interpreter, and the gate
    # must replay the same key streams in every CI run
    stream = gen_stream(P, n, batches, spec["owners"],
                        seed=zlib.crc32(spec["name"].encode()))
    busy = spec["busy_us"]
    pt = cm.CORI_PHASE1.pt_overhead
    ops = P * n
    k0, v0, _ = stream[0]

    # warmup: compile every executor (excluded from every total)
    for arm in cm.ARMS:
        d1, _ = execs["insert"][arm](data0, k0, v0)
        jax.block_until_ready(execs["find"][arm](d1, k0))

    # calibration (setup, the analogue of the paper's offline component
    # calibration): median of 3 accounted reps per (op, arm) seeds the
    # chooser's EWMAs; exploration keeps them honest in-stream.
    chooser = ad_mod.AdaptiveEngine(P, am_engine=eng, measure=False,
                                    explore_every=8)
    stats = OpStats(target_busy_us=busy)
    for arm in cm.ARMS:
        reps = [_batch_us(arm, execs, data0, k0, v0, busy, pt)
                for _ in range(3)]
        for op, idx in ((cm.DSOp.HT_INSERT, 0), (cm.DSOp.HT_FIND, 1)):
            dec = ad_mod.Decision(op=op, promise=Promise.CRW, arm=arm,
                                  skew=1.0, scores={}, source="calibration",
                                  batch_ops=ops)
            chooser.observe(dec, float(np.median([r[idx] for r in reps]))
                            / ops)

    # interleaved measurement: every batch runs all fixed arms AND the
    # adaptive choice back to back, so machine drift cancels out of the
    # regret instead of biasing whichever stream ran last; per-batch
    # MEDIANS (not sums) keep a contended-CI spike on one batch from
    # dominating the metric.
    fixed_batches: Dict[str, List[float]] = {a: [] for a in cm.ARMS}
    adaptive_batches: List[float] = []
    decide_us = 0.0
    arm_counts: Dict[str, int] = {}
    skews: List[float] = []
    for keys, vals, owners in stream:
        for arm in cm.ARMS:
            ins, fnd = _batch_us(arm, execs, data0, keys, vals, busy, pt)
            fixed_batches[arm].append(ins + fnd)

        batch_decide_us = 0.0
        t0 = time.perf_counter()
        dec_i = chooser.decide(cm.DSOp.HT_INSERT, Promise.CRW, dst=owners,
                               stats=stats)
        batch_decide_us += (time.perf_counter() - t0) * 1e6
        batch_us = 0.0
        out = {}
        us = accounted_us(dec_i.arm, busy, pt,
                          lambda: out.setdefault(
                              "d", execs["insert"][dec_i.arm](
                                  data0, keys, vals)[0]))
        chooser.observe(dec_i, us / ops)
        batch_us += us
        # telemetry only (outside the charged decide span): steady-state
        # decisions ride the pure-EWMA fast path and skip the host skew
        # statistic, so the Decision record no longer carries it
        skews.append(ad_mod.batch_skew(owners, P))
        arm_counts[dec_i.arm] = arm_counts.get(dec_i.arm, 0) + 1

        t0 = time.perf_counter()
        dec_f = chooser.decide(cm.DSOp.HT_FIND, Promise.CR, dst=owners,
                               stats=stats)
        batch_decide_us += (time.perf_counter() - t0) * 1e6
        us = accounted_us(dec_f.arm, busy, pt,
                          lambda: execs["find"][dec_f.arm](out["d"], keys))
        chooser.observe(dec_f, us / ops)
        batch_us += us
        arm_counts[dec_f.arm] = arm_counts.get(dec_f.arm, 0) + 1
        decide_us += batch_decide_us
        adaptive_batches.append(batch_us + batch_decide_us)

    fixed = {a: float(np.median(b)) / ops for a, b in fixed_batches.items()}
    best_arm = min(fixed, key=fixed.get)
    adaptive_us = float(np.median(adaptive_batches)) / ops
    regret = adaptive_us / fixed[best_arm] - 1.0

    return {
        "busy_us": busy,
        "skew_mean": float(np.mean(skews)),
        "fixed_us_per_op": {a: round(v, 4) for a, v in fixed.items()},
        "best_fixed_arm": best_arm,
        "best_fixed_us_per_op": round(fixed[best_arm], 4),
        "adaptive_us_per_op": round(adaptive_us, 4),
        "decision_overhead_us_per_batch": round(decide_us / batches, 2),
        "regret": round(regret, 4),
        "arm_counts": arm_counts,
        "bonus": bool(spec.get("bonus", False)),
    }


def run(P: int = 8, n: int = 64, batches: int = 24) -> dict:
    batches = int(os.environ.get("REPRO_ADAPT_BATCHES", batches))
    n = int(os.environ.get("REPRO_ADAPT_N", n))
    ht0 = ht_mod.make_hashtable(P, NSLOTS, VAL_WORDS)
    eng = am_mod.AMEngine(P)
    ht_mod.build_am_handlers(ht0, eng, max_probes=MAX_PROBES)
    execs = build_executors(P, eng)
    report = {"benchmark": "adaptive", "unit": "us_per_op", "P": P, "n": n,
              "batches": batches, "regret_target": REGRET_TARGET,
              "scenarios": {}}
    csv = Csv(["benchmark", "scenario", "impl", "us_per_op"])
    for spec in scenario_specs(P):
        res = run_scenario(spec, P, n, batches, execs, eng, ht0.win.data)
        report["scenarios"][spec["name"]] = res
        for arm, us in res["fixed_us_per_op"].items():
            csv.add("adaptive", spec["name"], f"fixed:{arm}", us)
        csv.add("adaptive", spec["name"], "adaptive",
                res["adaptive_us_per_op"])
        print(f"# {spec['name']}: best fixed = {res['best_fixed_arm']} "
              f"({res['best_fixed_us_per_op']} us/op), adaptive = "
              f"{res['adaptive_us_per_op']} us/op, regret = "
              f"{res['regret']:+.1%}, arms = {res['arm_counts']}")
    core_regrets = {s: report["scenarios"][s]["regret"]
                    for s in CORE_SCENARIOS}
    report["max_core_regret"] = max(core_regrets.values())
    return report


def emit(report: dict, out="artifacts/bench", fname="BENCH_adaptive.json"):
    p = pathlib.Path(out) / fname
    p.parent.mkdir(parents=True, exist_ok=True)
    with open(p, "w") as f:
        json.dump(stamp_label(report), f, indent=2)
    print(f"# wrote {p}")
    return str(p)


def main(out="artifacts/bench"):
    report = run()
    emit(report, out=out)
    return report


def smoke() -> bool:
    """CI gate: regret <= REGRET_TARGET on the three core scenarios.

    Wall-clock perf gate, so one retry on failure: transient machine load
    (the usual CI flake) clears on the rerun, while a genuine chooser
    regression fails both."""
    batches = int(os.environ.get("REPRO_ADAPT_BATCHES", 16))
    report = run(batches=batches)
    worst = report["max_core_regret"]
    if worst > REGRET_TARGET:
        print(f"# regret {worst:+.1%} over target — retrying once "
              f"(wall-clock gate)")
        retry = run(batches=batches)
        if retry["max_core_regret"] < worst:
            report, worst = retry, retry["max_core_regret"]
    emit(report)
    ok = worst <= REGRET_TARGET
    print(f"max core-scenario regret {worst:+.1%} "
          f"(target <= {REGRET_TARGET:.0%}): {'OK' if ok else 'FAIL'}")
    return ok


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        sys.exit(0 if smoke() else 1)
    main()
