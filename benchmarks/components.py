"""Paper Fig. 3 / Table I: component-operation latencies.

Measures put, get, FAD (random addresses), FAD-single-variable,
single CAS, persistent CAS, and the AM round trip on the batched phase
engine, at several virtual-rank counts. Emits per-op µs and the
calibrated ComponentCosts used by the queue/hash-table benchmark
predictions (Figs. 4–5 methodology).

Reproduces the paper's two qualitative findings structurally:
  * persistent CAS >> single CAS (multiple rounds under contention);
  * FAD-single-variable > FAD-random: all AMOs funnel into one owner's
    serialized lane (on Aries the cause was NIC-side; here it is the
    owner-lane serialization — same shape, different microarchitecture,
    see DESIGN.md §2).
"""
from __future__ import annotations

import json
import pathlib
import sys

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import am as am_mod
from repro.core import costmodel as cm
from repro.core import hashtable as ht_mod
from repro.core import routing
from repro.core import window
from repro.core.types import AmoKind

from .common import Csv, gen_zipf_dup_keys, time_op

LOCAL = 4096


def _mk(P, n, seed=0):
    rng = np.random.default_rng(seed)
    dst = jnp.asarray(rng.integers(0, P, (P, n)), jnp.int32)
    off = jnp.asarray(rng.integers(0, LOCAL, (P, n)), jnp.int32)
    return dst, off


def bench_components(P: int = 8, n: int = 64, iters: int = 15):
    win = window.make_window(P, LOCAL)
    dst, off = _mk(P, n)
    ops = P * n

    def put(w):
        return window.rdma_put(w, dst, off, jnp.ones((P, n, 1), jnp.int32))

    def get(w):
        return window.rdma_get(w, dst, off, width=1)

    def fad(w):
        return window.rdma_fao(w, dst, off, 1, AmoKind.FAA)

    zero_off = jnp.zeros_like(off)

    def fad_single(w):
        return window.rdma_fao(w, dst, zero_off, 1, AmoKind.FAA)

    def cas(w):
        return window.rdma_cas(w, dst, off, 0, 1)

    def cas_persistent(w):
        # poll until success: swap cur -> cur+1, retry on conflict
        def round_(i, carry):
            w, pending, cur = carry
            old, w = window.rdma_cas(w, dst, zero_off, cur, cur + 1,
                                     valid=pending)
            done = pending & (old == cur)
            return w, pending & ~done, old
        cur = window.rdma_get(w, dst, zero_off, width=1)[..., 0]
        w, pending, _ = jax.lax.fori_loop(
            0, 8, round_, (w, jnp.ones((P, n), bool), cur))
        return w

    # Fused component descriptors (DESIGN.md §2) — the claim+write,
    # claim+write+publish, and lock+gather compound phases the hash table's
    # fused hot path is built from, plus a planned persistent CAS showing
    # route-plan reuse across rounds.
    vals2 = jnp.ones((P, n, 2), jnp.int32)

    def cas_put(w):
        _, w = window.rdma_cas_put(w, dst, off, 0, 1, off + 1, vals2)
        return w

    def cas_put_pub(w):
        _, w = window.rdma_cas_put_publish(w, dst, off, 0, 1, off + 1,
                                           vals2, 3)
        return w

    def fao_get(w):
        _, rec, w = window.rdma_fao_get(w, dst, off, 1, AmoKind.FAA, off, 3)
        return w, rec

    def cas_persistent_planned(w):
        plan = routing.make_plan(dst, cap=n)

        def round_(i, carry):
            w, pending, cur = carry
            old, w = window.rdma_cas(w, dst, zero_off, cur, cur + 1,
                                     valid=pending, plan=plan)
            done = pending & (old == cur)
            return w, pending & ~done, old
        cur = window.rdma_get(w, dst, zero_off, width=1, plan=plan)[..., 0]
        w, pending, _ = jax.lax.fori_loop(
            0, 8, round_, (w, jnp.ones((P, n), bool), cur))
        return w

    # AM round trip: the inner operation is a remote hash-table insert
    # (matches the paper's AM benchmark).
    ht = ht_mod.make_hashtable(P, LOCAL, 1)
    eng = am_mod.AMEngine(P)
    ht_mod.build_am_handlers(ht, eng)
    rng = np.random.default_rng(1)
    keys = jnp.asarray(rng.integers(1, 1 << 20, (P, n)), jnp.int32)

    def am_rt(table):
        ht2 = ht_mod.DHashTable(win=window.Window(data=table),
                                nslots=LOCAL, val_words=1)
        ht3, ok, probes = ht_mod.insert_rpc(ht2, eng, keys, keys[..., None])
        return ht3.win.data

    rows = {}
    rows["put"] = time_op(put, win, iters=iters, ops_per_call=ops)
    rows["get"] = time_op(get, win, iters=iters, ops_per_call=ops)
    rows["fad"] = time_op(fad, win, iters=iters, ops_per_call=ops)
    rows["fad_single"] = time_op(fad_single, win, iters=iters,
                                 ops_per_call=ops)
    rows["cas_single"] = time_op(cas, win, iters=iters, ops_per_call=ops)
    rows["cas_persistent"] = time_op(cas_persistent, win, iters=iters,
                                     ops_per_call=ops)
    rows["cas_persistent_planned"] = time_op(cas_persistent_planned, win,
                                             iters=iters, ops_per_call=ops)
    rows["cas_put"] = time_op(cas_put, win, iters=iters, ops_per_call=ops)
    rows["cas_put_pub"] = time_op(cas_put_pub, win, iters=iters,
                                  ops_per_call=ops)
    rows["fao_get"] = time_op(fao_get, win, iters=iters, ops_per_call=ops)
    rows["am_rt"] = time_op(am_rt, ht.win.data, iters=iters,
                            ops_per_call=ops)
    return rows


def calibrated_costs(rows) -> cm.ComponentCosts:
    return cm.calibrate({
        "W": rows["put"], "R": rows["get"], "A_cas": rows["cas_single"],
        "A_fao": rows["fad"], "am_rt": rows["am_rt"],
        "A_cas_put": rows.get("cas_put"),
        "A_cas_put_pub": rows.get("cas_put_pub"),
        "A_fao_get": rows.get("fao_get"),
        "handler": 0.0,
    })


# ---------------------------------------------------------------------------
# Coalescing acceptance workload (DESIGN.md §6): hot-owner, zipfian
# duplicate-heavy hash-table insert+find — sender-side combining vs the
# PR 3 planned/fused path on the SAME batch.
# ---------------------------------------------------------------------------
def bench_coalescing(P: int = 8, n: int = 64, alpha: float = 1.1,
                     nkeys: int = 48, iters: int = 9,
                     max_probes: int = 48, nslots: int = 4096):
    """Returns a row dict: µs/op for the fused and fused+coalesced engines
    on a hot-owner zipfian insert+find workload, plus the measured wire
    statistics (dedup ratio, request payload rows per probe phase) —
    `payload_rows_*` from the coalescing structure, `engine_rows_coalesced`
    independently from the engine's own phase log, so the smoke gate can
    cross-check that the wire actually shrank."""
    from repro.core import window as win_mod
    from repro.core.types import Promise

    rng = np.random.default_rng(7)
    keys = jnp.asarray(gen_zipf_dup_keys(P, n, rng, alpha=alpha,
                                         nkeys=nkeys, hot_owner=0),
                       jnp.int32)
    vals = ((keys * 31 + 7) & 0x7FFFFF)[..., None]
    base = ht_mod.make_hashtable(P, nslots, 1)
    ops = P * n

    def wrap(data):
        return ht_mod.DHashTable(win=window.Window(data=data), nslots=nslots,
                                 val_words=1)

    def insert_find(coalesce):
        def fn(data):
            ht, ok, _ = ht_mod.insert_rdma(
                wrap(data), keys, vals, promise=Promise.CRW,
                max_probes=max_probes, fused=True, coalesce=coalesce)
            ht, f, v = ht_mod.find_rdma(
                ht, keys, promise=Promise.CR, max_probes=max_probes,
                fused=True, coalesce=coalesce)
            return ht.win.data, f, v
        return fn

    us_fused = time_op(insert_find(False), base.win.data, iters=iters,
                       ops_per_call=ops)
    us_coalesced = time_op(insert_find(True), base.win.data, iters=iters,
                           ops_per_call=ops)

    # Wire statistics. payload_rows_* come from the coalescing structure
    # the insert's CoalescedPlan uses; engine_rows_coalesced is measured
    # INDEPENDENTLY, from the rows-out stats the engine records into its
    # phase log while actually executing the coalesced workload — the two
    # must agree or the engine is not shipping what the structure claims.
    dst, start = ht_mod._place(base, keys)
    payload = jnp.concatenate([keys[..., None], vals], axis=-1)
    co = routing.coalesce(dst, start, match=payload)
    rows_in = int(np.asarray(co.rows_in).sum())
    rows_out = int(np.asarray(co.rows_out).sum())
    win_mod.drain_phase_log()
    with win_mod.decision_scope("bench_coalescing"):
        insert_find(True)(base.win.data)
    infos = [info for _, _, info in win_mod.drain_phase_log() if info]
    engine_rows = infos[0]["rows_out"] if infos else None
    return {
        "ht_hot_insert_find_fused": us_fused,
        "ht_hot_insert_find_coalesced": us_coalesced,
        "coalesce_speedup": us_fused / us_coalesced if us_coalesced else None,
        "dedup_ratio": rows_out / max(rows_in, 1),
        "payload_rows_uncoalesced": rows_in,
        "payload_rows_coalesced": rows_out,
        "engine_rows_coalesced": engine_rows,
        "alpha": alpha, "nkeys": nkeys, "n": n, "P": P,
    }


# Fused-vs-unfused pairing: fused op -> (unfused component sequence) for the
# machine-readable artifact.
FUSED_PAIRS = {
    "cas_put": ["cas_single", "put"],
    "cas_put_pub": ["cas_single", "put", "fad"],
    "fao_get": ["fad", "get"],
    "cas_persistent_planned": ["cas_persistent"],
}


def emit_json(all_rows, out="artifacts/bench",
              fname="BENCH_components.json", coalescing=None):
    """Machine-readable per-op µs + exchange counts + fused-vs-unfused
    ratios (+ the coalescing acceptance row when measured), for cross-PR
    perf trajectories (consumed by benchmarks/trajectory.py and CI)."""
    from repro.core.types import Backend, Promise
    report = {"benchmark": "components", "unit": "us_per_op",
              "rows": {str(P): rows for P, rows in all_rows.items()},
              "fused_vs_unfused": {}, "exchange_counts": {}}
    if coalescing is not None:
        report["coalescing"] = {str(r["P"]): r for r in coalescing}
    for P, rows in all_rows.items():
        pairs = {}
        for fused_op, seq in FUSED_PAIRS.items():
            if fused_op not in rows:
                continue
            unfused_us = sum(rows[c] for c in seq)
            pairs[fused_op] = {
                "fused_us": rows[fused_op],
                "unfused_us": unfused_us,
                "unfused_sequence": seq,
                "speedup": unfused_us / rows[fused_op]
                if rows[fused_op] else None,
            }
        report["fused_vs_unfused"][str(P)] = pairs
    for fused in (False, True):
        key = "fused" if fused else "unfused"
        report["exchange_counts"][key] = {
            "ht_find_crw_per_probe": cm.exchange_count(
                cm.DSOp.HT_FIND, Promise.CRW, Backend.RDMA, fused=fused),
            "ht_insert_crw_per_probe": cm.exchange_count(
                cm.DSOp.HT_INSERT, Promise.CRW, Backend.RDMA, fused=fused,
                probes=1),
            "network_phases_ht_insert_crw": cm.network_phases(
                cm.DSOp.HT_INSERT, Promise.CRW, Backend.RDMA, fused=fused),
            "network_phases_ht_find_crw": cm.network_phases(
                cm.DSOp.HT_FIND, Promise.CRW, Backend.RDMA, fused=fused),
        }
    p = pathlib.Path(out) / fname
    p.parent.mkdir(parents=True, exist_ok=True)
    with open(p, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {p}")
    return str(p)


def main(out="artifacts/bench", ranks=(2, 4, 8, 16)):
    csv = Csv(["benchmark", "nranks", "op", "us_per_op"])
    all_rows = {}
    for P in ranks:
        rows = bench_components(P=P)
        all_rows[P] = rows
        for op, us in rows.items():
            csv.add("components(fig3)", P, op, f"{us:.3f}")
    csv.dump(f"{out}/components.csv")
    co_row = bench_coalescing(P=8)
    csv.add("coalescing", 8, "ht_hot_insert_find_fused",
            f"{co_row['ht_hot_insert_find_fused']:.3f}")
    csv.add("coalescing", 8, "ht_hot_insert_find_coalesced",
            f"{co_row['ht_hot_insert_find_coalesced']:.3f}")
    emit_json(all_rows, out=out, coalescing=[co_row])
    # structural findings (paper Fig. 3)
    r = all_rows[8] if 8 in all_rows else all_rows[max(all_rows)]
    print(f"# persistent_cas/single_cas = "
          f"{r['cas_persistent']/r['cas_single']:.2f} (expect > 1)")
    print(f"# fad_single/fad = {r['fad_single']/r['fad']:.2f} "
          f"(expect >= 1; Aries pathology analogue)")
    print(f"# fused cas_put vs cas+put: "
          f"{(r['cas_single']+r['put'])/r['cas_put']:.2f}x")
    print(f"# fused fao_get vs fad+get: "
          f"{(r['fad']+r['get'])/r['fao_get']:.2f}x")
    print(f"# coalescing hot-owner insert+find: "
          f"{co_row['coalesce_speedup']:.2f}x at dedup ratio "
          f"{co_row['dedup_ratio']:.2f}")
    return all_rows


def smoke_coalesce(P: int = 8, n: int = 64, iters: int = 9,
                   threshold: float = 1.3,
                   update_artifact: bool = True) -> bool:
    """Coalescing smoke gate (scripts/smoke.sh): hot-owner zipfian
    insert+find must speed up >= `threshold` over the PR 3 planned/fused
    path, the wire rows must actually shrink (dedup < 1), and the rows
    the ENGINE logged while executing must equal the rows the coalescing
    structure predicted. Folds its row into the existing
    BENCH_components.json (written by the earlier smoke step) so the
    workload runs once per smoke invocation."""
    row = bench_coalescing(P=P, n=n, iters=iters)
    print(f"fused      {row['ht_hot_insert_find_fused']:8.3f} us/op")
    print(f"coalesced  {row['ht_hot_insert_find_coalesced']:8.3f} us/op")
    print(f"speedup    {row['coalesce_speedup']:.2f}x "
          f"(target >= {threshold}x)")
    print(f"dedup ratio {row['dedup_ratio']:.3f}  payload rows "
          f"{row['payload_rows_uncoalesced']} -> "
          f"{row['payload_rows_coalesced']} "
          f"(engine logged {row['engine_rows_coalesced']})")
    rows_ok = (row["payload_rows_coalesced"]
               < row["payload_rows_uncoalesced"]
               and row["engine_rows_coalesced"]
               == row["payload_rows_coalesced"])
    if not rows_ok:
        print("FAIL: engine-logged wire rows do not shrink as the "
              "coalescing structure predicts")
    if update_artifact:
        p = pathlib.Path("artifacts/bench") / "BENCH_components.json"
        if p.exists():
            with open(p) as f:
                report = json.load(f)
            report.setdefault("coalescing", {})[str(P)] = row
            with open(p, "w") as f:
                json.dump(report, f, indent=2)
            print(f"# updated coalescing row in {p}")
    return bool(row["coalesce_speedup"] >= threshold) and rows_ok


if __name__ == "__main__":
    if "--smoke-coalesce" in sys.argv:
        sys.exit(0 if smoke_coalesce() else 1)
    main()
