"""Paper Fig. 3 / Table I: component-operation latencies.

Measures put, get, FAD (random addresses), FAD-single-variable,
single CAS, persistent CAS, and the AM round trip on the batched phase
engine, at several virtual-rank counts. Emits per-op µs and the
calibrated ComponentCosts used by the queue/hash-table benchmark
predictions (Figs. 4–5 methodology).

Reproduces the paper's two qualitative findings structurally:
  * persistent CAS >> single CAS (multiple rounds under contention);
  * FAD-single-variable > FAD-random: all AMOs funnel into one owner's
    serialized lane (on Aries the cause was NIC-side; here it is the
    owner-lane serialization — same shape, different microarchitecture,
    see DESIGN.md §2).
"""
from __future__ import annotations

import json
import pathlib
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import am as am_mod
from repro.core import costmodel as cm
from repro.core import hashtable as ht_mod
from repro.core import routing
from repro.core import window
from repro.core.types import AmoKind

from .common import (Csv, gen_batch_keys, gen_zipf_dup_keys, stamp_label,
                     time_op)

LOCAL = 4096


def _mk(P, n, seed=0):
    rng = np.random.default_rng(seed)
    dst = jnp.asarray(rng.integers(0, P, (P, n)), jnp.int32)
    off = jnp.asarray(rng.integers(0, LOCAL, (P, n)), jnp.int32)
    return dst, off


def bench_components(P: int = 8, n: int = 64, iters: int = 15):
    win = window.make_window(P, LOCAL)
    dst, off = _mk(P, n)
    ops = P * n

    def put(w):
        return window.rdma_put(w, dst, off, jnp.ones((P, n, 1), jnp.int32))

    def get(w):
        return window.rdma_get(w, dst, off, width=1)

    def fad(w):
        return window.rdma_fao(w, dst, off, 1, AmoKind.FAA)

    zero_off = jnp.zeros_like(off)

    def fad_single(w):
        return window.rdma_fao(w, dst, zero_off, 1, AmoKind.FAA)

    def cas(w):
        return window.rdma_cas(w, dst, off, 0, 1)

    def cas_persistent(w):
        # poll until success: swap cur -> cur+1, retry on conflict
        def round_(i, carry):
            w, pending, cur = carry
            old, w = window.rdma_cas(w, dst, zero_off, cur, cur + 1,
                                     valid=pending)
            done = pending & (old == cur)
            return w, pending & ~done, old
        cur = window.rdma_get(w, dst, zero_off, width=1)[..., 0]
        w, pending, _ = jax.lax.fori_loop(
            0, 8, round_, (w, jnp.ones((P, n), bool), cur))
        return w

    # Fused component descriptors (DESIGN.md §2) — the claim+write,
    # claim+write+publish, and lock+gather compound phases the hash table's
    # fused hot path is built from, plus a planned persistent CAS showing
    # route-plan reuse across rounds.
    vals2 = jnp.ones((P, n, 2), jnp.int32)

    def cas_put(w):
        _, w = window.rdma_cas_put(w, dst, off, 0, 1, off + 1, vals2)
        return w

    def cas_put_pub(w):
        _, w = window.rdma_cas_put_publish(w, dst, off, 0, 1, off + 1,
                                           vals2, 3)
        return w

    def fao_get(w):
        _, rec, w = window.rdma_fao_get(w, dst, off, 1, AmoKind.FAA, off, 3)
        return w, rec

    def cas_persistent_planned(w):
        plan = routing.make_plan(dst, cap=n)

        def round_(i, carry):
            w, pending, cur = carry
            old, w = window.rdma_cas(w, dst, zero_off, cur, cur + 1,
                                     valid=pending, plan=plan)
            done = pending & (old == cur)
            return w, pending & ~done, old
        cur = window.rdma_get(w, dst, zero_off, width=1, plan=plan)[..., 0]
        w, pending, _ = jax.lax.fori_loop(
            0, 8, round_, (w, jnp.ones((P, n), bool), cur))
        return w

    # AM round trip: the inner operation is a remote hash-table insert
    # (matches the paper's AM benchmark).
    ht = ht_mod.make_hashtable(P, LOCAL, 1)
    eng = am_mod.AMEngine(P)
    ht_mod.build_am_handlers(ht, eng)
    rng = np.random.default_rng(1)
    keys = jnp.asarray(rng.integers(1, 1 << 20, (P, n)), jnp.int32)

    def am_rt(table):
        ht2 = ht_mod.DHashTable(win=window.Window(data=table),
                                nslots=LOCAL, val_words=1)
        ht3, ok, probes = ht_mod.insert_rpc(ht2, eng, keys, keys[..., None])
        return ht3.win.data

    rows = {}
    rows["put"] = time_op(put, win, iters=iters, ops_per_call=ops)
    rows["get"] = time_op(get, win, iters=iters, ops_per_call=ops)
    rows["fad"] = time_op(fad, win, iters=iters, ops_per_call=ops)
    rows["fad_single"] = time_op(fad_single, win, iters=iters,
                                 ops_per_call=ops)
    rows["cas_single"] = time_op(cas, win, iters=iters, ops_per_call=ops)
    rows["cas_persistent"] = time_op(cas_persistent, win, iters=iters,
                                     ops_per_call=ops)
    rows["cas_persistent_planned"] = time_op(cas_persistent_planned, win,
                                             iters=iters, ops_per_call=ops)
    rows["cas_put"] = time_op(cas_put, win, iters=iters, ops_per_call=ops)
    rows["cas_put_pub"] = time_op(cas_put_pub, win, iters=iters,
                                  ops_per_call=ops)
    rows["fao_get"] = time_op(fao_get, win, iters=iters, ops_per_call=ops)
    rows["am_rt"] = time_op(am_rt, ht.win.data, iters=iters,
                            ops_per_call=ops)
    return rows


def calibrated_costs(rows) -> cm.ComponentCosts:
    return cm.calibrate({
        "W": rows["put"], "R": rows["get"], "A_cas": rows["cas_single"],
        "A_fao": rows["fad"], "am_rt": rows["am_rt"],
        "A_cas_put": rows.get("cas_put"),
        "A_cas_put_pub": rows.get("cas_put_pub"),
        "A_fao_get": rows.get("fao_get"),
        "handler": 0.0,
    })


# ---------------------------------------------------------------------------
# Coalescing acceptance workload (DESIGN.md §6): hot-owner, zipfian
# duplicate-heavy hash-table insert+find — sender-side combining vs the
# PR 3 planned/fused path on the SAME batch.
# ---------------------------------------------------------------------------
def bench_coalescing(P: int = 8, n: int = 64, alpha: float = 1.1,
                     nkeys: int = 48, iters: int = 9,
                     max_probes: int = 48, nslots: int = 4096):
    """Returns a row dict: µs/op for the fused and fused+coalesced engines
    on a hot-owner zipfian insert+find workload, plus the measured wire
    statistics (dedup ratio, request payload rows per probe phase) —
    `payload_rows_*` from the coalescing structure, `engine_rows_coalesced`
    independently from the engine's own phase log, so the smoke gate can
    cross-check that the wire actually shrank."""
    from repro.core import window as win_mod
    from repro.core.types import Promise

    rng = np.random.default_rng(7)
    keys = jnp.asarray(gen_zipf_dup_keys(P, n, rng, alpha=alpha,
                                         nkeys=nkeys, hot_owner=0),
                       jnp.int32)
    vals = ((keys * 31 + 7) & 0x7FFFFF)[..., None]
    base = ht_mod.make_hashtable(P, nslots, 1)
    ops = P * n

    def wrap(data):
        return ht_mod.DHashTable(win=window.Window(data=data), nslots=nslots,
                                 val_words=1)

    def insert_find(coalesce):
        def fn(data):
            ht, ok, _ = ht_mod.insert_rdma(
                wrap(data), keys, vals, promise=Promise.CRW,
                max_probes=max_probes, fused=True, coalesce=coalesce)
            ht, f, v = ht_mod.find_rdma(
                ht, keys, promise=Promise.CR, max_probes=max_probes,
                fused=True, coalesce=coalesce)
            return ht.win.data, f, v
        return fn

    us_fused = time_op(insert_find(False), base.win.data, iters=iters,
                       ops_per_call=ops)
    us_coalesced = time_op(insert_find(True), base.win.data, iters=iters,
                           ops_per_call=ops)

    # Wire statistics. payload_rows_* come from the coalescing structure
    # the insert's CoalescedPlan uses; engine_rows_coalesced is measured
    # INDEPENDENTLY, from the rows-out stats the engine records into its
    # phase log while actually executing the coalesced workload — the two
    # must agree or the engine is not shipping what the structure claims.
    dst, start = ht_mod._place(base, keys)
    payload = jnp.concatenate([keys[..., None], vals], axis=-1)
    co = routing.coalesce(dst, start, match=payload)
    rows_in = int(np.asarray(co.rows_in).sum())
    rows_out = int(np.asarray(co.rows_out).sum())
    win_mod.drain_phase_log()
    with win_mod.decision_scope("bench_coalescing"):
        insert_find(True)(base.win.data)
    infos = [info for _, _, info in win_mod.drain_phase_log() if info]
    engine_rows = infos[0]["rows_out"] if infos else None
    return {
        "ht_hot_insert_find_fused": us_fused,
        "ht_hot_insert_find_coalesced": us_coalesced,
        "coalesce_speedup": us_fused / us_coalesced if us_coalesced else None,
        "dedup_ratio": rows_out / max(rows_in, 1),
        "payload_rows_uncoalesced": rows_in,
        "payload_rows_coalesced": rows_out,
        "engine_rows_coalesced": engine_rows,
        "alpha": alpha, "nkeys": nkeys, "n": n, "P": P,
    }


# ---------------------------------------------------------------------------
# Cache-tier acceptance workload (DESIGN.md §8): read-heavy zipfian
# duplicate-heavy hash-table finds — hot-bucket cache vs the PR 4
# fused+coalesced path on the SAME stream. Both arms run EAGERLY: the
# cache's lookup/fill book-keeping is host-side by design (it no-ops
# under tracing), so a jitted timing loop would silently bench the
# uncached path twice.
# ---------------------------------------------------------------------------
def bench_cache(P: int = 8, n: int = 64, batches: int = 8,
                alpha: float = 1.1, nkeys: int = 48, iters: int = 7,
                max_probes: int = 8, nslots: int = 4096,
                n_mix: int = 160, read_frac: float = 0.9,
                capacity: int = 4096, seed: int = 11):
    """Returns a row dict: MEDIAN per-find-batch µs/op for the
    fused+coalesced find (no cache) and the cached engine on a read-heavy
    zipfian stream (`batches` find batches + one fresh-key insert batch
    per rep, ~{batches}:1 read:write), plus the measured hit rate and the
    exchange counts both arms issue on a steady-state all-hit batch.

    The median-of-batches statistic is the honest one for a cache tier:
    the batch right after an insert refills its invalidated entries at
    miss cost (and a single miss row pays the FULL probe-phase loop —
    exchanges are per phase, not per row), while every steady batch
    short-circuits to zero exchanges. The median prices the steady state;
    the refill spikes stay in the stream and in the hit-rate figure."""
    from repro.core import adaptive as ad_mod
    from repro.core import cache as cache_mod
    from repro.core import routing as rt_mod
    from repro.core.types import Promise

    rng = np.random.default_rng(seed)
    used: set = set()
    # One zipf draw over ONE shared key universe, sliced into the stream's
    # find batches (per-call universes would never re-hit the cache).
    big = gen_zipf_dup_keys(P, n * batches, rng, alpha=alpha, nkeys=nkeys)
    finds = [jnp.asarray(big[:, i * n:(i + 1) * n], jnp.int32)
             for i in range(batches)]
    used.update(int(k) for k in np.unique(big))

    def val_of(keys):
        return ((keys * 31 + 7) & 0x7FFFFF)[..., None]

    def seed_table():
        ht = ht_mod.make_hashtable(P, nslots, 1)
        ht, ok, _ = ht_mod.insert_rdma(
            ht, jnp.asarray(big, jnp.int32), val_of(jnp.asarray(big)),
            promise=Promise.CRW, max_probes=max_probes, fused=True,
            coalesce=True)
        jax.block_until_ready(ht.win.data)
        return ht

    # Fresh-key insert batches: the WRITE fraction of a
    # gen_batch_keys(read_frac=...) mixed batch (insert with valid=~reads
    # — exercising valid-masked invalidation), pre-generated so both arms
    # replay the IDENTICAL stream (cache invalidation included).
    writes = []
    for _ in range(iters + 2):
        wk, reads = gen_batch_keys(P, n_mix, "uniform", rng, used,
                                   read_frac=read_frac)
        writes.append((jnp.asarray(wk, jnp.int32),
                       jnp.asarray(~reads)))

    def run_stream(state, find_fn, insert_fn, reps):
        """Replay the read-heavy stream; returns per-find-batch seconds."""
        per_batch = []
        for r in range(reps):
            for keys in finds:
                t0 = time.perf_counter()
                state["ht"], f, v = find_fn(state["ht"], keys)
                jax.block_until_ready(v)
                per_batch.append(time.perf_counter() - t0)
            wkeys, wmask = writes[r % len(writes)]
            state["ht"], ok, _ = insert_fn(state["ht"], wkeys, wmask)
            jax.block_until_ready(state["ht"].win.data)
        return per_batch

    def median_us(per_batch):
        per_batch = sorted(per_batch)
        return per_batch[len(per_batch) // 2] / (P * n) * 1e6

    # Baseline arm: PR 4 fused+coalesced, eager, no cache.
    def find_base(ht, keys):
        return ht_mod.find_rdma(ht, keys, promise=Promise.CR,
                                max_probes=max_probes, fused=True,
                                coalesce=True)

    def insert_base(ht, wkeys, wmask):
        return ht_mod.insert_rdma(ht, wkeys, val_of(wkeys),
                                  promise=Promise.CRW, valid=wmask,
                                  max_probes=max_probes, fused=True,
                                  coalesce=True)

    state_b = {"ht": seed_table()}
    run_stream(state_b, find_base, insert_base, 1)  # warmup
    us_base = median_us(run_stream(state_b, find_base, insert_base, iters))

    # Cached arm: same stream through the adaptive engine with a
    # hot-bucket cache attached; one warm rep fills the cache.
    eng = ad_mod.AdaptiveEngine(P, arms=("rdma_fused",))
    eng.attach_cache(cache_mod.BucketCache(P, nslots, 1, capacity=capacity,
                                           max_probes=max_probes))

    def find_cached(ht, keys):
        return eng.ht_find(ht, keys, promise=Promise.CR,
                           max_probes=max_probes)

    def insert_cached(ht, wkeys, wmask):
        return eng.ht_insert(ht, wkeys, val_of(wkeys), promise=Promise.CRW,
                             valid=wmask, max_probes=max_probes)

    state_c = {"ht": seed_table()}
    run_stream(state_c, find_cached, insert_cached, 1)  # warm: fill cache
    us_cached = median_us(
        run_stream(state_c, find_cached, insert_cached, iters))
    c = eng.cache.counters
    looked = (c["hits"] + c["misses"]) or 1
    hit_rate = c["hits"] / looked

    # Wire cross-check: exchanges a steady-state find batch issues per
    # arm. The same batch runs twice and the SECOND run is counted, so
    # the cached arm has refilled anything the stream's last insert
    # invalidated — steady state is all-hit and must issue ZERO
    # exchanges, while the baseline pays its full probe loop every time.
    def count_exchanges(find_fn, state):
        roles = []

        def hook(x, role):
            if role.endswith("_pre"):
                roles.append(role[:-4])
            return x
        state["ht"], _, _ = find_fn(state["ht"], finds[0])  # refill pass
        with rt_mod.sharding_hook(hook):
            state["ht"], _, v = find_fn(state["ht"], finds[0])
            jax.block_until_ready(v)
        return len(roles)

    exch_base = count_exchanges(find_base, state_b)
    exch_cached = count_exchanges(find_cached, state_c)

    # Bit-exactness on a final all-universe find.
    probe = jnp.asarray(big[:, :n], jnp.int32)
    _, f_b, v_b = ht_mod.find_rdma(state_b["ht"], probe, promise=Promise.CR,
                                   max_probes=max_probes, fused=True)
    _, f_c, v_c = eng.ht_find(state_c["ht"], probe, promise=Promise.CR,
                              max_probes=max_probes)
    exact = (bool(np.array_equal(np.asarray(f_b), np.asarray(f_c)))
             and bool(np.array_equal(np.asarray(v_b), np.asarray(v_c))))
    return {
        "ht_read_heavy_find_coalesced": us_base,
        "ht_read_heavy_find_cached": us_cached,
        "cache_speedup": us_base / us_cached if us_cached else None,
        "hit_rate": hit_rate,
        "exchanges_coalesced": exch_base,
        "exchanges_cached": exch_cached,
        "bit_exact": exact,
        "alpha": alpha, "nkeys": nkeys, "n": n, "batches": batches,
        "n_mix": n_mix, "read_frac": read_frac, "P": P,
    }


# Fused-vs-unfused pairing: fused op -> (unfused component sequence) for the
# machine-readable artifact.
FUSED_PAIRS = {
    "cas_put": ["cas_single", "put"],
    "cas_put_pub": ["cas_single", "put", "fad"],
    "fao_get": ["fad", "get"],
    "cas_persistent_planned": ["cas_persistent"],
}


def emit_json(all_rows, out="artifacts/bench",
              fname="BENCH_components.json", coalescing=None, cache=None):
    """Machine-readable per-op µs + exchange counts + fused-vs-unfused
    ratios (+ the coalescing / cache acceptance rows when measured), for
    cross-PR perf trajectories (consumed by benchmarks/trajectory.py and
    CI)."""
    from repro.core.types import Backend, Promise
    report = {"benchmark": "components", "unit": "us_per_op",
              "rows": {str(P): rows for P, rows in all_rows.items()},
              "fused_vs_unfused": {}, "exchange_counts": {}}
    if coalescing is not None:
        report["coalescing"] = {str(r["P"]): r for r in coalescing}
    if cache is not None:
        report["cache"] = {str(r["P"]): r for r in cache}
    for P, rows in all_rows.items():
        pairs = {}
        for fused_op, seq in FUSED_PAIRS.items():
            if fused_op not in rows:
                continue
            unfused_us = sum(rows[c] for c in seq)
            pairs[fused_op] = {
                "fused_us": rows[fused_op],
                "unfused_us": unfused_us,
                "unfused_sequence": seq,
                "speedup": unfused_us / rows[fused_op]
                if rows[fused_op] else None,
            }
        report["fused_vs_unfused"][str(P)] = pairs
    for fused in (False, True):
        key = "fused" if fused else "unfused"
        report["exchange_counts"][key] = {
            "ht_find_crw_per_probe": cm.exchange_count(
                cm.DSOp.HT_FIND, Promise.CRW, Backend.RDMA, fused=fused),
            "ht_insert_crw_per_probe": cm.exchange_count(
                cm.DSOp.HT_INSERT, Promise.CRW, Backend.RDMA, fused=fused,
                probes=1),
            "network_phases_ht_insert_crw": cm.network_phases(
                cm.DSOp.HT_INSERT, Promise.CRW, Backend.RDMA, fused=fused),
            "network_phases_ht_find_crw": cm.network_phases(
                cm.DSOp.HT_FIND, Promise.CRW, Backend.RDMA, fused=fused),
        }
    stamp_label(report)
    p = pathlib.Path(out) / fname
    p.parent.mkdir(parents=True, exist_ok=True)
    with open(p, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {p}")
    return str(p)


def main(out="artifacts/bench", ranks=(2, 4, 8, 16)):
    csv = Csv(["benchmark", "nranks", "op", "us_per_op"])
    all_rows = {}
    for P in ranks:
        rows = bench_components(P=P)
        all_rows[P] = rows
        for op, us in rows.items():
            csv.add("components(fig3)", P, op, f"{us:.3f}")
    csv.dump(f"{out}/components.csv")
    co_row = bench_coalescing(P=8)
    csv.add("coalescing", 8, "ht_hot_insert_find_fused",
            f"{co_row['ht_hot_insert_find_fused']:.3f}")
    csv.add("coalescing", 8, "ht_hot_insert_find_coalesced",
            f"{co_row['ht_hot_insert_find_coalesced']:.3f}")
    ca_row = bench_cache(P=8)
    csv.add("cache", 8, "ht_read_heavy_find_coalesced",
            f"{ca_row['ht_read_heavy_find_coalesced']:.3f}")
    csv.add("cache", 8, "ht_read_heavy_find_cached",
            f"{ca_row['ht_read_heavy_find_cached']:.3f}")
    emit_json(all_rows, out=out, coalescing=[co_row], cache=[ca_row])
    # structural findings (paper Fig. 3)
    r = all_rows[8] if 8 in all_rows else all_rows[max(all_rows)]
    print(f"# persistent_cas/single_cas = "
          f"{r['cas_persistent']/r['cas_single']:.2f} (expect > 1)")
    print(f"# fad_single/fad = {r['fad_single']/r['fad']:.2f} "
          f"(expect >= 1; Aries pathology analogue)")
    print(f"# fused cas_put vs cas+put: "
          f"{(r['cas_single']+r['put'])/r['cas_put']:.2f}x")
    print(f"# fused fao_get vs fad+get: "
          f"{(r['fad']+r['get'])/r['fao_get']:.2f}x")
    print(f"# coalescing hot-owner insert+find: "
          f"{co_row['coalesce_speedup']:.2f}x at dedup ratio "
          f"{co_row['dedup_ratio']:.2f}")
    print(f"# cache read-heavy zipfian find: "
          f"{ca_row['cache_speedup']:.2f}x at hit rate "
          f"{ca_row['hit_rate']:.3f}")
    return all_rows


def smoke_coalesce(P: int = 8, n: int = 64, iters: int = 9,
                   threshold: float = 1.3,
                   update_artifact: bool = True) -> bool:
    """Coalescing smoke gate (scripts/smoke.sh): hot-owner zipfian
    insert+find must speed up >= `threshold` over the PR 3 planned/fused
    path, the wire rows must actually shrink (dedup < 1), and the rows
    the ENGINE logged while executing must equal the rows the coalescing
    structure predicted. Folds its row into the existing
    BENCH_components.json (written by the earlier smoke step) so the
    workload runs once per smoke invocation."""
    row = bench_coalescing(P=P, n=n, iters=iters)
    print(f"fused      {row['ht_hot_insert_find_fused']:8.3f} us/op")
    print(f"coalesced  {row['ht_hot_insert_find_coalesced']:8.3f} us/op")
    print(f"speedup    {row['coalesce_speedup']:.2f}x "
          f"(target >= {threshold}x)")
    print(f"dedup ratio {row['dedup_ratio']:.3f}  payload rows "
          f"{row['payload_rows_uncoalesced']} -> "
          f"{row['payload_rows_coalesced']} "
          f"(engine logged {row['engine_rows_coalesced']})")
    rows_ok = (row["payload_rows_coalesced"]
               < row["payload_rows_uncoalesced"]
               and row["engine_rows_coalesced"]
               == row["payload_rows_coalesced"])
    if not rows_ok:
        print("FAIL: engine-logged wire rows do not shrink as the "
              "coalescing structure predicts")
    if update_artifact:
        p = pathlib.Path("artifacts/bench") / "BENCH_components.json"
        if p.exists():
            with open(p) as f:
                report = json.load(f)
            report.setdefault("coalescing", {})[str(P)] = row
            with open(p, "w") as f:
                json.dump(report, f, indent=2)
            print(f"# updated coalescing row in {p}")
    return bool(row["coalesce_speedup"] >= threshold) and rows_ok


def smoke_cache(P: int = 8, iters: int = 7, threshold: float = 5.0,
                update_artifact: bool = True) -> bool:
    """Cache-tier smoke gate (scripts/smoke.sh): the read-heavy zipfian
    find stream must speed up >= `threshold` over the PR 4
    fused+coalesced path, the observed hit rate must be high enough for
    the §8 discount to be the explanation (>= 0.9), the cached arm must
    issue strictly fewer exchanges (wire shrink, not wall-clock luck),
    and the two arms' final find results must be bit-identical. Folds its
    row into the existing BENCH_components.json (written by the earlier
    smoke step) so the workload runs once per smoke invocation."""
    row = bench_cache(P=P, iters=iters)
    print(f"coalesced  {row['ht_read_heavy_find_coalesced']:8.3f} us/op")
    print(f"cached     {row['ht_read_heavy_find_cached']:8.3f} us/op")
    print(f"speedup    {row['cache_speedup']:.2f}x "
          f"(target >= {threshold}x)")
    print(f"hit rate   {row['hit_rate']:.3f}  exchanges "
          f"{row['exchanges_coalesced']} -> {row['exchanges_cached']}  "
          f"bit_exact {row['bit_exact']}")
    wire_ok = row["exchanges_cached"] < row["exchanges_coalesced"]
    if not wire_ok:
        print("FAIL: cached arm did not issue fewer exchanges than the "
              "coalesced baseline")
    if not row["bit_exact"]:
        print("FAIL: cached and uncached finds disagree")
    if row["hit_rate"] < 0.9:
        print("FAIL: hit rate below 0.9 on the read-heavy stream")
    if update_artifact:
        p = pathlib.Path("artifacts/bench") / "BENCH_components.json"
        if p.exists():
            with open(p) as f:
                report = json.load(f)
            report.setdefault("cache", {})[str(P)] = row
            with open(p, "w") as f:
                json.dump(report, f, indent=2)
            print(f"# updated cache row in {p}")
    return (bool(row["cache_speedup"] >= threshold) and wire_ok
            and row["bit_exact"] and row["hit_rate"] >= 0.9)


if __name__ == "__main__":
    if "--smoke-coalesce" in sys.argv:
        sys.exit(0 if smoke_coalesce() else 1)
    if "--smoke-cache" in sys.argv:
        sys.exit(0 if smoke_cache() else 1)
    main()
