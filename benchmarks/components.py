"""Paper Fig. 3 / Table I: component-operation latencies.

Measures put, get, FAD (random addresses), FAD-single-variable,
single CAS, persistent CAS, and the AM round trip on the batched phase
engine, at several virtual-rank counts. Emits per-op µs and the
calibrated ComponentCosts used by the queue/hash-table benchmark
predictions (Figs. 4–5 methodology).

Reproduces the paper's two qualitative findings structurally:
  * persistent CAS >> single CAS (multiple rounds under contention);
  * FAD-single-variable > FAD-random: all AMOs funnel into one owner's
    serialized lane (on Aries the cause was NIC-side; here it is the
    owner-lane serialization — same shape, different microarchitecture,
    see DESIGN.md §2).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import am as am_mod
from repro.core import costmodel as cm
from repro.core import hashtable as ht_mod
from repro.core import window
from repro.core.types import AmoKind

from .common import Csv, time_op

LOCAL = 4096


def _mk(P, n, seed=0):
    rng = np.random.default_rng(seed)
    dst = jnp.asarray(rng.integers(0, P, (P, n)), jnp.int32)
    off = jnp.asarray(rng.integers(0, LOCAL, (P, n)), jnp.int32)
    return dst, off


def bench_components(P: int = 8, n: int = 64, iters: int = 15):
    win = window.make_window(P, LOCAL)
    dst, off = _mk(P, n)
    ops = P * n

    def put(w):
        return window.rdma_put(w, dst, off, jnp.ones((P, n, 1), jnp.int32))

    def get(w):
        return window.rdma_get(w, dst, off, width=1)

    def fad(w):
        return window.rdma_fao(w, dst, off, 1, AmoKind.FAA)

    zero_off = jnp.zeros_like(off)

    def fad_single(w):
        return window.rdma_fao(w, dst, zero_off, 1, AmoKind.FAA)

    def cas(w):
        return window.rdma_cas(w, dst, off, 0, 1)

    def cas_persistent(w):
        # poll until success: swap cur -> cur+1, retry on conflict
        def round_(i, carry):
            w, pending, cur = carry
            old, w = window.rdma_cas(w, dst, zero_off, cur, cur + 1,
                                     valid=pending)
            done = pending & (old == cur)
            return w, pending & ~done, old
        cur = window.rdma_get(w, dst, zero_off, width=1)[..., 0]
        w, pending, _ = jax.lax.fori_loop(
            0, 8, round_, (w, jnp.ones((P, n), bool), cur))
        return w

    # AM round trip: the inner operation is a remote hash-table insert
    # (matches the paper's AM benchmark).
    ht = ht_mod.make_hashtable(P, LOCAL, 1)
    eng = am_mod.AMEngine(P)
    ht_mod.build_am_handlers(ht, eng)
    rng = np.random.default_rng(1)
    keys = jnp.asarray(rng.integers(1, 1 << 20, (P, n)), jnp.int32)

    def am_rt(table):
        ht2 = ht_mod.DHashTable(win=window.Window(data=table),
                                nslots=LOCAL, val_words=1)
        ht3, ok = ht_mod.insert_rpc(ht2, eng, keys, keys[..., None])
        return ht3.win.data

    rows = {}
    rows["put"] = time_op(put, win, iters=iters, ops_per_call=ops)
    rows["get"] = time_op(get, win, iters=iters, ops_per_call=ops)
    rows["fad"] = time_op(fad, win, iters=iters, ops_per_call=ops)
    rows["fad_single"] = time_op(fad_single, win, iters=iters,
                                 ops_per_call=ops)
    rows["cas_single"] = time_op(cas, win, iters=iters, ops_per_call=ops)
    rows["cas_persistent"] = time_op(cas_persistent, win, iters=iters,
                                     ops_per_call=ops)
    rows["am_rt"] = time_op(am_rt, ht.win.data, iters=iters,
                            ops_per_call=ops)
    return rows


def calibrated_costs(rows) -> cm.ComponentCosts:
    return cm.calibrate({
        "W": rows["put"], "R": rows["get"], "A_cas": rows["cas_single"],
        "A_fao": rows["fad"], "am_rt": rows["am_rt"],
        "handler": 0.0,
    })


def main(out="artifacts/bench"):
    csv = Csv(["benchmark", "nranks", "op", "us_per_op"])
    all_rows = {}
    for P in (2, 4, 8, 16):
        rows = bench_components(P=P)
        all_rows[P] = rows
        for op, us in rows.items():
            csv.add("components(fig3)", P, op, f"{us:.3f}")
    csv.dump(f"{out}/components.csv")
    # structural findings (paper Fig. 3)
    r = all_rows[8]
    print(f"# persistent_cas/single_cas = "
          f"{r['cas_persistent']/r['cas_single']:.2f} (expect > 1)")
    print(f"# fad_single/fad = {r['fad_single']/r['fad']:.2f} "
          f"(expect >= 1; Aries pathology analogue)")
    return all_rows


if __name__ == "__main__":
    main()
