"""Scale as a first-class axis: weak + strong scaling sweeps over the
simulated shard count P (DESIGN.md §9, ISSUE 8 tentpole).

The paper measures at fixed machine scale; this repo's emulation carries P
as the leading array dimension, so "more ranks" is a reshape, not a
cluster — which makes shard count a sweepable benchmark axis on one host.
For P = 8 -> 64 -> 256 this bench measures every data-structure op
(hash-table insert/find, queue push/pop) on every arm

    rdma        seed per-component one-sided engine
    rdma_fused  planned + fused-descriptor one-sided engine
    am          aggregated active messages (vmapped handler dispatch)
    cached      hot-bucket cache attached (CR find only, DESIGN.md §8):
                host lookup + one jitted miss-subset find step

under two scalings:

  * **weak**: n ops per rank held constant — total work grows with P.
    Per-op time should stay flat for a scalable engine; growth isolates
    the per-rank occupancy-exchange and reply fan-out costs that the
    cost model's `exch_per_rank` / `fanout_per_rank` terms price
    (costmodel._p_scaled).
  * **strong**: TOTAL ops held constant — n = total / P shrinks per rank.
    Smaller per-rank batches amortize the fixed exchange overheads worse,
    the classic strong-scaling wall.

The measured weak-scaling growth of the one-sided and AM find arms is
least-squares-fitted back into the two cost-model slopes and emitted as
`fitted_params` — the per-P recalibration that keeps `predict_arm`
ordering arms correctly at P=64/256 (pinned by tests/
test_costmodel_ordering.py).

  python -m benchmarks.scaling_bench             # full run -> JSON artifact
  python -m benchmarks.scaling_bench --smoke     # reduced config

Env overrides: REPRO_SCALE_N (weak n/rank), REPRO_SCALE_TOTAL (strong
total ops), REPRO_SCALE_ITERS, REPRO_SCALE_PS (comma-separated).
Artifact: artifacts/bench/BENCH_scaling.json (folded into
BENCH_trajectory.json's "scaling" section by benchmarks/trajectory.py).
"""
from __future__ import annotations

import json
import os
import pathlib
import sys
import time
from typing import Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import am as am_mod
from repro.core import cache as cache_mod
from repro.core import hashtable as ht_mod
from repro.core import queue as q_mod
from repro.core import window

from .common import Csv, gen_batch_keys, stamp_label

PS = (8, 64, 256)
NSLOTS = 4096          # per rank — weak scaling of table memory
VAL_WORDS = 1
MAX_PROBES = 8
HT_ARMS = ("rdma", "rdma_fused", "am", "cached")
Q_ARMS = ("rdma", "rdma_fused", "am")


def _cfg(smoke: bool):
    n_weak = int(os.environ.get("REPRO_SCALE_N", 16 if smoke else 32))
    total = int(os.environ.get("REPRO_SCALE_TOTAL", 1024 if smoke else 2048))
    iters = int(os.environ.get("REPRO_SCALE_ITERS", 2 if smoke else 3))
    ps = tuple(int(x) for x in os.environ.get(
        "REPRO_SCALE_PS", ",".join(map(str, PS))).split(","))
    return n_weak, total, iters, ps


def _median(xs: List[float]) -> float:
    return float(np.median(xs))


def _timed_us_per_op(fn, outputs_of, ops: int, iters: int) -> float:
    """Median wall µs/op of `fn()` over `iters` reps (first rep warms the
    jit cache and is discarded)."""
    jax.block_until_ready(outputs_of(fn()))
    reps = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(outputs_of(fn()))
        reps.append((time.perf_counter() - t0) * 1e6 / ops)
    return _median(reps)


# ---------------------------------------------------------------------------
# hash table
# ---------------------------------------------------------------------------
def _ht_executors(P: int, eng: am_mod.AMEngine):
    def _wrap(data):
        return ht_mod.DHashTable(win=window.Window(data=data),
                                 nslots=NSLOTS, val_words=VAL_WORDS)

    def mk_insert(fused):
        @jax.jit
        def f(data, keys, vals):
            t, ok, _ = ht_mod.insert_rdma(_wrap(data), keys, vals,
                                          max_probes=MAX_PROBES, fused=fused)
            return t.win.data, ok
        return f

    def mk_find(fused):
        @jax.jit
        def f(data, keys):
            _, found, _ = ht_mod.find_rdma(_wrap(data), keys,
                                           max_probes=MAX_PROBES,
                                           fused=fused)
            return found
        return f

    @jax.jit
    def am_insert(data, keys, vals):
        t, ok, _ = ht_mod.insert_rpc(_wrap(data), eng, keys, vals)
        return t.win.data, ok

    @jax.jit
    def am_find(data, keys):
        found, _ = ht_mod.find_rpc(_wrap(data), eng, keys)
        return found

    @jax.jit
    def miss_find(data, keys, miss):
        _, found, vals, slot = ht_mod.find_rdma(_wrap(data), keys,
                                                valid=miss, fused=True,
                                                max_probes=MAX_PROBES,
                                                return_slot=True)
        return found, vals, slot

    return {
        "insert": {"rdma": mk_insert(False), "rdma_fused": mk_insert(True),
                   "am": am_insert},
        "find": {"rdma": mk_find(False), "rdma_fused": mk_find(True),
                 "am": am_find},
        "miss_find": miss_find,
        "wrap": _wrap,
    }


def bench_ht(P: int, n: int, iters: int, seed: int) -> Dict[str, Dict]:
    """{op: {arm: us_per_op}} for one (P, n) hash-table config."""
    rng = np.random.default_rng(seed)
    keys = jnp.asarray(gen_batch_keys(P, n, "uniform", rng))
    vals = jnp.asarray(
        rng.integers(1, 1 << 20, (P, n, VAL_WORDS)).astype(np.int32))
    ht0 = ht_mod.make_hashtable(P, NSLOTS, VAL_WORDS)
    eng = am_mod.AMEngine(P)
    ht_mod.build_am_handlers(ht0, eng, max_probes=MAX_PROBES)
    ex = _ht_executors(P, eng)
    data0 = ht0.win.data
    ops = P * n
    out: Dict[str, Dict] = {"insert": {}, "find": {}}

    filled = {}
    for arm in ("rdma", "rdma_fused", "am"):
        ins = ex["insert"][arm]
        out["insert"][arm] = _timed_us_per_op(
            lambda ins=ins: ins(data0, keys, vals), lambda r: r[1],
            ops, iters)
        filled[arm] = ins(data0, keys, vals)[0]
        fnd = ex["find"][arm]
        d1 = filled[arm]
        out["find"][arm] = _timed_us_per_op(
            lambda fnd=fnd, d1=d1: fnd(d1, keys), lambda r: r, ops, iters)

    # cached arm: warm the hot-bucket cache with the find keys, then
    # measure the §8 steady state — host lookup + one jitted miss-subset
    # step (all-hit: the step's probe loop exits immediately, the cost is
    # the lookup itself, which scales with P on the host)
    cache = cache_mod.BucketCache(P, NSLOTS, VAL_WORDS, capacity=4096,
                                  max_probes=MAX_PROBES)
    ht1 = ex["wrap"](filled["rdma_fused"])
    _, f_w, _ = ht_mod.find_rdma(ht1, keys, fused=True,
                                 max_probes=MAX_PROBES, cache=cache)
    jax.block_until_ready(f_w)
    cache.drain_fills(force=True)
    keys_np = np.asarray(keys)
    miss_step = ex["miss_find"]
    d1 = filled["rdma_fused"]

    def cached_find():
        look = cache.lookup(keys_np)
        miss = jnp.asarray(look.miss)
        return miss_step(d1, keys, miss)

    out["find"]["cached"] = _timed_us_per_op(
        cached_find, lambda r: r, ops, iters)
    return out


# ---------------------------------------------------------------------------
# queue
# ---------------------------------------------------------------------------
def bench_q(P: int, n: int, iters: int, seed: int) -> Dict[str, Dict]:
    """{op: {arm: us_per_op}} for one (P, n) hosted-queue config."""
    rng = np.random.default_rng(seed)
    vals = jnp.asarray(
        rng.integers(1, 1 << 20, (P, n, VAL_WORDS)).astype(np.int32))
    cap = max(1024, 2 * P * n)
    q0 = q_mod.make_queue(P, 0, cap, VAL_WORDS)
    eng = am_mod.AMEngine(P)
    q_mod.build_am_handlers(q0, eng)
    ops = P * n

    def mk_push(planned):
        @jax.jit
        def f(data, vals):
            q2, ok = q_mod.push_rdma(_wrapq(data), vals, planned=planned)
            return q2.win.data, ok
        return f

    def mk_pop(planned):
        @jax.jit
        def f(data):
            q2, got, v = q_mod.pop_rdma(_wrapq(data), n, planned=planned)
            return q2.win.data, got, v
        return f

    def _wrapq(data):
        return q_mod.DQueue(win=window.Window(data=data), host=q0.host,
                            capacity=q0.capacity, val_words=q0.val_words,
                            checksum=q0.checksum)

    @jax.jit
    def am_push(data, vals):
        q2, ok = q_mod.push_rpc(_wrapq(data), eng, vals)
        return q2.win.data, ok

    @jax.jit
    def am_pop(data):
        q2, got, v = q_mod.pop_rpc(_wrapq(data), eng, n)
        return q2.win.data, got, v

    pushes = {"rdma": mk_push(False), "rdma_fused": mk_push(True),
              "am": am_push}
    pops = {"rdma": mk_pop(False), "rdma_fused": mk_pop(True),
            "am": am_pop}
    data0 = q0.win.data
    out: Dict[str, Dict] = {"push": {}, "pop": {}}
    for arm in Q_ARMS:
        push, pop = pushes[arm], pops[arm]
        out["push"][arm] = _timed_us_per_op(
            lambda push=push: push(data0, vals), lambda r: r[1], ops, iters)
        d1 = push(data0, vals)[0]
        out["pop"][arm] = _timed_us_per_op(
            lambda pop=pop, d1=d1: pop(d1), lambda r: r[1:], ops, iters)
    return out


# ---------------------------------------------------------------------------
# slope fitting (cost-model P-dependence recalibration)
# ---------------------------------------------------------------------------
def _fit_slope(per_p: Dict[int, float], base_p: int) -> Optional[float]:
    """Least-squares slope s of t(P)/t(P0) = (1 + s(P-1)) / (1 + s(P0-1))
    over the measured per-P medians — closed form per point, averaged.
    None when the base is missing; clamped at 0 (a measured SPEEDUP at
    higher P is noise, not negative wire cost)."""
    t0 = per_p.get(base_p)
    if not t0:
        return None
    ss = []
    for p, t in per_p.items():
        if p == base_p or not t:
            continue
        r = t / t0
        denom = (p - 1) - r * (base_p - 1)
        if denom > 0:
            ss.append(max(0.0, (r - 1.0) / denom))
    return float(np.mean(ss)) if ss else None


def fit_params(weak: Dict[str, Dict]) -> Dict[str, Optional[float]]:
    """Fit the two _p_scaled slopes from the weak-scaling find medians:
    the one-sided fused find is a pure wire-term op (R per probe) ->
    exch_per_rank; the AM find's growth is reply fan-out -> fanout_per_rank.
    """
    rdma_pp = {int(p): d["ht"]["find"].get("rdma_fused")
               for p, d in weak.items()}
    am_pp = {int(p): d["ht"]["find"].get("am") for p, d in weak.items()}
    base = min(rdma_pp)
    return {"exch_per_rank": _fit_slope(rdma_pp, base),
            "fanout_per_rank": _fit_slope(am_pp, base),
            "base_p": base}


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------
def run(smoke: bool) -> Dict:
    n_weak, total, iters, ps = _cfg(smoke)
    weak: Dict[str, Dict] = {}
    strong: Dict[str, Dict] = {}
    for P in ps:
        n_strong = max(1, total // P)
        weak[str(P)] = {
            "n_per_rank": n_weak,
            "ht": bench_ht(P, n_weak, iters, seed=P),
            "q": bench_q(P, n_weak, iters, seed=P + 1),
        }
        strong[str(P)] = {
            "n_per_rank": n_strong,
            "ht": bench_ht(P, n_strong, iters, seed=P + 2),
            "q": bench_q(P, n_strong, iters, seed=P + 3),
        }
        print(f"# P={P}: weak n/rank={n_weak}, strong n/rank={n_strong}")
    fitted = fit_params(weak)
    result = {
        "schema": "bench-scaling-v1",
        "ps": list(ps), "nslots_per_rank": NSLOTS,
        "weak_n_per_rank": n_weak, "strong_total_ops": total,
        "iters": iters,
        "weak": weak, "strong": strong,
        "fitted_params": fitted,
    }
    csv = Csv(["scaling", "P", "struct", "op", "arm", "us_per_op"])
    for label, section in (("weak", weak), ("strong", strong)):
        for p, d in section.items():
            for struct in ("ht", "q"):
                for op, arms in d[struct].items():
                    for arm, us in arms.items():
                        if us is not None:
                            csv.add(label, p, struct, op, arm,
                                    round(us, 4))
    print(f"# fitted exch_per_rank={fitted['exch_per_rank']} "
          f"fanout_per_rank={fitted['fanout_per_rank']}")
    emit_json(result)
    return result


def emit_json(result: Dict, out_dir: str = "artifacts/bench") -> str:
    p = pathlib.Path(out_dir) / "BENCH_scaling.json"
    p.parent.mkdir(parents=True, exist_ok=True)
    with open(p, "w") as f:
        json.dump(stamp_label(result), f, indent=2)
    print(f"# wrote {p}")
    return str(p)


def main():
    run(smoke="--smoke" in sys.argv)


if __name__ == "__main__":
    main()
