"""Cumulative perf-trajectory file: per-PR medians of the benchmark
artifacts, appended to BENCH_trajectory.json at the repo root so future
PRs have a baseline to regress against.

One entry per commit label (re-running under the same HEAD replaces the
entry instead of appending). Medians are deliberately coarse — one number
per (suite, config) — because the trajectory is for spotting cross-PR
cliffs, not for microbenchmark archaeology; the full per-op numbers stay
in artifacts/bench/BENCH_*.json.

    python -m benchmarks.trajectory          # collect + update from the
                                             # existing artifacts
"""
from __future__ import annotations

import json
import pathlib
import subprocess
from typing import Optional

REPO = pathlib.Path(__file__).resolve().parent.parent
TRAJECTORY = REPO / "BENCH_trajectory.json"
BENCH_DIR = REPO / "artifacts" / "bench"


def _git_label() -> str:
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True, cwd=REPO,
                             timeout=10)
        if out.returncode == 0:
            return out.stdout.strip()
    except Exception:
        pass
    return "unknown"


def _median(vals) -> Optional[float]:
    vals = sorted(v for v in vals if isinstance(v, (int, float)))
    if not vals:
        return None
    mid = len(vals) // 2
    return (vals[mid] if len(vals) % 2
            else (vals[mid - 1] + vals[mid]) / 2.0)


def _load(fname: str) -> Optional[dict]:
    p = BENCH_DIR / fname
    if not p.exists():
        return None
    with open(p) as f:
        return json.load(f)


def _csv_medians(fname: str, impl_col: str, val_col: str) -> dict:
    """Per-impl median over a benchmark CSV's numeric value column."""
    p = BENCH_DIR / fname
    if not p.exists():
        return {}
    rows = p.read_text().strip().splitlines()
    header = rows[0].split(",")
    try:
        i_impl, i_val = header.index(impl_col), header.index(val_col)
    except ValueError:
        return {}
    by_impl: dict = {}
    for line in rows[1:]:
        cells = line.split(",")
        try:
            by_impl.setdefault(cells[i_impl], []).append(float(cells[i_val]))
        except (ValueError, IndexError):
            continue
    return {impl: _median(v) for impl, v in by_impl.items()}


def collect() -> dict:
    """One trajectory entry from whatever artifacts currently exist."""
    entry: dict = {"label": _git_label()}

    comp = _load("BENCH_components.json")
    if comp:
        rows = comp.get("rows", {})
        p8 = rows.get("8") or (rows[max(rows, key=int)] if rows else {})
        entry["components"] = {
            "median_us_per_op_P8": _median(p8.values()),
            "ops": {k: v for k, v in sorted(p8.items())},
        }
        co = comp.get("coalescing", {}).get("8")
        if co:
            entry["components"]["coalescing"] = {
                "speedup": co.get("coalesce_speedup"),
                "dedup_ratio": co.get("dedup_ratio"),
                "us_coalesced": co.get("ht_hot_insert_find_coalesced"),
            }
        ca = comp.get("cache", {}).get("8")
        if ca:
            entry["components"]["cache"] = {
                "speedup": ca.get("cache_speedup"),
                "hit_rate": ca.get("hit_rate"),
                "us_cached": ca.get("ht_read_heavy_find_cached"),
            }

    pl = _load("BENCH_pipeline.json")
    if pl:
        entry["pipeline"] = {
            "speedup_depth2": pl.get("speedup_depth2"),
            "per_batch_us": pl.get("per_batch_us"),
            "busy_us": pl.get("busy_us"),
        }

    ad = _load("BENCH_adaptive.json")
    if ad:
        scen = ad.get("scenarios", ad)
        regrets = [s.get("regret") for s in scen.values()
                   if isinstance(s, dict) and "regret" in s]
        entry["adaptive"] = {
            "median_regret": _median(regrets),
            "scenarios": sorted(k for k in scen if isinstance(
                scen[k], dict)),
        }

    ht = _csv_medians("hashtable.csv", "impl", "measured_us")
    if ht:
        entry["hashtable"] = {"median_us_per_impl": ht,
                              "median_us": _median(ht.values())}
    qb = _csv_medians("queue.csv", "impl", "measured_us")
    if qb:
        entry["queue"] = {"median_us_per_impl": qb,
                          "median_us": _median(qb.values())}
    return entry


def update(path: pathlib.Path = TRAJECTORY) -> dict:
    """Insert/replace this HEAD's entry in the trajectory file."""
    entry = collect()
    history = []
    if path.exists():
        try:
            with open(path) as f:
                history = json.load(f).get("entries", [])
        except (json.JSONDecodeError, AttributeError):
            history = []
    history = [e for e in history if e.get("label") != entry["label"]]
    history.append(entry)
    doc = {"schema": "bench-trajectory-v1",
           "note": "per-PR benchmark medians; latest entry last",
           "entries": history}
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"# trajectory: {len(history)} entries -> {path}")
    return doc


def main():
    update()


if __name__ == "__main__":
    main()
