"""Cumulative perf-trajectory file: per-PR medians of the benchmark
artifacts, appended to BENCH_trajectory.json at the repo root so future
PRs have a baseline to regress against.

One entry per commit label (re-running under the same HEAD replaces the
entry instead of appending). Medians are deliberately coarse — one number
per (suite, config) — because the trajectory is for spotting cross-PR
cliffs, not for microbenchmark archaeology; the full per-op numbers stay
in artifacts/bench/BENCH_*.json.

The entry label comes from the artifacts themselves: every emitter
stamps `label` (short HEAD at *measurement* time) into its JSON via
benchmarks.common.stamp_label, so an artifact measured under commit A
is never filed under commit B just because trajectory.py ran after a
later commit landed (that mislabeling bit the c879e13/8a56c96 entry).
Unstamped or mixed-label artifact sets fall back to HEAD with a
warning.

    python -m benchmarks.trajectory          # collect + update from the
                                             # existing artifacts
    python -m benchmarks.trajectory --check  # regression gate: compare
                                             # the fresh entry against
                                             # the last different-label
                                             # entry; fail on any key
                                             # > 20% worse (CI)
"""
from __future__ import annotations

import json
import pathlib
import subprocess
import sys
from typing import Dict, List, Optional, Tuple

REPO = pathlib.Path(__file__).resolve().parent.parent
TRAJECTORY = REPO / "BENCH_trajectory.json"
BENCH_DIR = REPO / "artifacts" / "bench"

# Regression-gate knobs (--check): a key is a regression when the new
# value is worse than the previous entry's same key by more than
# CHECK_TOLERANCE. "Worse" is direction-aware — see _higher_is_better.
CHECK_TOLERANCE = 0.20

# Dotted key paths exempt from the gate. busy_us is a calibration knob
# (sized per-run from the measured steady state, not a performance
# result), and per_batch_us = stage time + busy_us, so both move with
# the knob — the cross-PR pipeline metric is speedup_depth2, which IS
# gated; median_regret is gated by the absolute <= 0.10 ceiling in
# adaptive_bench --smoke, and its run-to-run noise at small batch counts
# exceeds any sane relative tolerance.
CHECK_OPT_OUT = (
    "pipeline.busy_us",
    "pipeline.per_batch_us",
    "adaptive.median_regret",
)

_HIGHER_BETTER_MARKERS = ("speedup", "hit_rate", "dedup_ratio")


def _git_label() -> str:
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True, cwd=REPO,
                             timeout=10)
        if out.returncode == 0:
            return out.stdout.strip()
    except Exception:
        pass
    return "unknown"


def _median(vals) -> Optional[float]:
    vals = sorted(v for v in vals if isinstance(v, (int, float)))
    if not vals:
        return None
    mid = len(vals) // 2
    return (vals[mid] if len(vals) % 2
            else (vals[mid - 1] + vals[mid]) / 2.0)


def _load(fname: str) -> Optional[dict]:
    p = BENCH_DIR / fname
    if not p.exists():
        return None
    with open(p) as f:
        return json.load(f)


def _csv_medians(fname: str, impl_col: str, val_col: str) -> dict:
    """Per-impl median over a benchmark CSV's numeric value column."""
    p = BENCH_DIR / fname
    if not p.exists():
        return {}
    rows = p.read_text().strip().splitlines()
    header = rows[0].split(",")
    try:
        i_impl, i_val = header.index(impl_col), header.index(val_col)
    except ValueError:
        return {}
    by_impl: dict = {}
    for line in rows[1:]:
        cells = line.split(",")
        try:
            by_impl.setdefault(cells[i_impl], []).append(float(cells[i_val]))
        except (ValueError, IndexError):
            continue
    return {impl: _median(v) for impl, v in by_impl.items()}


def _resolve_label(artifacts: List[Optional[dict]]) -> str:
    """Entry label from the artifacts' own stamps. Unique stamp wins;
    no stamps -> HEAD fallback; mixed stamps -> HEAD with a warning
    (the artifact set straddles commits and shouldn't be filed as one
    measurement)."""
    stamps = {a["label"] for a in artifacts
              if a and a.get("label") and a["label"] != "unknown"}
    dirty = any(a.get("git_dirty") for a in artifacts if a)
    if dirty:
        print("# WARNING: some artifacts were measured on a dirty tree")
    if len(stamps) == 1:
        return stamps.pop()
    head = _git_label()
    if len(stamps) > 1:
        print(f"# WARNING: artifacts stamped with mixed labels "
              f"{sorted(stamps)}; filing entry under HEAD ({head})")
    return head


def collect() -> dict:
    """One trajectory entry from whatever artifacts currently exist."""
    comp = _load("BENCH_components.json")
    pl = _load("BENCH_pipeline.json")
    ad = _load("BENCH_adaptive.json")
    sc = _load("BENCH_scaling.json")
    fl = _load("BENCH_faults.json")
    entry: dict = {"label": _resolve_label([comp, pl, ad, sc, fl])}

    if comp:
        rows = comp.get("rows", {})
        p8 = rows.get("8") or (rows[max(rows, key=int)] if rows else {})
        entry["components"] = {
            "median_us_per_op_P8": _median(p8.values()),
            "ops": {k: v for k, v in sorted(p8.items())},
        }
        co = comp.get("coalescing", {}).get("8")
        if co:
            entry["components"]["coalescing"] = {
                "speedup": co.get("coalesce_speedup"),
                "dedup_ratio": co.get("dedup_ratio"),
                "us_coalesced": co.get("ht_hot_insert_find_coalesced"),
            }
        ca = comp.get("cache", {}).get("8")
        if ca:
            entry["components"]["cache"] = {
                "speedup": ca.get("cache_speedup"),
                "hit_rate": ca.get("hit_rate"),
                "us_cached": ca.get("ht_read_heavy_find_cached"),
            }

    if pl:
        entry["pipeline"] = {
            "speedup_depth2": pl.get("speedup_depth2"),
            "per_batch_us": pl.get("per_batch_us"),
            "busy_us": pl.get("busy_us"),
        }
        cached = pl.get("cached")
        if isinstance(cached, dict):
            entry["pipeline"]["cached"] = {
                "speedup_depth2": cached.get("speedup_depth2"),
                "hit_rate_last_stream": cached.get("hit_rate_last_stream"),
            }

    if ad:
        scen = ad.get("scenarios", ad)
        regrets = [s.get("regret") for s in scen.values()
                   if isinstance(s, dict) and "regret" in s]
        entry["adaptive"] = {
            "median_regret": _median(regrets),
            "scenarios": sorted(k for k in scen if isinstance(
                scen[k], dict)),
        }

    if sc:
        entry["scaling"] = _scaling_section(sc)

    if fl:
        # deterministic plane counters only (pure functions of the
        # sweep seed): wall_us_* stays in the artifact, not the
        # trajectory, because chaos replay wall time is compile- and
        # load-dominated
        faults: dict = {}
        for lr, row in sorted((fl.get("sweep") or {}).items(),
                              key=lambda kv: float(kv[0])):
            faults[lr] = {k: row[k] for k in
                          ("retransmits", "dup_redeliveries",
                           "backoff_units", "exhausted",
                           "nonconformant_arms") if k in row}
        if faults:
            entry["faults"] = faults

    ht = _csv_medians("hashtable.csv", "impl", "measured_us")
    if ht:
        entry["hashtable"] = {"median_us_per_impl": ht,
                              "median_us": _median(ht.values())}
    qb = _csv_medians("queue.csv", "impl", "measured_us")
    if qb:
        entry["queue"] = {"median_us_per_impl": qb,
                          "median_us": _median(qb.values())}
    return entry


def _scaling_section(sc: dict) -> dict:
    """Per-P medians from BENCH_scaling.json: for each mode (weak /
    strong) and P, the median us/op across (struct, op) per arm — one
    number per (mode, P, arm), coarse on purpose."""
    out: dict = {}
    for mode in ("weak", "strong"):
        per_p = sc.get(mode)
        if not isinstance(per_p, dict):
            continue
        out[mode] = {}
        for p_str, rec in sorted(per_p.items(), key=lambda kv: int(kv[0])):
            by_arm: Dict[str, list] = {}
            for struct in ("ht", "q"):
                for op_rows in (rec.get(struct) or {}).values():
                    for arm, us in (op_rows or {}).items():
                        if isinstance(us, (int, float)):
                            by_arm.setdefault(arm, []).append(us)
            out[mode][p_str] = {
                arm: _median(v) for arm, v in sorted(by_arm.items())}
    fitted = sc.get("fitted_params")
    if isinstance(fitted, dict):
        out["fitted_params"] = fitted
    return out


def update(path: pathlib.Path = TRAJECTORY) -> dict:
    """Insert/replace this entry in the trajectory file (keyed by the
    artifact-stamped label)."""
    entry = collect()
    history = []
    if path.exists():
        try:
            with open(path) as f:
                history = json.load(f).get("entries", [])
        except (json.JSONDecodeError, AttributeError):
            history = []
    history = [e for e in history if e.get("label") != entry["label"]]
    history.append(entry)
    doc = {"schema": "bench-trajectory-v1",
           "note": "per-PR benchmark medians; latest entry last",
           "entries": history}
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"# trajectory: {len(history)} entries -> {path}")
    return doc


# ---------------------------------------------------------------------------
# Regression gate (--check)
# ---------------------------------------------------------------------------

def _flatten(d: dict, prefix: str = "") -> Dict[str, float]:
    out: Dict[str, float] = {}
    for k, v in d.items():
        path = f"{prefix}.{k}" if prefix else str(k)
        if isinstance(v, dict):
            out.update(_flatten(v, path))
        elif isinstance(v, (int, float)) and not isinstance(v, bool):
            out[path] = float(v)
    return out


def _higher_is_better(key: str) -> bool:
    return any(m in key for m in _HIGHER_BETTER_MARKERS)


def compare(prev: dict, new: dict,
            tolerance: float = CHECK_TOLERANCE) -> List[Tuple[str, float,
                                                              float, float]]:
    """Regressions of `new` vs `prev`: list of (key, prev, new, ratio)
    where ratio > 1 means `new` is worse by that factor. Keys present in
    only one entry are skipped (new benches appear, old ones retire)."""
    p_flat, n_flat = _flatten(prev), _flatten(new)
    bad = []
    for key in sorted(set(p_flat) & set(n_flat)):
        if any(key == o or key.startswith(o + ".") for o in CHECK_OPT_OUT):
            continue
        pv, nv = p_flat[key], n_flat[key]
        if pv <= 0 or nv <= 0:
            continue
        ratio = pv / nv if _higher_is_better(key) else nv / pv
        if ratio > 1.0 + tolerance:
            bad.append((key, pv, nv, ratio))
    return bad


def check(path: pathlib.Path = TRAJECTORY) -> bool:
    """CI gate: collect a fresh entry from the current artifacts and
    compare it against the last trajectory entry with a DIFFERENT label
    (i.e. the previous PR's measurement). Does not write the file."""
    new = collect()
    history = []
    if path.exists():
        try:
            with open(path) as f:
                history = json.load(f).get("entries", [])
        except (json.JSONDecodeError, AttributeError):
            history = []
    baseline = None
    for e in reversed(history):
        if e.get("label") != new["label"]:
            baseline = e
            break
    if baseline is None:
        print("# trajectory check: no prior entry to compare against; OK")
        return True
    bad = compare(baseline, new)
    print(f"# trajectory check: {new['label']} vs {baseline['label']} "
          f"(tolerance {CHECK_TOLERANCE:.0%})")
    if not bad:
        print("# trajectory check: OK — no key worse than tolerance")
        return True
    for key, pv, nv, ratio in bad:
        print(f"REGRESSION {key}: {pv:.4g} -> {nv:.4g} "
              f"({ratio - 1.0:+.1%} worse)")
    return False


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    if "--check" in argv:
        sys.exit(0 if check() else 1)
    update()


if __name__ == "__main__":
    main()
