"""Pipelined batch engine: depth-d overlap speedup + attentiveness sweep
(DESIGN.md §7).

The paper's RPC liability is *attentiveness* — remote progress only happens
when the target enters the runtime — and its flip side is that any engine
running batches lock-step leaves the owner-apply lane idle while the next
batch is still being staged. `core/pipeline.py` closes that gap with
futures-style op handles over double-buffered windows; this benchmark
measures what the overlap buys and makes the attentiveness knob measurable:

1. **Depth sweep** (the acceptance gate): a stream of P=8 insert+find
   batches runs through `Pipeline(ht, depth=d)` for d in DEPTHS. Between
   submits the host performs `busy_us` of application compute (`common.
   busy_wait` — the same interspersed-compute knob as the Fig. 6
   attentiveness emulation, sized by default to one measured batch
   execution). depth=1 forces each batch before staging the next, so host
   and device serialize: T ≈ Σ (busy + exec). depth>=2 stages batch k+1
   (host) while batch k executes (device): T ≈ Σ max(busy, exec) — the §7
   overlap formula measured end-to-end. The gate requires
   depth-2 >= 1.25x depth-1 on this mix (ISSUE 5 acceptance).

   Busy sizing (the PR 6 regression fix, ISSUE 8): the busy window is the
   MEDIAN steady-state batch execution measured over the REAL state
   trajectory (a calibration pass of the whole stream at depth 1), not
   the empty-table warmup batch. Probe chains lengthen as the table
   fills, so an empty-table-sized window under-fills the overlap for the
   back half of the stream and the measured speedup decays with batch
   count — that mis-sizing, not the engine, was the 1.50x -> 1.18x drop.

1b. **Cache-attached depth sweep** (ISSUE 8 acceptance): the same sweep
   with a `core/cache.BucketCache` in the loop — a hot read set is
   pre-warmed, each batch does the host-side cache work (pre-write
   invalidation + lookup) at stage time and ships ONE jitted
   insert + miss-subset-find step (`find_rdma(..., return_slot=True)`
   feeds `cache.note_fill`). This pins that the host cache path stays
   off the critical path of the overlap: deferred fills drain
   non-blocking while the pipeline holds windows in flight
   (`cache.drain_fills` auto-detect, the §8/§7 interaction fixed here).

2. **Attentiveness sweep**: deferred AM batches (`find_async(...,
   backend="rpc")`) wait in the `AMEngine` dispatch queue until the next
   dispatch point; their queue wait is measured against the busy window
   separating submit from the next dispatch point. Service latency tracks
   the busy window ~1:1 — the paper's attentiveness cost, now a directly
   tunable and measurable quantity of the engine itself.

  python -m benchmarks.pipeline_bench            # full run -> JSON artifact
  python -m benchmarks.pipeline_bench --smoke    # CI gate (reduced config)

Env overrides: REPRO_PIPE_N, REPRO_PIPE_BATCHES, REPRO_PIPE_ITERS.
Artifact: artifacts/bench/BENCH_pipeline.json (folded into
BENCH_trajectory.json by benchmarks/trajectory.py).
"""
from __future__ import annotations

import json
import os
import pathlib
import sys
import time
from typing import Dict, List, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import am as am_mod
from repro.core import hashtable as ht_mod
from repro.core import pipeline as pl_mod

from .common import Csv, busy_wait, gen_batch_keys, stamp_label

P = 8
# Low load factor by construction: the stream's total inserts per rank
# (batches * n) must stay well under NSLOTS, or probe loops lengthen as
# the table fills and the "exec per batch" the busy window was sized to
# stops being representative.
NSLOTS = 1 << 15
VAL_WORDS = 1
DEPTHS = (1, 2, 4)
GATE = 1.25


def _cfg(smoke: bool) -> Tuple[int, int, int]:
    n = int(os.environ.get("REPRO_PIPE_N", 192 if smoke else 256))
    batches = int(os.environ.get("REPRO_PIPE_BATCHES", 10 if smoke else 20))
    iters = int(os.environ.get("REPRO_PIPE_ITERS", 3 if smoke else 5))
    return n, batches, iters


def _make_step():
    """One jitted insert+find batch — the unit of pipelined work. The op
    closure dispatches this asynchronously; the host returns as soon as
    the work is enqueued (the overlap mechanism, DESIGN.md §7)."""

    @jax.jit
    def step(ht, keys, vals, fkeys):
        ht, ok, probes = ht_mod.insert_rdma(ht, keys, vals, fused=True)
        ht, found, fvals = ht_mod.find_rdma(ht, fkeys, fused=True)
        return ht, (ok, probes, found, fvals)

    return step


def _gen_batches(n: int, batches: int, seed: int = 0):
    """Device-resident key/val batches (distinct keys across the stream);
    each batch finds the keys of the PREVIOUS batch (a dependent mix)."""
    rng = np.random.default_rng(seed)
    used: set = set()
    out = []
    prev_keys = None
    for _ in range(batches):
        k = gen_batch_keys(P, n, "uniform", rng, used)
        v = rng.integers(1, 1 << 20, (P, n, VAL_WORDS)).astype(np.int32)
        fk = prev_keys if prev_keys is not None else k
        out.append((jnp.asarray(k), jnp.asarray(v), jnp.asarray(fk)))
        prev_keys = k
    return out


def _run_stream(submit, ht0, batch_ids, depth: int, busy_us: float,
                before=None) -> Tuple[float, List[float]]:
    """(total wall seconds, per-batch wall seconds) for one stream pass.

    submit(pipe, i) stages batch i; `before(pipe)` (optional) runs once
    before the clock starts (cache re-warm, outside the timed region)."""
    pipe = pl_mod.Pipeline(ht0, depth=depth)
    if before is not None:
        before(pipe)
    per = []
    t0 = time.perf_counter()
    for i in batch_ids:
        tb = time.perf_counter()
        submit(pipe, i)
        busy_wait(busy_us)
        per.append(time.perf_counter() - tb)
    pipe.flush()
    return time.perf_counter() - t0, per


def _steady_busy_us(submit, ht0, batch_ids, before=None) -> float:
    """Busy-window calibration over the REAL state trajectory.

    One un-timed depth-1 pass warms every jit shape (the probe loop's
    trip count grows as the table fills — each fill level is the same
    compiled fn, but the warm pass also pays compilation exactly once);
    a second depth-1 pass with busy=0 measures per-batch execution, and
    the busy window is its p90 — the app-compute window must cover the
    SLOW end of the steady-state distribution (probe chains lengthen as
    the table fills), or the back half of the stream re-serializes and
    the measured overlap decays with batch count — the PR 6 sizing (one
    empty-table batch) failed exactly that way."""
    _run_stream(submit, ht0, batch_ids, 1, 0.0, before=before)
    _, per = _run_stream(submit, ht0, batch_ids, 1, 0.0, before=before)
    per = sorted(per)
    return per[min(len(per) - 1, (len(per) * 9) // 10)] * 1e6


def _depth_medians(submit, ht0, batch_ids, iters, busy_us, before=None):
    """Interleaved depth sweep (machine drift cancels), medians over
    iters."""
    totals: Dict[int, List[float]] = {d: [] for d in DEPTHS}
    for _ in range(iters):
        for d in DEPTHS:
            t, _ = _run_stream(submit, ht0, batch_ids, d, busy_us,
                               before=before)
            totals[d].append(t)
    return {d: sorted(ts)[len(ts) // 2] for d, ts in totals.items()}


def bench_depth_sweep(n: int, batches: int, iters: int) -> Dict:
    """The acceptance workload: depth-1 vs depth-d wall time."""
    step = _make_step()
    dev_batches = _gen_batches(n, batches)
    ht0 = ht_mod.make_hashtable(P, NSLOTS, VAL_WORDS)
    ids = list(range(batches))

    def submit(pipe, i):
        k, v, fk = dev_batches[i]
        pipe.submit(lambda ht, k=k, v=v, fk=fk: step(ht, k, v, fk))

    busy_us = _steady_busy_us(submit, ht0, ids)
    med = _depth_medians(submit, ht0, ids, iters, busy_us)
    speedup = med[1] / med[2]
    return {
        "P": P, "n": n, "batches": batches, "iters": iters,
        "mix": "insert+find", "busy_us": busy_us,
        "exec_us_per_batch": busy_us,
        "total_s": {str(d): med[d] for d in DEPTHS},
        "per_batch_us": {str(d): med[d] / batches * 1e6 for d in DEPTHS},
        "speedup_depth2": speedup,
        "gate": GATE,
    }


def bench_depth_sweep_cached(n: int, batches: int, iters: int) -> Dict:
    """The depth sweep with a BucketCache attached (ISSUE 8 acceptance).

    Mix: every batch inserts fresh keys and finds a fixed HOT set that was
    pre-inserted and cache-warmed; the op does the host cache work at
    stage time (pre-write invalidation + lookup) and ships one jitted
    insert + miss-subset-find step whose hit slots feed `note_fill`.
    Hits decay within a stream as fresh inserts bump hot probe windows
    (the version protocol at work), so the cache is re-warmed before
    each pass — outside the timed region."""
    from repro.core import cache as cache_mod

    dev_batches = _gen_batches(n, batches, seed=7)
    np_keys = [np.asarray(k) for k, _, _ in dev_batches]
    rng = np.random.default_rng(99)
    used = {int(x) for k in np_keys for x in k.ravel()}
    hot_np = gen_batch_keys(P, n, "uniform", rng, used)
    hot_vals = rng.integers(1, 1 << 20, (P, n, VAL_WORDS)).astype(np.int32)
    hot = jnp.asarray(hot_np)

    ht_empty = ht_mod.make_hashtable(P, NSLOTS, VAL_WORDS)
    ht0, ok_w, _ = ht_mod.insert_rdma(ht_empty, hot,
                                      jnp.asarray(hot_vals), fused=True)
    jax.block_until_ready(ok_w)
    cache = cache_mod.BucketCache(P, NSLOTS, VAL_WORDS, capacity=4096,
                                  max_probes=8)

    @jax.jit
    def step(ht, keys, vals, fkeys, miss):
        ht, ok, probes = ht_mod.insert_rdma(ht, keys, vals, fused=True)
        ht, found, fvals, slot = ht_mod.find_rdma(ht, fkeys, fused=True,
                                                  valid=miss,
                                                  return_slot=True)
        return ht, (ok, probes, found, fvals, slot)

    hit_log: List[float] = []

    def submit(pipe, i):
        k, v, _ = dev_batches[i]
        k_np = np_keys[i]

        def op(ht):
            cache.on_insert_keys(k_np)
            look = cache.lookup(hot_np)
            hit_log.append(look.hit_rate)
            miss = jnp.asarray(look.miss)
            ht2, outs = step(ht, k, v, hot, miss)
            cache.note_fill(look, outs[4], outs[2], outs[3])
            return ht2, outs

        pipe.submit(op)

    def rewarm(pipe):
        # sync integrated find on the hot set: all-miss -> probe -> fills
        # applied eagerly (no pipeline in flight yet)
        cache.invalidate_all()
        ht_r, f, _ = ht_mod.find_rdma(ht0, hot, fused=True, cache=cache)
        jax.block_until_ready(f)
        cache.drain_fills(force=True)
        hit_log.clear()

    ids = list(range(batches))
    busy_us = _steady_busy_us(submit, ht0, ids, before=rewarm)
    med = _depth_medians(submit, ht0, ids, iters, busy_us, before=rewarm)
    speedup = med[1] / med[2]
    hit_rate = float(np.mean(hit_log[-batches:])) if hit_log else 0.0
    return {
        "P": P, "n": n, "batches": batches, "iters": iters,
        "mix": "insert-fresh+find-hot(cache)", "busy_us": busy_us,
        "total_s": {str(d): med[d] for d in DEPTHS},
        "per_batch_us": {str(d): med[d] / batches * 1e6 for d in DEPTHS},
        "speedup_depth2": speedup,
        "hit_rate_last_stream": hit_rate,
        "fill_drops": cache.counters["fill_drops"],
        "fills": cache.counters["fills"],
        "gate": GATE,
    }


def bench_attentiveness(n: int = 64) -> List[Dict]:
    """Deferred-AM queue wait vs the busy window before the next dispatch
    point: the attentiveness knob, measured on the engine itself. The
    timestamp is taken INSIDE the deferred op — i.e. when the dispatch
    point actually drains it — so the reported wait is the real queue
    time, not the caller's own busy window re-measured."""
    ht0 = ht_mod.make_hashtable(P, NSLOTS, VAL_WORDS)
    rng = np.random.default_rng(1)
    keys = jnp.asarray(gen_batch_keys(P, n, "uniform", rng))
    rows = []
    for busy in (0.0, 500.0, 2000.0, 8000.0):
        eng = am_mod.AMEngine(P)
        ht_mod.build_am_handlers(ht0, eng)
        pipe = pl_mod.Pipeline(ht0, depth=2, am_engine=eng)
        staged_at = {}

        def op(ht):
            staged_at["t"] = time.perf_counter()
            ht2, found, vals = ht_mod.find(ht, keys, backend="rpc",
                                           engine=eng)
            return ht2, (found, vals)

        t0 = time.perf_counter()
        h = pipe.submit(op, deferred=True, label="att_find")
        busy_wait(busy)
        pending = pipe.pending_deferred
        pipe.flush()                      # the dispatch point
        h.result()
        wait_us = (staged_at["t"] - t0) * 1e6
        rows.append({"busy_us": busy, "service_wait_us": wait_us,
                     "dispatch_points": eng.dispatch_points,
                     "pending_before_flush": pending})
    return rows


def emit_json(result: Dict, out_dir: str = "artifacts/bench") -> str:
    p = pathlib.Path(out_dir) / "BENCH_pipeline.json"
    p.parent.mkdir(parents=True, exist_ok=True)
    with open(p, "w") as f:
        json.dump(stamp_label({"schema": "bench-pipeline-v2", **result}),
                  f, indent=2)
    print(f"# wrote {p}")
    return str(p)


def run(smoke: bool) -> Dict:
    n, batches, iters = _cfg(smoke)
    sweep = bench_depth_sweep(n, batches, iters)
    cached = bench_depth_sweep_cached(n, batches, iters)
    att = bench_attentiveness()
    csv = Csv(["depth", "total_s", "per_batch_us"])
    for d in DEPTHS:
        csv.add(d, f"{sweep['total_s'][str(d)]:.4f}",
                f"{sweep['per_batch_us'][str(d)]:.1f}")
    print(f"# speedup depth2/depth1: {sweep['speedup_depth2']:.3f}x "
          f"(gate >= {GATE}x, busy_us={sweep['busy_us']:.0f})")
    print(f"# cache-attached speedup depth2/depth1: "
          f"{cached['speedup_depth2']:.3f}x "
          f"(hit rate {cached['hit_rate_last_stream']:.2f}, "
          f"busy_us={cached['busy_us']:.0f})")
    for r in att:
        print(f"# attentiveness: busy={r['busy_us']:.0f}us -> "
              f"deferred wait={r['service_wait_us']:.0f}us")
    result = {**sweep, "cached": cached, "attentiveness": att}
    emit_json(result)
    return result


def smoke() -> bool:
    result = run(smoke=True)
    ok_plain = result["speedup_depth2"] >= GATE
    ok_cached = result["cached"]["speedup_depth2"] >= GATE
    ok = ok_plain and ok_cached
    status = "PASS" if ok else "FAIL"
    print(f"# pipeline smoke {status}: depth-2 speedup "
          f"{result['speedup_depth2']:.3f}x, cache-attached "
          f"{result['cached']['speedup_depth2']:.3f}x vs gate {GATE}x")
    return ok


def main():
    run(smoke=False)


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        sys.exit(0 if smoke() else 1)
    main()
