"""§Roofline: derive the three per-device roofline terms for every
(arch × shape) cell from the dry-run artifacts (single-pod mesh), identify
the dominant term, and emit the EXPERIMENTS.md table.

  compute_s    = HLO_FLOPs(trip-aware) / 197 TFLOP/s (bf16, v5e)
  memory_s     = HLO HBM-byte proxy     / 819 GB/s
  collective_s = ICI ring-model bytes   / 100 GB/s (2 links x 50 GB/s,
                 bidirectional ring on one mesh axis)

MODEL_FLOPS = 6·N·D (train, dense), 6·N_active·D (train, MoE),
2·N(_active)·D (prefill/decode) — per the assignment spec; the
HLO/MODEL ratio surfaces remat + attention + capacity waste.
"""
from __future__ import annotations

import json
import pathlib
from dataclasses import dataclass

from repro.configs import registry
from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

ICI_EFFECTIVE = 2 * ICI_BW_PER_LINK   # bidirectional ring on one axis


def model_flops(arch: str, shape_name: str) -> float:
    cfg = registry.get(arch)
    shape = registry.get_shape(cfg, shape_name)
    n = (cfg.active_params_count() if cfg.n_experts
         else cfg.params_count())
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch            # one token per sequence
    return 2.0 * n * tokens


@dataclass
class Cell:
    arch: str
    shape: str
    mesh: str
    world: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_global: float
    ratio: float
    note: str

    @property
    def step_s(self):
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self):
        """useful-work fraction: time the hardware would need for
        MODEL_FLOPS alone / the bottleneck term's time."""
        ideal = self.model_flops / self.world / PEAK_FLOPS_BF16
        return ideal / self.step_s if self.step_s else 0.0


NOTES = {
    "compute": "reduce HLO/model ratio: causal chunk skip, remat policy, "
               "fewer recomputed attention matmuls",
    "memory": "fuse/serve larger per-step tiles; cut activation and cache "
              "re-reads (flash already removes S^2 traffic)",
    "collective": "reshard to cut all-gathers (FSDP prefetch), hierarchical"
                  " reduction, int8 gradient compression",
}


def load_cells(dryrun_dir="artifacts/dryrun", mesh="pod"):
    cells = []
    for p in sorted(pathlib.Path(dryrun_dir).glob(f"*__{mesh}.json")):
        r = json.loads(p.read_text())
        if not r.get("ok"):
            continue
        world = r["world"]
        comp = r["hlo_flops_per_device"] / PEAK_FLOPS_BF16
        mem = r["hlo_hbm_bytes_per_device"] / HBM_BW
        coll = r["collective_bytes_per_device"] / ICI_EFFECTIVE
        dom = max(("compute", comp), ("memory", mem),
                  ("collective", coll), key=lambda t: t[1])[0]
        mf = model_flops(r["arch"], r["shape"])
        hlo_global = r["hlo_flops_per_device"] * world
        cells.append(Cell(
            arch=r["arch"], shape=r["shape"], mesh=r["mesh"], world=world,
            compute_s=comp, memory_s=mem, collective_s=coll, dominant=dom,
            model_flops=mf, hlo_flops_global=hlo_global,
            ratio=hlo_global / mf if mf else 0.0,
            note=NOTES[dom]))
    return cells


def as_markdown(cells) -> str:
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant |"
        " model/HLO | roofline_frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for c in sorted(cells, key=lambda c: (c.arch, c.shape)):
        lines.append(
            f"| {c.arch} | {c.shape} | {c.compute_s:.3e} | "
            f"{c.memory_s:.3e} | {c.collective_s:.3e} | {c.dominant} | "
            f"{1.0/c.ratio if c.ratio else 0:.3f} | "
            f"{c.roofline_fraction:.3f} |")
    return "\n".join(lines)


def main(out="artifacts/bench"):
    cells = load_cells()
    outp = pathlib.Path(out)
    outp.mkdir(parents=True, exist_ok=True)
    md = as_markdown(cells)
    (outp / "roofline.md").write_text(md + "\n")
    print(f"benchmark,arch,shape,compute_s,memory_s,collective_s,dominant,"
          f"roofline_frac")
    for c in sorted(cells, key=lambda c: (c.arch, c.shape)):
        print(f"roofline,{c.arch},{c.shape},{c.compute_s:.4e},"
              f"{c.memory_s:.4e},{c.collective_s:.4e},{c.dominant},"
              f"{c.roofline_fraction:.4f}")
    # hillclimb candidates
    worst = min(cells, key=lambda c: c.roofline_fraction)
    most_coll = max(cells, key=lambda c: (c.collective_s / c.step_s
                                          if c.step_s else 0))
    print(f"# worst roofline fraction: {worst.arch}/{worst.shape} "
          f"({worst.roofline_fraction:.3f})")
    print(f"# most collective-bound: {most_coll.arch}/{most_coll.shape} "
          f"({most_coll.collective_s/most_coll.step_s:.2f} of step)")
    return cells


if __name__ == "__main__":
    main()
