"""Paper Fig. 5: hash-table operation latencies — RDMA find C_R / C_RW,
AM insert/find, RDMA insert C_RW / C_W — measured vs model prediction,
for BOTH engines: the seed per-component path (fused=False) and the
planned+fused path (fused=True, DESIGN.md §2). The `*_fused` columns
re-validate the model's ordering claim against the faster engine.

Run `python -m benchmarks.hashtable_bench --smoke` for the single-config
(P=8, n=64) fused-vs-seed speedup check used by scripts/smoke.sh.
"""
from __future__ import annotations

import sys

import numpy as np
import jax.numpy as jnp

from repro.core import am as am_mod
from repro.core import costmodel as cm
from repro.core import hashtable as ht_mod
from repro.core import window
from repro.core.types import Backend, Promise

from . import components
from .common import Csv, time_op

NSLOTS = 8192


def bench_hashtable(P: int = 8, n: int = 32, iters: int = 15):
    ops = P * n
    rng = np.random.default_rng(0)
    keys = jnp.asarray(
        rng.permutation(1 << 20)[:ops].reshape(P, n) + 1, jnp.int32)
    vals = jnp.stack([keys, keys], axis=-1)
    base = ht_mod.make_hashtable(P, NSLOTS, 2)
    eng = am_mod.AMEngine(P)
    ht_mod.build_am_handlers(base, eng)
    filled, ok, _ = ht_mod.insert_rdma(base, keys, vals, promise=Promise.CW)
    assert bool(ok.all())

    def wrap(data):
        return ht_mod.DHashTable(win=window.Window(data=data),
                                 nslots=NSLOTS, val_words=2)

    def insert(promise, fused):
        def fn(data):
            ht, _, _ = ht_mod.insert_rdma(wrap(data), keys, vals,
                                          promise=promise, max_probes=4,
                                          fused=fused)
            return ht.win.data
        return fn

    def insert_am(data):
        ht, _, _ = ht_mod.insert_rpc(wrap(data), eng, keys, vals)
        return ht.win.data

    def find(promise, fused):
        def fn(data):
            ht, f, v = ht_mod.find_rdma(wrap(data), keys, promise=promise,
                                        max_probes=4, fused=fused)
            return ht.win.data, f, v
        return fn

    def find_am(data):
        return ht_mod.find_rpc(wrap(data), eng, keys)

    empty = base.win.data
    full = filled.win.data
    return {
        "rdma_find_cr": time_op(find(Promise.CR, False), full, iters=iters,
                                ops_per_call=ops),
        "rdma_find_cr_fused": time_op(find(Promise.CR, True), full,
                                      iters=iters, ops_per_call=ops),
        "am_find_crw": time_op(find_am, full, iters=iters,
                               ops_per_call=ops),
        "am_insert_crw": time_op(insert_am, empty, iters=iters,
                                 ops_per_call=ops),
        "rdma_find_crw": time_op(find(Promise.CRW, False), full,
                                 iters=iters, ops_per_call=ops),
        "rdma_find_crw_fused": time_op(find(Promise.CRW, True), full,
                                       iters=iters, ops_per_call=ops),
        "rdma_insert_crw": time_op(insert(Promise.CRW, False), empty,
                                   iters=iters, ops_per_call=ops),
        "rdma_insert_crw_fused": time_op(insert(Promise.CRW, True), empty,
                                         iters=iters, ops_per_call=ops),
        "rdma_insert_cw": time_op(insert(Promise.CW, False), empty,
                                  iters=iters, ops_per_call=ops),
        "rdma_insert_cw_fused": time_op(insert(Promise.CW, True), empty,
                                        iters=iters, ops_per_call=ops),
    }


# impl -> (op, promise, backend, fused)
PRED = {
    "rdma_find_cr": (cm.DSOp.HT_FIND, Promise.CR, Backend.RDMA, False),
    "rdma_find_cr_fused": (cm.DSOp.HT_FIND, Promise.CR, Backend.RDMA, True),
    "rdma_find_crw": (cm.DSOp.HT_FIND, Promise.CRW, Backend.RDMA, False),
    "rdma_find_crw_fused": (cm.DSOp.HT_FIND, Promise.CRW, Backend.RDMA,
                            True),
    "am_find_crw": (cm.DSOp.HT_FIND, Promise.CRW, Backend.RPC, False),
    "am_insert_crw": (cm.DSOp.HT_INSERT, Promise.CRW, Backend.RPC, False),
    "rdma_insert_crw": (cm.DSOp.HT_INSERT, Promise.CRW, Backend.RDMA,
                        False),
    "rdma_insert_crw_fused": (cm.DSOp.HT_INSERT, Promise.CRW, Backend.RDMA,
                              True),
    "rdma_insert_cw": (cm.DSOp.HT_INSERT, Promise.CW, Backend.RDMA, False),
    "rdma_insert_cw_fused": (cm.DSOp.HT_INSERT, Promise.CW, Backend.RDMA,
                             True),
}

# fused impl -> its seed-engine counterpart (speedup accounting)
SPEEDUP_PAIRS = {
    "rdma_insert_crw_fused": "rdma_insert_crw",
    "rdma_insert_cw_fused": "rdma_insert_cw",
    "rdma_find_crw_fused": "rdma_find_crw",
    "rdma_find_cr_fused": "rdma_find_cr",
}


def _predict(impl, params):
    op, promise, backend, fused = PRED[impl]
    return cm.predict(op, promise, backend, params=params, fused=fused)


def fused_speedups(rows):
    return {f: rows[u] / rows[f] for f, u in SPEEDUP_PAIRS.items()
            if f in rows and u in rows and rows[f]}


def main(out="artifacts/bench"):
    csv = Csv(["benchmark", "nranks", "impl", "measured_us",
               "predicted_us"])
    comp = components.bench_components(P=8)
    params = components.calibrated_costs(comp)
    for P in (2, 4, 8):
        rows = bench_hashtable(P=P)
        preds = {impl: _predict(impl, params) for impl in rows}
        for impl, us in rows.items():
            csv.add("hashtable(fig5)", P, impl, f"{us:.3f}",
                    f"{preds[impl]:.3f}")
        m_order = sorted(rows, key=rows.get)
        p_order = sorted(preds, key=preds.get)
        agree = sum(a == b for a, b in zip(m_order, p_order))
        print(f"# P={P} order agreement {agree}/{len(m_order)}: "
              f"measured {m_order}")
        for f, s in fused_speedups(rows).items():
            print(f"# P={P} {f} speedup over seed path: {s:.2f}x")
    csv.dump(f"{out}/hashtable.csv")
    return csv


def smoke(P: int = 8, n: int = 64, iters: int = 9) -> bool:
    """Acceptance config: fused+planned RDMA path vs the seed path at
    P=8, n=64 — median speedup must be >= 1.3x on the hot ops."""
    rows = bench_hashtable(P=P, n=n, iters=iters)
    speedups = fused_speedups(rows)
    for f, s in sorted(speedups.items()):
        print(f"{f:28s} {rows[f]:8.3f} us  (seed {rows[SPEEDUP_PAIRS[f]]:8.3f}"
              f" us)  speedup {s:.2f}x")
    med = float(np.median(list(speedups.values())))
    print(f"median fused/planned speedup at P={P}, n={n}: {med:.2f}x "
          f"(target >= 1.3x)")
    return med >= 1.3


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        sys.exit(0 if smoke() else 1)
    main()
