"""Paper Fig. 5: hash-table operation latencies — RDMA find C_R / C_RW,
AM insert/find, RDMA insert C_RW / C_W — measured vs model prediction."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import am as am_mod
from repro.core import costmodel as cm
from repro.core import hashtable as ht_mod
from repro.core import window
from repro.core.types import Backend, Promise

from . import components
from .common import Csv, time_op

NSLOTS = 8192


def bench_hashtable(P: int = 8, n: int = 32, iters: int = 15):
    ops = P * n
    rng = np.random.default_rng(0)
    keys = jnp.asarray(
        rng.permutation(1 << 20)[:ops].reshape(P, n) + 1, jnp.int32)
    vals = jnp.stack([keys, keys], axis=-1)
    base = ht_mod.make_hashtable(P, NSLOTS, 2)
    eng = am_mod.AMEngine(P)
    ht_mod.build_am_handlers(base, eng)
    filled, ok, _ = ht_mod.insert_rdma(base, keys, vals, promise=Promise.CW)
    assert bool(ok.all())

    def wrap(data):
        return ht_mod.DHashTable(win=window.Window(data=data),
                                 nslots=NSLOTS, val_words=2)

    def insert_crw(data):
        ht, _, _ = ht_mod.insert_rdma(wrap(data), keys, vals,
                                      promise=Promise.CRW, max_probes=4)
        return ht.win.data

    def insert_cw(data):
        ht, _, _ = ht_mod.insert_rdma(wrap(data), keys, vals,
                                      promise=Promise.CW, max_probes=4)
        return ht.win.data

    def insert_am(data):
        ht, _ = ht_mod.insert_rpc(wrap(data), eng, keys, vals)
        return ht.win.data

    def find_cr(data):
        ht, f, v = ht_mod.find_rdma(wrap(data), keys, promise=Promise.CR,
                                    max_probes=4)
        return f, v

    def find_crw(data):
        ht, f, v = ht_mod.find_rdma(wrap(data), keys, promise=Promise.CRW,
                                    max_probes=4)
        return ht.win.data, f, v

    def find_am(data):
        return ht_mod.find_rpc(wrap(data), eng, keys)

    empty = base.win.data
    full = filled.win.data
    return {
        "rdma_find_cr": time_op(find_cr, full, iters=iters,
                                ops_per_call=ops),
        "am_find_crw": time_op(find_am, full, iters=iters,
                               ops_per_call=ops),
        "am_insert_crw": time_op(insert_am, empty, iters=iters,
                                 ops_per_call=ops),
        "rdma_find_crw": time_op(find_crw, full, iters=iters,
                                 ops_per_call=ops),
        "rdma_insert_crw": time_op(insert_crw, empty, iters=iters,
                                   ops_per_call=ops),
        "rdma_insert_cw": time_op(insert_cw, empty, iters=iters,
                                  ops_per_call=ops),
    }


PRED = {
    "rdma_find_cr": (cm.DSOp.HT_FIND, Promise.CR, Backend.RDMA),
    "rdma_find_crw": (cm.DSOp.HT_FIND, Promise.CRW, Backend.RDMA),
    "am_find_crw": (cm.DSOp.HT_FIND, Promise.CRW, Backend.RPC),
    "am_insert_crw": (cm.DSOp.HT_INSERT, Promise.CRW, Backend.RPC),
    "rdma_insert_crw": (cm.DSOp.HT_INSERT, Promise.CRW, Backend.RDMA),
    "rdma_insert_cw": (cm.DSOp.HT_INSERT, Promise.CW, Backend.RDMA),
}


def main(out="artifacts/bench"):
    csv = Csv(["benchmark", "nranks", "impl", "measured_us",
               "predicted_us"])
    comp = components.bench_components(P=8)
    params = components.calibrated_costs(comp)
    for P in (2, 4, 8):
        rows = bench_hashtable(P=P)
        preds = {impl: cm.predict(*PRED[impl], params=params)
                 for impl in rows}
        for impl, us in rows.items():
            csv.add("hashtable(fig5)", P, impl, f"{us:.3f}",
                    f"{preds[impl]:.3f}")
        m_order = sorted(rows, key=rows.get)
        p_order = sorted(preds, key=preds.get)
        agree = sum(a == b for a, b in zip(m_order, p_order))
        print(f"# P={P} order agreement {agree}/{len(m_order)}: "
              f"measured {m_order}")
    csv.dump(f"{out}/hashtable.csv")
    return csv


if __name__ == "__main__":
    main()
