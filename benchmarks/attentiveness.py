"""Paper Fig. 6: queue-insert latency as the target's attentiveness
degrades (interspersed compute between AM dispatch points).

Emulation: the target services AMs only at dispatch points separated by
`busy_us` of real compute (busy-wait). A request arrives uniformly inside
the busy window, so it waits busy/2 on average. Three curves:

  am            request waits for the next dispatch point
  am_pt         a progress thread services immediately, at a constant
                contention factor (cost model's pt_overhead)
  rdma          the NIC lane (window phase engine) is always live:
                latency independent of target compute — the paper's
                central RDMA advantage.

CI knobs (de-flaking): the RNG is seeded (`seed`), and the sweep is
env-overridable — REPRO_ATT_ROUNDS (int), REPRO_ATT_BUSY (comma list of
µs), REPRO_ATT_SEED. `--smoke` runs a seconds-scale two-point sweep that
only asserts the structural Fig. 6 shape (AM latency grows with busy).
"""
from __future__ import annotations

import os
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import am as am_mod
from repro.core import costmodel as cm
from repro.core import queue as q_mod
from repro.core.types import Promise

from . import components
from .common import Csv, busy_wait as _busy_wait


def _env_overrides(rounds, busy_list, seed):
    rounds = int(os.environ.get("REPRO_ATT_ROUNDS", rounds))
    busy = os.environ.get("REPRO_ATT_BUSY")
    if busy:
        busy_list = tuple(float(b) for b in busy.split(","))
    seed = int(os.environ.get("REPRO_ATT_SEED", seed))
    return rounds, busy_list, seed


def bench_attentiveness(P: int = 4, n: int = 16, rounds: int = 30,
                        busy_list=(0, 1, 2, 4, 8, 16, 32), seed: int = 0):
    """Latency is per *dispatch* (one service opportunity), not per op:
    aggregation would otherwise amortize the attentiveness wait across the
    batch, which is a real property of the batched engine but hides the
    paper's per-request effect being measured here.

    Arguments are taken literally; only main() applies the REPRO_ATT_*
    env overrides (so smoke()'s fixed two-point sweep cannot be bent into
    a shape that fails its own assertion)."""
    vals = jnp.ones((P, n, 1), jnp.int32)
    ops = 1  # per-dispatch latency
    q0 = q_mod.make_queue(P, 0, 1 << 16, 1)
    eng = am_mod.AMEngine(P)
    q_mod.build_am_handlers(q0, eng)

    def am_phase(data):
        q = q_mod.DQueue(win=q_mod.Window(data=data), host=0,
                         capacity=1 << 16, val_words=1)
        q, _ = q_mod.push_rpc(q, eng, vals)
        return q.win.data

    def rdma_phase(data):
        q = q_mod.DQueue(win=q_mod.Window(data=data), host=0,
                         capacity=1 << 16, val_words=1)
        q, _ = q_mod.push_rdma(q, vals, promise=Promise.CW)
        return q.win.data

    am_j = jax.jit(am_phase)
    rdma_j = jax.jit(rdma_phase)
    jax.block_until_ready(am_j(q0.win.data))
    jax.block_until_ready(rdma_j(q0.win.data))
    rng = np.random.default_rng(seed)

    out = []
    for busy in busy_list:
        lat = {"am": [], "am_pt": [], "rdma": []}
        for _ in range(rounds):
            # request issued at a uniform offset into the busy window
            offset = rng.uniform(0, busy) if busy else 0.0
            t0 = time.perf_counter()
            _busy_wait(busy - offset)        # residual target compute
            jax.block_until_ready(am_j(q0.win.data))
            lat["am"].append((time.perf_counter() - t0) * 1e6 / ops)
            # progress thread: immediate service, constant overhead
            t0 = time.perf_counter()
            jax.block_until_ready(am_j(q0.win.data))
            dt = (time.perf_counter() - t0) * 1e6 / ops
            lat["am_pt"].append(dt * cm.CORI_PHASE1.pt_overhead)
            # rdma: NIC lane needs no target participation
            t0 = time.perf_counter()
            jax.block_until_ready(rdma_j(q0.win.data))
            lat["rdma"].append((time.perf_counter() - t0) * 1e6 / ops)
        med = {k: float(np.median(v)) for k, v in lat.items()}
        out.append((busy, med))
    return out


def main(out="artifacts/bench"):
    csv = Csv(["benchmark", "busy_us", "impl", "us_per_op"])
    rounds, busy_list, seed = _env_overrides(30, (0, 1, 2, 4, 8, 16, 32), 0)
    rows = bench_attentiveness(rounds=rounds, busy_list=busy_list,
                               seed=seed)
    for busy, med in rows:
        for impl, us in med.items():
            csv.add("attentiveness(fig6)", busy, impl, f"{us:.3f}")
    csv.dump(f"{out}/attentiveness.csv")
    # Fig. 6 structure: AM latency grows with busy; RDMA roughly flat;
    # crossover exists.
    am0 = rows[0][1]["am"]
    amN = rows[-1][1]["am"]
    r0 = rows[0][1]["rdma"]
    rN = rows[-1][1]["rdma"]
    print(f"# am {am0:.2f} -> {amN:.2f} us (grows); "
          f"rdma {r0:.2f} -> {rN:.2f} us (flat-ish)")
    crossover = next((b for b, m in rows if m["am"] > m["rdma"]), None)
    print(f"# am/rdma crossover at busy ~= {crossover} us")
    return rows


def smoke() -> bool:
    """Fast CI path: a seeded two-point sweep asserting only the robust
    structural property — AM latency strictly grows once the busy window
    dwarfs the dispatch itself (the wait is busy/2 in expectation, so the
    1000 µs point exceeds the 0 µs point by construction, not by luck)."""
    rows = bench_attentiveness(rounds=5, busy_list=(0, 1000), seed=0)
    am0, amN = rows[0][1]["am"], rows[-1][1]["am"]
    ok = amN > am0
    print(f"# smoke: am {am0:.1f} -> {amN:.1f} us at busy 0 -> 1000 "
          f"({'OK' if ok else 'FAIL'})")
    return ok


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        sys.exit(0 if smoke() else 1)
    main()
