"""Paper Fig. 6: queue-insert latency as the target's attentiveness
degrades (interspersed compute between AM dispatch points).

Emulation: the target services AMs only at dispatch points separated by
`busy_us` of real compute (busy-wait). A request arrives uniformly inside
the busy window, so it waits busy/2 on average. Three curves:

  am            request waits for the next dispatch point
  am_pt         a progress thread services immediately, at a constant
                contention factor (cost model's pt_overhead)
  rdma          the NIC lane (window phase engine) is always live:
                latency independent of target compute — the paper's
                central RDMA advantage.

CI knobs (de-flaking): the RNG is seeded (`seed`), and the sweep is
env-overridable — REPRO_ATT_ROUNDS (int), REPRO_ATT_BUSY (comma list of
µs), REPRO_ATT_SEED. `--smoke` runs a seconds-scale two-point sweep that
only asserts the structural Fig. 6 shape (AM latency grows with busy).

Fault sweep (DESIGN.md §10): `--faults` replays a mixed insert/find
stream per arm under seeded FaultPlans of increasing loss
(`--loss-rate` / REPRO_FAULT_LOSS comma list, `--dead-owner` /
REPRO_FAULT_DEAD rank) and records the plane's deterministic retransmit
counters plus conformance vs the fault-free oracle into
artifacts/bench/BENCH_faults.json (trajectory.py files it under a
"faults" section). `--smoke-chaos` is the CI gate: a seeded soak of
drops + duplicates + one permanently dead owner at P=8 must stay
conformant on every arm, and a permanently stalled queue must raise
RemoteTimeout inside the retry deadline instead of hanging.
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import adaptive as ad_mod
from repro.core import am as am_mod
from repro.core import costmodel as cm
from repro.core import faults as flt
from repro.core import hashtable as ht_mod
from repro.core import pipeline as pl_mod
from repro.core import queue as q_mod
from repro.core.types import Promise

from . import components
from .common import Csv, busy_wait as _busy_wait, stamp_label


def _env_overrides(rounds, busy_list, seed):
    rounds = int(os.environ.get("REPRO_ATT_ROUNDS", rounds))
    busy = os.environ.get("REPRO_ATT_BUSY")
    if busy:
        busy_list = tuple(float(b) for b in busy.split(","))
    seed = int(os.environ.get("REPRO_ATT_SEED", seed))
    return rounds, busy_list, seed


def bench_attentiveness(P: int = 4, n: int = 16, rounds: int = 30,
                        busy_list=(0, 1, 2, 4, 8, 16, 32), seed: int = 0):
    """Latency is per *dispatch* (one service opportunity), not per op:
    aggregation would otherwise amortize the attentiveness wait across the
    batch, which is a real property of the batched engine but hides the
    paper's per-request effect being measured here.

    Arguments are taken literally; only main() applies the REPRO_ATT_*
    env overrides (so smoke()'s fixed two-point sweep cannot be bent into
    a shape that fails its own assertion)."""
    vals = jnp.ones((P, n, 1), jnp.int32)
    ops = 1  # per-dispatch latency
    q0 = q_mod.make_queue(P, 0, 1 << 16, 1)
    eng = am_mod.AMEngine(P)
    q_mod.build_am_handlers(q0, eng)

    def am_phase(data):
        q = q_mod.DQueue(win=q_mod.Window(data=data), host=0,
                         capacity=1 << 16, val_words=1)
        q, _ = q_mod.push_rpc(q, eng, vals)
        return q.win.data

    def rdma_phase(data):
        q = q_mod.DQueue(win=q_mod.Window(data=data), host=0,
                         capacity=1 << 16, val_words=1)
        q, _ = q_mod.push_rdma(q, vals, promise=Promise.CW)
        return q.win.data

    am_j = jax.jit(am_phase)
    rdma_j = jax.jit(rdma_phase)
    jax.block_until_ready(am_j(q0.win.data))
    jax.block_until_ready(rdma_j(q0.win.data))
    rng = np.random.default_rng(seed)

    out = []
    for busy in busy_list:
        lat = {"am": [], "am_pt": [], "rdma": []}
        for _ in range(rounds):
            # request issued at a uniform offset into the busy window
            offset = rng.uniform(0, busy) if busy else 0.0
            t0 = time.perf_counter()
            _busy_wait(busy - offset)        # residual target compute
            jax.block_until_ready(am_j(q0.win.data))
            lat["am"].append((time.perf_counter() - t0) * 1e6 / ops)
            # progress thread: immediate service, constant overhead
            t0 = time.perf_counter()
            jax.block_until_ready(am_j(q0.win.data))
            dt = (time.perf_counter() - t0) * 1e6 / ops
            lat["am_pt"].append(dt * cm.CORI_PHASE1.pt_overhead)
            # rdma: NIC lane needs no target participation
            t0 = time.perf_counter()
            jax.block_until_ready(rdma_j(q0.win.data))
            lat["rdma"].append((time.perf_counter() - t0) * 1e6 / ops)
        med = {k: float(np.median(v)) for k, v in lat.items()}
        out.append((busy, med))
    return out


def main(out="artifacts/bench"):
    csv = Csv(["benchmark", "busy_us", "impl", "us_per_op"])
    rounds, busy_list, seed = _env_overrides(30, (0, 1, 2, 4, 8, 16, 32), 0)
    rows = bench_attentiveness(rounds=rounds, busy_list=busy_list,
                               seed=seed)
    for busy, med in rows:
        for impl, us in med.items():
            csv.add("attentiveness(fig6)", busy, impl, f"{us:.3f}")
    csv.dump(f"{out}/attentiveness.csv")
    # Fig. 6 structure: AM latency grows with busy; RDMA roughly flat;
    # crossover exists.
    am0 = rows[0][1]["am"]
    amN = rows[-1][1]["am"]
    r0 = rows[0][1]["rdma"]
    rN = rows[-1][1]["rdma"]
    print(f"# am {am0:.2f} -> {amN:.2f} us (grows); "
          f"rdma {r0:.2f} -> {rN:.2f} us (flat-ish)")
    crossover = next((b for b, m in rows if m["am"] > m["rdma"]), None)
    print(f"# am/rdma crossover at busy ~= {crossover} us")
    return rows


def smoke() -> bool:
    """Fast CI path: a seeded two-point sweep asserting only the robust
    structural property — AM latency strictly grows once the busy window
    dwarfs the dispatch itself (the wait is busy/2 in expectation, so the
    1000 µs point exceeds the 0 µs point by construction, not by luck)."""
    rows = bench_attentiveness(rounds=5, busy_list=(0, 1000), seed=0)
    am0, amN = rows[0][1]["am"], rows[-1][1]["am"]
    ok = amN > am0
    print(f"# smoke: am {am0:.1f} -> {amN:.1f} us at busy 0 -> 1000 "
          f"({'OK' if ok else 'FAIL'})")
    return ok


# ---------------------------------------------------------------------------
# Fault sweep + chaos gate (DESIGN.md §10)
# ---------------------------------------------------------------------------
_FAULT_ARMS = ("rdma", "rdma_fused", "am", "auto")


def _val_of(keys):
    return jnp.concatenate([((keys * 31 + 7) & 0x7FFFFF)[..., None],
                            ((keys * 17 + 3) & 0x7FFFFF)[..., None]],
                           axis=-1).astype(jnp.int32)


class _ArmStream:
    """Mixed insert/find stream on one arm — the fault-free instance is
    the oracle (cross-arm conformance is pinned by the test suite)."""

    def __init__(self, nranks: int, arm: str, nslots: int = 256):
        self.ht = ht_mod.make_hashtable(nranks, nslots, 2)
        self.auto = ad_mod.AdaptiveEngine(nranks,
                                          am_engine=am_mod.AMEngine(nranks),
                                          policy="round_robin")
        if arm != "auto":
            self.auto.policy = "cost"
            self.auto.force_arm = arm

    def step(self, keys):
        self.ht, ok, _ = self.auto.ht_insert(self.ht, keys, _val_of(keys))
        self.ht, found, vals = self.auto.ht_find(self.ht, keys)
        return np.asarray(ok), np.asarray(found), np.asarray(vals)


def _distinct_batches(nranks: int, nbatches: int, n: int, seed: int):
    rng = np.random.default_rng(seed)
    flat = rng.choice(np.arange(1, 1 << 20), size=nbatches * nranks * n,
                      replace=False)
    return [jnp.asarray(flat[i * nranks * n:(i + 1) * nranks * n]
                        .reshape(nranks, n), jnp.int32)
            for i in range(nbatches)]


def _run_schedule(nranks: int, arm: str, plan, batches):
    """(conformant, wall_us_per_batch): replay `batches` under `plan`
    next to a fault-free oracle and compare every visible output."""
    oracle, chaos = _ArmStream(nranks, arm), _ArmStream(nranks, arm)
    plan.reset()
    conformant = True
    t0 = time.perf_counter()
    for keys in batches:
        o = oracle.step(keys)
        with flt.fault_scope(plan):
            c = chaos.step(keys)
        conformant &= all(np.array_equal(a, b) for a, b in zip(o, c))
    wall = (time.perf_counter() - t0) * 1e6 / max(1, len(batches))
    return conformant, wall


def _fault_env(loss_rates, dead_owner):
    env = os.environ.get("REPRO_FAULT_LOSS")
    if env:
        loss_rates = tuple(float(x) for x in env.split(","))
    if "--loss-rate" in sys.argv:
        loss_rates = (float(sys.argv[sys.argv.index("--loss-rate") + 1]),)
    env = os.environ.get("REPRO_FAULT_DEAD")
    if env is not None:
        dead_owner = int(env)
    if "--dead-owner" in sys.argv:
        dead_owner = int(sys.argv[sys.argv.index("--dead-owner") + 1])
    return loss_rates, dead_owner


def fault_sweep(nranks: int = 8, nbatches: int = 4, n: int = 8,
                loss_rates=(0.05, 0.2, 0.4), dead_owner=None,
                seed: int = 7, out: str = "artifacts/bench"):
    """Per-loss-rate fault sweep: conformance plus the plane's
    deterministic retransmit counters (pure functions of the seed, so
    the trajectory gate sees run-to-run-stable numbers, unlike wall
    time, which is reported but not filed)."""
    report = {"schema": "bench-faults-v1", "P": nranks,
              "dead_owner": dead_owner, "seed": seed, "sweep": {}}
    batches = _distinct_batches(nranks, nbatches, n, seed)
    for lr in loss_rates:
        dead = {int(dead_owner): None} if dead_owner is not None else None
        row = {"drop_rate": lr, "dup_rate": lr / 2,
               "nonconformant_arms": 0}
        for arm in _FAULT_ARMS:
            plan = flt.FaultPlan(nranks, seed=seed, drop_rate=lr,
                                 dup_rate=lr / 2, dead_owners=dead)
            okc, wall = _run_schedule(nranks, arm, plan, batches)
            s = plan.stats()
            row[f"wall_us_{arm}"] = round(wall, 1)
            row["nonconformant_arms"] += 0 if okc else 1
            if arm == "rdma":     # plane counters: same plan per arm
                row.update(retransmits=s["dropped"],
                           dup_redeliveries=s["dup_filtered"],
                           backoff_units=round(s["backoff_total"], 2),
                           exhausted=s["exhausted"])
        report["sweep"][f"{lr:g}"] = row
        print(f"# faults loss={lr:g}: retransmits={row['retransmits']} "
              f"dups={row['dup_redeliveries']} "
              f"nonconformant={row['nonconformant_arms']}")
    os.makedirs(out, exist_ok=True)
    with open(f"{out}/BENCH_faults.json", "w") as f:
        json.dump(stamp_label(report), f, indent=2)
    return report


def smoke_chaos() -> bool:
    """CI chaos gate: a seeded soak — drops + duplicates + one
    permanently dead owner at P=8 — must stay conformant with the
    fault-free oracle on every arm, the plane must never exhaust a row
    (exactly-once holds inside the retry budget), and a permanently
    stalled deferred queue must fail fast with RemoteTimeout instead of
    hanging past the retry deadline."""
    nranks, ok = 8, True
    batches = _distinct_batches(nranks, nbatches=3, n=8, seed=11)
    for arm in _FAULT_ARMS:
        plan = flt.FaultPlan(nranks, seed=17, drop_rate=0.25,
                             dup_rate=0.30, dead_owners={2: None})
        conf, wall = _run_schedule(nranks, arm, plan, batches)
        s = plan.stats()
        arm_ok = conf and s["exhausted"] == 0
        ok &= arm_ok
        print(f"# chaos {arm:10s}: conformant={conf} "
              f"exhausted={s['exhausted']} ({wall:.0f} us/batch) "
              f"({'OK' if arm_ok else 'FAIL'})")
    # liveness: dead queue -> typed timeout inside the deadline ceiling
    plan = flt.FaultPlan(nranks, seed=17, stall_forever=True,
                         retry=flt.RetryPolicy(deadline=8))
    plan.reset()
    eng = am_mod.AMEngine(nranks)
    ht = ht_mod.make_hashtable(nranks, 256, 2)
    keys = batches[0]
    t0 = time.perf_counter()
    try:
        with flt.fault_scope(plan):
            pipe = pl_mod.Pipeline(ht, depth=2, am_engine=eng)

            def op(state, k=keys):
                st, okk, _ = ht_mod.insert_rdma(st, k, _val_of(k))
                return st, okk

            h = pipe.submit(op, deferred=True)
            h.result(timeout=8)
        timed_out = False
    except flt.RemoteTimeout:
        timed_out = True
    dt = time.perf_counter() - t0
    ok &= timed_out and dt < 60.0
    print(f"# chaos dead-queue: RemoteTimeout={timed_out} in {dt:.1f}s "
          f"({'OK' if timed_out else 'FAIL'})")
    return ok


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        sys.exit(0 if smoke() else 1)
    if "--smoke-chaos" in sys.argv:
        sys.exit(0 if smoke_chaos() else 1)
    if "--faults" in sys.argv:
        rates, dead = _fault_env((0.05, 0.2, 0.4), None)
        fault_sweep(loss_rates=rates, dead_owner=dead)
        sys.exit(0)
    main()
