"""Serving example: batched greedy decoding with ring-buffer / recurrent
caches across three architecture families.

  PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch import serve

for arch in ("smollm-135m", "recurrentgemma-9b", "xlstm-1.3b"):
    print(f"\n--- {arch} (reduced) ---")
    serve.main(["--arch", arch, "--reduced", "--batch", "2",
                "--prompt-len", "6", "--gen-len", "10"])
