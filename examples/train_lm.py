"""End-to-end training driver (deliverable (b)): trains an LM with the
full production loop — deterministic pipeline, async checkpointing,
straggler monitor, restart-from-latest.

Default runs a reduced smollm on CPU in ~1 minute. `--full` trains the
real smollm-135m config (the assignment's ~100M-param arch) — on a TPU
pod that is the production invocation; on this 1-core CPU container it
compiles and steps, just slowly.

  PYTHONPATH=src python examples/train_lm.py
  PYTHONPATH=src python examples/train_lm.py --full --steps 300
"""
import argparse
import sys

from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    argv = ["--arch", "smollm-135m", "--steps", str(args.steps),
            "--ckpt", args.ckpt, "--ckpt-every", "50",
            "--lr", "3e-3", "--log-every", "10"]
    if args.full:
        argv += ["--batch", "2", "--seq", "256"]
    else:
        argv += ["--reduced", "--batch", "16", "--seq", "64"]
    losses = train.main(argv)
    assert losses[-1] < losses[0], "training must reduce loss"
    print(f"OK: loss {losses[0]:.3f} -> {losses[-1]:.3f} over "
          f"{args.steps} steps")


if __name__ == "__main__":
    main()
