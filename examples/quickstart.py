"""Quickstart: the paper's two data structures under both implementation
styles, the cost model choosing between them, and the adaptive AUTO
backend choosing per batch at runtime (DESIGN.md §4).

  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import am, costmodel as cm, hashtable as ht, queue as dq
from repro.core.adaptive import AdaptiveEngine
from repro.core.types import Backend, OpStats, Promise

P = 8  # virtual ranks

# --- distributed hash table ------------------------------------------------
table = ht.make_hashtable(P, nslots=128, val_words=1)
keys = jnp.arange(P * 4, dtype=jnp.int32).reshape(P, 4) + 1
vals = (keys * 10)[..., None]

# RDMA style: CAS (claim) + PUT (write) + FAO (publish) — 3 network phases
table, ok, probes = ht.insert_rdma(table, keys, vals, promise=Promise.CRW)
print(f"[rdma] fully-atomic insert: ok={bool(ok.all())} "
      f"max_probes={int(probes.max())} (cost model: "
      f"{cm.predict(cm.DSOp.HT_INSERT, Promise.CRW, Backend.RDMA):.1f} us "
      f"on Cori Aries)")

# RPC style: one active-message round trip, probing runs in the handler
# (the reply carries the handler's real probe count)
engine = am.AMEngine(P)
table2 = ht.make_hashtable(P, nslots=128, val_words=1)
ht.build_am_handlers(table2, engine)
table2, ok2, probes2 = ht.insert_rpc(table2, engine, keys, vals)
found, got = ht.find_rpc(table2, engine, keys)
print(f"[rpc ] insert+find: ok={bool(ok2.all() and found.all())} "
      f"(cost model: {cm.predict(cm.DSOp.HT_INSERT, Promise.CRW, Backend.RPC):.1f} us)")

# --- hosted queue ------------------------------------------------------------
q = dq.make_queue(P, host=0, capacity=256, val_words=1)
q, okq = dq.push_rdma(q, keys[..., None], promise=Promise.CW)
q, gotq, outq = dq.pop_rdma(q, 4, promise=Promise.CR)
print(f"[rdma] phasal queue push/pop: pushed={int(okq.sum())} "
      f"popped={int(gotq.sum())}")

# --- backend="auto": the adaptive layer picks the arm per batch -------------
# The default front-end backend IS auto; passing an AdaptiveEngine with
# measure=True also feeds the chooser's latency EWMAs, and its decision log
# records which arm each batch took (and the batch's owner-load skew).
engine3 = am.AMEngine(P)
chooser = AdaptiveEngine(P, am_engine=engine3, measure=True)
table3 = ht.make_hashtable(P, nslots=128, val_words=1)
table3, ok3, _ = ht.insert(table3, keys, vals, adaptive=chooser)
table3, found3, _ = ht.find(table3, keys, adaptive=chooser)
for d in chooser.log:
    print(f"[auto ] {d.op.value}: arm={d.arm} skew={d.skew:.2f} "
          f"scores={{{', '.join(f'{a}: {s:.1f}' for a, s in d.scores.items())}}}")
print(f"[auto ] insert+find ok={bool(ok3.all() and found3.all())}")

# --- the paper's punchline: the model picks the winner per workload ---------
for busy in (0.0, 1.0, 4.0, 16.0):
    b = cm.choose_backend(cm.DSOp.HT_INSERT, Promise.CRW,
                          OpStats(target_busy_us=busy))
    print(f"[model] insert with target busy {busy:4.1f}us -> {b.value}")

# MoE dispatch as a data-structure op (DESIGN.md §3): ship tokens (RPC)
# vs pull expert weights (RDMA)
for tokens in (64, 4096, 262144):
    b = cm.choose_moe_backend(tokens_per_rank=tokens, d_model=2048,
                              expert_bytes_per_rank=3 * 64 * 2048 * 1408 * 2)
    print(f"[model] MoE dispatch at {tokens:7d} tokens/rank -> {b.value}")
