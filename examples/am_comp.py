"""The paper's am-comp benchmark suite (BCL examples/benchmarks/am-comp),
reduced sizes: component latencies, queue pushes, hash-table ops,
attentiveness — measured on the phase engine vs the analytical model.

  PYTHONPATH=src python examples/am_comp.py
"""
from benchmarks import attentiveness, components, hashtable_bench, queue_bench

print("=== components (Fig. 3) ===")
rows = components.bench_components(P=4, n=32, iters=5)
for op, us in rows.items():
    print(f"  {op:16s} {us:8.2f} us/op")

print("=== queue push (Fig. 4) ===")
q = queue_bench.bench_queue(P=4, n=16, iters=5)
for impl, us in q.items():
    print(f"  {impl:24s} {us:8.2f} us/op")

print("=== hash table (Fig. 5) ===")
h = hashtable_bench.bench_hashtable(P=4, n=16, iters=5)
for impl, us in h.items():
    print(f"  {impl:18s} {us:8.2f} us/op")

print("=== attentiveness (Fig. 6) ===")
for busy, med in attentiveness.bench_attentiveness(
        P=2, n=8, rounds=8, busy_list=(0, 4, 16)):
    print(f"  busy={busy:3d}us  am={med['am']:7.2f}  "
          f"am_pt={med['am_pt']:7.2f}  rdma={med['rdma']:7.2f}")
