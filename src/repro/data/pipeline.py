"""Deterministic synthetic data pipeline fed through the paper's DQueue.

Determinism contract: batch(step, host) is a pure function of
(seed, step, host) — elastic restarts and replayed steps are bit-exact,
which the fault-tolerance tests rely on.

The producer/consumer handoff uses a DQueue at the paper's *phasal*
promise levels: the producer pushes work descriptors under C_W, a barrier
(the end of the SPMD step) separates phases, and consumers pop under C_R —
exactly the barrier-separated usage BCL's cheap queue variants assume
(paper §III-B2). On a real deployment the queue is host-resident and the
descriptors point at prefetched device buffers.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig, ShapeSpec
from ..core import queue as dqueue
from ..core.types import Promise


@dataclass
class SyntheticLM:
    """Markov-ish synthetic LM data: learnable (low-entropy) but non-trivial.

    tokens[t+1] = (a * tokens[t] + drift + noise) % vocab with per-sequence
    drift — a tiny model can reduce loss quickly, which the integration
    test asserts.
    """

    vocab: int
    seq_len: int
    seed: int = 0

    def batch(self, step: int, host: int, batch_size: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, host]))
        B, S = batch_size, self.seq_len
        a = 3
        drift = rng.integers(0, 7, (B, 1))
        t0 = rng.integers(0, self.vocab, (B, 1))
        toks = np.zeros((B, S), np.int64)
        toks[:, :1] = t0
        noise = (rng.random((B, S)) < 0.05) * rng.integers(
            0, self.vocab, (B, S))
        for t in range(1, S):
            toks[:, t] = (a * toks[:, t - 1] + drift[:, 0]) % self.vocab
        toks = np.where(noise > 0, noise, toks)
        return toks.astype(np.int32)

    def train_batch(self, cfg: ArchConfig, shape: ShapeSpec, step: int,
                    host: int = 0) -> Dict[str, jax.Array]:
        B = shape.global_batch
        A = shape.grad_accum
        toks = self.batch(step, host, B).reshape(A, B // A, shape.seq_len)
        out = {"tokens": jnp.asarray(toks)}
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, host, 7]))
        if cfg.family == "encdec":
            out["frames"] = jnp.asarray(rng.normal(
                0, 1, (A, B // A, shape.seq_len, cfg.d_model)),
                cfg.compute_dtype)
        if cfg.family == "vlm":
            st = shape.seq_len - cfg.n_patch_tokens
            out["tokens"] = out["tokens"][..., :st]
            out["patch_embeds"] = jnp.asarray(rng.normal(
                0, 1, (A, B // A, cfg.n_patch_tokens, cfg.d_model)),
                cfg.compute_dtype)
        return out


class QueuedPipeline:
    """Producer/consumer over a DQueue of work descriptors
    [step | host | shard]. Phasal promises per the paper: pushes (C_W) and
    pops (C_R) are separated by the step barrier."""

    def __init__(self, nranks: int, host: int = 0, capacity: int = 1024):
        self.q = dqueue.make_queue(nranks, host=host, capacity=capacity,
                                   val_words=3)
        self.nranks = nranks

    def produce(self, steps, hosts_per_step: int):
        """Push descriptors for a window of steps (one producer rank)."""
        descs = np.array([[s, h, s * hosts_per_step + h]
                          for s in steps for h in range(hosts_per_step)],
                         np.int32)
        P = self.nranks
        per = -(-len(descs) // P)
        pad = np.zeros((per * P - len(descs), 3), np.int32)
        vals = jnp.asarray(np.concatenate([descs, pad]).reshape(P, per, 3))
        valid = jnp.arange(per * P).reshape(P, per) < len(descs)
        self.q, ok = dqueue.push(self.q, vals, promise=Promise.CW,
                                 valid=valid)
        return ok

    def consume(self, n_per_rank: int):
        """Pop up to n descriptors per rank (C_R phase)."""
        self.q, got, vals = dqueue.pop(self.q, n_per_rank,
                                       promise=Promise.CR)
        return got, vals
