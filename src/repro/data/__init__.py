from .pipeline import SyntheticLM, QueuedPipeline
