"""rg_lru — blocked gated linear recurrence h_t = a_t * h_{t-1} + b_t.

The sequence-mixing hot spot of recurrentgemma (RG-LRU) and the mLSTM/sLSTM
cell updates reduce to this elementwise first-order recurrence. The TPU
adaptation: the recurrence is sequential in S but embarrassingly parallel
in (B, D), so we tile D onto the 128-lane VPU and walk S in VMEM-resident
chunks with the carry h in scratch — grid (B, nd, ns) with the s axis
minor-most. HBM traffic is exactly one read of (a, b) and one write of h:
memory-bound by construction, which the roofline analysis confirms.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rg_lru_kernel(a_ref, b_ref, h0_ref, h_ref, carry_ref, *, bs):
    isq = pl.program_id(2)

    @pl.when(isq == 0)
    def _init():
        carry_ref[...] = h0_ref[0][None].astype(jnp.float32)  # (1, bd)

    a = a_ref[0].astype(jnp.float32)                     # (bs, bd)
    b = b_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + b[t]
        h_ref[0, t] = h.astype(h_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, bs, step, carry_ref[...][0])
    carry_ref[...] = h[None]


@functools.partial(jax.jit, static_argnames=("block_s", "block_d",
                                             "interpret"))
def rg_lru_scan(a: jax.Array, b: jax.Array, h0: jax.Array | None = None,
                *, block_s: int = 256, block_d: int = 128,
                interpret: bool = True) -> jax.Array:
    """a, b (B, S, D); h0 (B, D) -> h (B, S, D) with
    h_t = a_t * h_{t-1} + b_t (h_{-1} = h0)."""
    B, S, D = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, D), a.dtype)
    bs, bd = min(block_s, S), min(block_d, D)
    ns, nd = pl.cdiv(S, bs), pl.cdiv(D, bd)
    kern = functools.partial(_rg_lru_kernel, bs=bs)
    return pl.pallas_call(
        kern,
        grid=(B, nd, ns),
        in_specs=[
            pl.BlockSpec((1, bs, bd), lambda ib, id_, is_: (ib, is_, id_)),
            pl.BlockSpec((1, bs, bd), lambda ib, id_, is_: (ib, is_, id_)),
            pl.BlockSpec((1, bd), lambda ib, id_, is_: (ib, id_)),
        ],
        out_specs=pl.BlockSpec((1, bs, bd),
                               lambda ib, id_, is_: (ib, is_, id_)),
        out_shape=jax.ShapeDtypeStruct((B, S, D), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, bd), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
