"""flash_attention — tiled causal/local-window GQA attention (forward).

TPU-native flash attention: grid (B, H, nq, nk) with the kv axis iterated
minor-most so the (acc, m, l) running-softmax state lives in VMEM scratch
across kv steps. Q/K/V tiles are VMEM blocks; the MXU sees (bq, d) x (d, bk)
and (bq, bk) x (bk, d) matmuls with bq/bk multiples of the 128 MXU edge.

Causality and local windows are handled by masking inside the tile and by
*skipping whole kv tiles* outside [q_lo - window, q_hi] via @pl.when — the
same round-trip-elision idea the paper applies to data structures: do not
pay for phases you can prove you don't need.

GQA: kv head index = q head // (H // Hkv), folded into the BlockSpec index
map (no repeated KV in HBM — the repeat is free through block indexing).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                  *, scale, causal, window, bq, bk, nk, seq_kv):
    iq, ik = pl.program_id(2), pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_lo = iq * bq
    k_lo = ik * bk
    # Tile-level skip: no work if this kv tile is entirely masked out.
    live = jnp.bool_(True)
    if causal:
        live &= k_lo <= q_lo + bq - 1
    if window > 0:
        live &= k_lo + bk - 1 > q_lo - window

    @pl.when(live)
    def _tile():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)            # (bk, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < seq_kv
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_cur)
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q (B, H, S, d); k/v (B, Hkv, Skv, d) -> (B, H, S, d).

    Queries are aligned to the *end* of the kv sequence (prefill: S == Skv).
    """
    B, H, S, d = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    g = H // Hkv
    bq, bk = min(block_q, S), min(block_k, Skv)
    nq, nk = pl.cdiv(S, bq), pl.cdiv(Skv, bk)
    scale = d ** -0.5
    kern = functools.partial(_flash_kernel, scale=scale, causal=causal,
                             window=window, bq=bq, bk=bk, nk=nk, seq_kv=Skv)
    return pl.pallas_call(
        kern,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b, h, i, j, g=g: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b, h, i, j, g=g: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * bq, d), q.dtype),
        scratch_shapes=[
            # (bq, d) f32 accumulator + (bq, 1) running max / sum in VMEM
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)[:, :, :S]
