# Pallas TPU kernels for the compute hot spots (DESIGN.md §3), each with a
# pure-jnp oracle in ref.py and a jit'd dispatcher in ops.py:
#   amo_apply    — serialized AMO batch at the owner («the NIC lane»)
#   hash_probe   — open-addressing probe loops («the AM handler body»)
#   flash_attention / flash_decode — attention hot paths (+ (o,m,l) partials
#                  for the RPC-style distributed decode)
#   moe_dispatch — vectorized FAA-ticket position assignment
#   rg_lru       — gated linear recurrence (recurrentgemma / xLSTM cells)
from . import ops, ref
