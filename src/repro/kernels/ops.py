"""ops — the jit'd public entry points for the kernel layer.

Every op dispatches between the Pallas kernel (TPU hot path; validated on
CPU via interpret=True) and the pure-jnp reference (`ref.py`), controlled
by `use_pallas`. The model/launch layers call these; the dry-run compiles
the jnp path (Pallas does not lower on the CPU backend), which is
numerically identical — the kernels are the *performance* realization.
"""
from __future__ import annotations

import os
from typing import Tuple

import jax
import jax.numpy as jnp

from . import amo_apply as _amo
from . import flash_attention as _fa
from . import flash_decode as _fd
from . import hash_probe as _hp
from . import moe_dispatch as _md
from . import ref
from . import rg_lru as _rg

Array = jax.Array

# Default backend: Pallas-in-interpret on CPU iff explicitly requested.
_USE_PALLAS = os.environ.get("REPRO_USE_PALLAS", "0") == "1"


def use_pallas_default() -> bool:
    return _USE_PALLAS


def _pick(flag):
    return _USE_PALLAS if flag is None else flag


def amo_apply(local: Array, ops: Array, mask: Array,
              use_pallas: bool | None = None,
              combine_runs: bool = False) -> Tuple[Array, Array]:
    """combine_runs=True merges consecutive duplicate runs in each owner's
    serialized op list before the lane walks it (operand folds / last
    writer / identical-row CAS — kernels/amo_apply.combine_runs) and
    reconstructs per-op old values after — bit-identical output, shorter
    effective serial chain at the owner (DESIGN.md §6)."""
    if combine_runs:
        ops2, mask2, run_start, prefix = jax.vmap(_amo.combine_runs)(ops,
                                                                     mask)
        old_rep, local2 = amo_apply(local, ops2, mask2,
                                    use_pallas=use_pallas)
        old = jax.vmap(_amo.reconstruct_runs)(ops, mask, run_start,
                                              prefix, old_rep)
        return old, local2
    if _pick(use_pallas):
        return _amo.amo_apply(local, ops, mask)
    return jax.vmap(ref.amo_apply)(local, ops, mask)


def fused_apply(local: Array, ops: Array, mask: Array, *, reply_width: int,
                use_pallas: bool | None = None) -> Tuple[Array, Array]:
    """Owner-lane apply for fused component descriptors (DESIGN.md §2).
    local (P, L); ops (P, m, 6+V); mask (P, m). Returns
    (reply (P, m, reply_width), local'). The XLA lane is the sequential
    oracle vmapped over owners; the Pallas lane is the VMEM-resident hot
    path — bit-identical by contract (tests/test_kernels.py)."""
    if _pick(use_pallas):
        return _amo.fused_apply(local, ops, mask, reply_width=reply_width)
    return jax.vmap(
        lambda l, o, m: ref.fused_apply(l, o, m, reply_width=reply_width)
    )(local, ops, mask)


def hash_find(table, starts, keys, mask, *, nslots, rec_w, max_probes=8,
              use_pallas: bool | None = None):
    if _pick(use_pallas):
        return _hp.hash_find(table, starts, keys, mask, nslots=nslots,
                             rec_w=rec_w, max_probes=max_probes)
    return jax.vmap(lambda t, s, k, m: ref.hash_find(
        t, s, k, m, nslots, rec_w, max_probes))(table, starts, keys, mask)


def hash_insert(table, starts, keys, vals, mask, *, nslots, rec_w,
                max_probes=8, use_pallas: bool | None = None):
    """Returns (ok (P, m), probes (P, m), table') — probes is the number of
    slots the handler examined, comparable with the RDMA CAS-probe count."""
    if _pick(use_pallas):
        return _hp.hash_insert(table, starts, keys, vals, mask,
                               nslots=nslots, rec_w=rec_w,
                               max_probes=max_probes)
    return jax.vmap(lambda t, s, k, v, m: ref.hash_insert(
        t, s, k, v, m, nslots, rec_w, max_probes))(table, starts, keys,
                                                   vals, mask)


def flash_attention(q, k, v, *, causal=True, window=0,
                    use_pallas: bool | None = None,
                    block_q: int = 128, block_k: int = 128):
    if _pick(use_pallas):
        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   block_q=block_q, block_k=block_k)
    return ref.mha(q, k, v, causal=causal, window=window)


def flash_decode(q, k, v, length, *, use_pallas: bool | None = None,
                 block_k: int = 256):
    if _pick(use_pallas):
        return _fd.flash_decode(q, k, v, length, block_k=block_k)
    return ref.decode_attention(q, k, v, length)


combine_decode_stats = ref.combine_decode_stats


def moe_dispatch(expert_ids, *, n_experts, use_pallas: bool | None = None,
                 block_t: int = 256):
    if _pick(use_pallas):
        return _md.moe_dispatch(expert_ids, n_experts=n_experts,
                                block_t=block_t)
    return ref.moe_dispatch(expert_ids, n_experts)


def rg_lru_scan(a, b, h0=None, *, use_pallas: bool | None = None,
                block_s: int = 256, block_d: int = 128):
    if _pick(use_pallas):
        return _rg.rg_lru_scan(a, b, h0, block_s=block_s, block_d=block_d)
    return ref.rg_lru_scan(a, b, h0)
