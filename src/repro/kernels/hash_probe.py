"""hash_probe — the RPC hash-table handler's hot path as a Pallas kernel.

Two kernels:

- `hash_find`: embarrassingly parallel probe loop, vectorized over a tile
  of `bm` requests; the local table stays resident in VMEM across the whole
  request batch (one HBM read), each probe is a VMEM gather — exactly the
  "expressive control flow at the target, zero extra network phases"
  property the paper attributes to RPC handlers.
- `hash_insert`: sequential over requests within an owner (insert-or-assign
  must observe earlier inserts in the same batch — same serialization
  argument as amo_apply), but each record read/write is a vectorized
  rec_w-word VMEM slice.

Layout: table (P, L) int32, nslots records of rec_w = 2 + vw words
[flag | key | val...] per rank; flag low byte 0=EMPTY, 2=READY.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _find_kernel(table_ref, starts_ref, keys_ref, mask_ref,
                 found_ref, vals_ref, *, nslots, rec_w, max_probes):
    # table (1, L); starts/keys/mask (1, bm); found (1, bm); vals (1, bm, vw)
    table = table_ref[0]
    starts = starts_ref[0]
    keys = keys_ref[0]
    bm = starts.shape[0]
    vw = rec_w - 2

    def probe(j, carry):
        found, vals, stop = carry
        slot = (starts + j) % nslots
        base = slot * rec_w
        idx = base[:, None] + jnp.arange(rec_w)[None, :]   # (bm, rec_w)
        rec = jnp.take(table, idx.reshape(-1), axis=0,
                       mode="clip").reshape(bm, rec_w)
        state = rec[:, 0] & 255
        hit = (~stop) & (state == 2) & (rec[:, 1] == keys)
        empty = (~stop) & (state == 0)
        vals = jnp.where(hit[:, None], rec[:, 2:], vals)
        return found | hit, vals, stop | hit | empty

    found0 = jnp.zeros((bm,), jnp.bool_)
    vals0 = jnp.zeros((bm, vw), jnp.int32)
    found, vals, _ = jax.lax.fori_loop(0, max_probes, probe,
                                       (found0, vals0, found0))
    ok = mask_ref[0] != 0
    found_ref[0] = (found & ok).astype(jnp.int32)
    vals_ref[0] = jnp.where((found & ok)[:, None], vals, 0)


@functools.partial(jax.jit, static_argnames=("nslots", "rec_w", "max_probes",
                                             "block_m", "interpret"))
def hash_find(table: jax.Array, starts: jax.Array, keys: jax.Array,
              mask: jax.Array, *, nslots: int, rec_w: int,
              max_probes: int = 8, block_m: int = 128,
              interpret: bool = True):
    """Vectorized batched find. table (P, L); starts/keys/mask (P, m).
    Returns (found (P, m) bool, vals (P, m, rec_w-2))."""
    P, L = table.shape
    m = starts.shape[1]
    bm = min(block_m, m)
    grid_m = pl.cdiv(m, bm)
    vw = rec_w - 2
    kern = functools.partial(_find_kernel, nslots=nslots, rec_w=rec_w,
                             max_probes=max_probes)
    found, vals = pl.pallas_call(
        kern,
        grid=(P, grid_m),
        in_specs=[
            pl.BlockSpec((1, L), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bm), lambda i, j: (i, j)),
            pl.BlockSpec((1, bm), lambda i, j: (i, j)),
            pl.BlockSpec((1, bm), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, bm), lambda i, j: (i, j)),
            pl.BlockSpec((1, bm, vw), lambda i, j: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((P, grid_m * bm), jnp.int32),
            jax.ShapeDtypeStruct((P, grid_m * bm, vw), jnp.int32),
        ],
        interpret=interpret,
    )(table, _pad(starts, grid_m * bm), _pad(keys, grid_m * bm),
      _pad(mask.astype(jnp.int32), grid_m * bm))
    return found[:, :m] != 0, vals[:, :m]


def _pad(x: jax.Array, to: int) -> jax.Array:
    if x.shape[1] == to:
        return x
    pad = [(0, 0), (0, to - x.shape[1])] + [(0, 0)] * (x.ndim - 2)
    return jnp.pad(x, pad)


def _insert_kernel(table_ref, starts_ref, keys_ref, vals_ref, mask_ref,
                   ok_ref, probes_ref, out_ref, *, nslots, rec_w,
                   max_probes):
    # sequential insert-or-assign over the owner's request list. All
    # pl.load/pl.store indices are pl.ds slices (bare scalar ints break
    # interpret-mode state discharge).
    out_ref[...] = table_ref[...]
    m = starts_ref.shape[1]
    vw = rec_w - 2

    def body(j, _):
        start = starts_ref[0, j]
        key = keys_ref[0, j]
        ok = mask_ref[0, j] != 0

        def probe(p, carry):
            slot, kind, probes = carry  # kind: 0 searching, 1 hit, 2 empty
            s = (start + p) % nslots
            rec = pl.load(out_ref, (pl.ds(0, 1), pl.ds(s * rec_w, 2)))[0]
            state = rec[0] & 255
            searching = kind == 0
            hit = searching & (state == 2) & (rec[1] == key)
            empty = searching & (state == 0)
            slot = jnp.where(hit | empty, s, slot)
            kind = jnp.where(hit, 1, jnp.where(empty, 2, kind))
            probes = probes + searching.astype(jnp.int32)
            return slot, kind, probes

        slot, kind, probes = jax.lax.fori_loop(
            0, max_probes, probe, (jnp.int32(-1), jnp.int32(0),
                                   jnp.int32(0)))
        can = ok & (kind > 0)
        base = jnp.where(can, slot * rec_w, 0)
        cur = pl.load(out_ref, (pl.ds(0, 1), pl.ds(base, rec_w)))[0]
        val = pl.load(vals_ref, (pl.ds(0, 1), pl.ds(j, 1),
                                 pl.ds(0, vw)))[0, 0]
        rec = jnp.concatenate([jnp.full((1,), 2, jnp.int32), key[None], val])
        pl.store(out_ref, (pl.ds(0, 1), pl.ds(base, rec_w)),
                 jnp.where(can, rec, cur)[None])
        pl.store(ok_ref, (pl.ds(0, 1), pl.ds(j, 1)),
                 can.astype(jnp.int32)[None, None])
        pl.store(probes_ref, (pl.ds(0, 1), pl.ds(j, 1)),
                 jnp.where(ok, probes, 0)[None, None])
        return 0

    jax.lax.fori_loop(0, m, body, 0)


@functools.partial(jax.jit, static_argnames=("nslots", "rec_w", "max_probes",
                                             "interpret"))
def hash_insert(table: jax.Array, starts: jax.Array, keys: jax.Array,
                vals: jax.Array, mask: jax.Array, *, nslots: int,
                rec_w: int, max_probes: int = 8, interpret: bool = True):
    """Serialized batched insert-or-assign. vals (P, m, rec_w-2).
    Returns (ok (P, m) bool, probes (P, m) int32, table')."""
    P, L = table.shape
    m = starts.shape[1]
    vw = rec_w - 2
    kern = functools.partial(_insert_kernel, nslots=nslots, rec_w=rec_w,
                             max_probes=max_probes)
    ok, probes, new_table = pl.pallas_call(
        kern,
        grid=(P,),
        in_specs=[
            pl.BlockSpec((1, L), lambda i: (i, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
            pl.BlockSpec((1, m, vw), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, m), lambda i: (i, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
            pl.BlockSpec((1, L), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((P, m), jnp.int32),
            jax.ShapeDtypeStruct((P, m), jnp.int32),
            jax.ShapeDtypeStruct((P, L), jnp.int32),
        ],
        interpret=interpret,
    )(table, starts, keys, vals, mask.astype(jnp.int32))
    return ok != 0, probes, new_table
