"""flash_decode — single-token GQA decode attention over a (possibly
sharded/paged) KV cache, returning flash partials (o, m, l).

This is the kernel half of the paper's RPC-style distributed decode
(DESIGN.md §3): each KV shard runs this kernel over its *local* cache slice
and replies with (o, m, l) — constant-size stats instead of the cache
itself — and the query owner combines them associatively
(`ref.combine_decode_stats`). The RDMA-style alternative gathers KV pages
to the query owner and runs the same kernel locally; the cost model picks
per cache length.

Grid: (B, Hkv, nk) — kv tiles iterated minor-most with running-softmax
scratch carried across tiles. All q heads of one kv group are processed
together as a (g, d) block so the MXU contraction is (g, d) x (d, bk).
Returns *unnormalized* numerator o plus (m, l), so partials combine across
shards without renormalization error.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, mx_ref, sm_ref, *, scale, bk, nk):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        mx_ref[...] = jnp.full_like(mx_ref, NEG_INF)
        sm_ref[...] = jnp.zeros_like(sm_ref)

    q = q_ref[0, 0].astype(jnp.float32)                 # (g, d)
    k = k_ref[0, 0].astype(jnp.float32)                 # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)                 # (bk, d)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (g, bk)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    valid = kpos < len_ref[0, 0]
    s = jnp.where(valid, s, NEG_INF)
    m_prev = mx_ref[...]                                 # (g, 1)
    m_cur = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
    p = jnp.where(valid, jnp.exp(s - m_cur), 0.0)
    alpha = jnp.exp(m_prev - m_cur)
    sm_ref[...] = sm_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    mx_ref[...] = m_cur

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0, 0] = acc_ref[...]
        m_ref[0, 0] = mx_ref[...][:, 0]
        l_ref[0, 0] = sm_ref[...][:, 0]


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 length: jax.Array, *, block_k: int = 256,
                 interpret: bool = True):
    """q (B, H, d); k/v (B, Hkv, S, d); length (B,) valid prefix length.

    Returns flash partials (o (B, H, d) f32 unnormalized, m (B, H) f32,
    l (B, H) f32). Final output = o / l after cross-shard combination.
    """
    B, H, d = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    g = H // Hkv
    bk = min(block_k, S)
    nk = pl.cdiv(S, bk)
    scale = d ** -0.5
    qg = q.reshape(B, Hkv, g, d)
    len2 = jnp.broadcast_to(length[:, None], (B, 1)).astype(jnp.int32)
    kern = functools.partial(_decode_kernel, scale=scale, bk=bk, nk=nk)
    o, m, l = pl.pallas_call(
        kern,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, j: (b, 0)),
            pl.BlockSpec((1, 1, g, d), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, h, j: (b, h, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, d), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, g), lambda b, h, j: (b, h, 0)),
            pl.BlockSpec((1, 1, g), lambda b, h, j: (b, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, g, d), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, g), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, g), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
        interpret=interpret,
    )(len2, qg, k, v)
    return o.reshape(B, H, d), m.reshape(B, H), l.reshape(B, H)
