"""amo_apply — the owner shard's "NIC lane": a serialized batch of atomic
memory operations applied against a local window shard.

Paper mapping (DESIGN.md §2): on Cray Aries the target NIC serializes
incoming AMOs against node memory while the CPU computes. TPUs have no NIC
atomics, so the owner executes the batch itself in deterministic
(src_rank, slot) order. This kernel IS that serialization point; its cost
is the `amo_apply` term in the cost model.

Grid: one program per owner row (the P axis); within a program a sequential
fori_loop walks the op list — atomics are *inherently* serial at the memory
controller, so the loop order is the semantics, not a perf bug. The local
window lives in VMEM for the whole batch (one HBM read + one write total),
which is the TPU-native win over per-op HBM round trips.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

OP_PUT, OP_GET, OP_CAS, OP_FAA, OP_FOR, OP_FAND, OP_FXOR = range(7)


def _amo_kernel(local_ref, ops_ref, mask_ref, old_ref, out_ref):
    # local_ref: (1, L) VMEM; ops_ref: (1, m, 4); mask_ref: (1, m)
    out_ref[...] = local_ref[...]
    m = ops_ref.shape[1]

    def body(j, _):
        op = ops_ref[0, j]
        off, code, a, b = op[0], op[1], op[2], op[3]
        ok = mask_ref[0, j] != 0
        safe = jnp.where(ok, off, 0)
        cur = pl.load(out_ref, (0, pl.ds(safe, 1)))[0]
        new = jnp.select(
            [code == OP_PUT, code == OP_GET, code == OP_CAS, code == OP_FAA,
             code == OP_FOR, code == OP_FAND, code == OP_FXOR],
            [b, cur, jnp.where(cur == a, b, cur), cur + a,
             cur | a, cur & a, cur ^ a], cur)
        pl.store(out_ref, (0, pl.ds(safe, 1)),
                 jnp.where(ok, new, cur)[None])
        pl.store(old_ref, (0, pl.ds(j, 1)), jnp.where(ok, cur, 0)[None])
        return 0

    jax.lax.fori_loop(0, m, body, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def amo_apply(local: jax.Array, ops: jax.Array, mask: jax.Array,
              *, interpret: bool = True):
    """Apply serialized AMO batches to each owner's shard.

    local (P, L) int32; ops (P, m, 4) rows [off|opcode|a|b]; mask (P, m).
    Returns (old (P, m), local' (P, L)).
    """
    P, L = local.shape
    m = ops.shape[1]
    old, new_local = pl.pallas_call(
        _amo_kernel,
        grid=(P,),
        in_specs=[
            pl.BlockSpec((1, L), lambda i: (i, 0)),
            pl.BlockSpec((1, m, 4), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, m), lambda i: (i, 0)),
            pl.BlockSpec((1, L), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((P, m), jnp.int32),
            jax.ShapeDtypeStruct((P, L), jnp.int32),
        ],
        interpret=interpret,
    )(local, ops, mask.astype(jnp.int32))
    return old, new_local
