"""amo_apply — the owner shard's "NIC lane": a serialized batch of atomic
memory operations applied against a local window shard.

Paper mapping (DESIGN.md §2): on Cray Aries the target NIC serializes
incoming AMOs against node memory while the CPU computes. TPUs have no NIC
atomics, so the owner executes the batch itself in deterministic
(src_rank, slot) order. This kernel IS that serialization point; its cost
is the `amo_apply` term in the cost model.

Two kernels share the lane:

- `amo_apply`: primitive single-word AMOs [off|opcode|a|b];
- `fused_apply`: fused component descriptors (DESIGN.md §2)
  [off|opcode|a|b|aux0|aux1|vals...] — CAS_PUT / CAS_PUT_PUB / FAO_GET
  compound ops applied in sub-phase order (atomics, compound puts, publish
  flips, phase-end gathers; each serialized), so a claim + record write +
  publish flip arrives in ONE request phase instead of three.

Grid: one program per owner row (the P axis); within a program a sequential
fori_loop walks the op list — atomics are *inherently* serial at the memory
controller, so the loop order is the semantics, not a perf bug. The local
window lives in VMEM for the whole batch (one HBM read + one write total),
which is the TPU-native win over per-op HBM round trips.

Note on indexing: every `pl.load`/`pl.store` index is a `pl.ds` slice —
mixing bare scalar ints into the index tuple breaks interpret-mode state
discharge (`'int' object has no attribute 'shape'`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

OP_PUT, OP_GET, OP_CAS, OP_FAA, OP_FOR, OP_FAND, OP_FXOR = range(7)
OP_CAS_PUT, OP_CAS_PUT_PUB, OP_FAO_GET = 7, 8, 9


# ---------------------------------------------------------------------------
# Duplicate-run combining (DESIGN.md §6), owner-lane side: merge maximal
# CONSECUTIVE runs of combinable ops in the serialized list before the
# sequential lane walks it, and reconstruct per-op old values after. Runs
# are consecutive by construction so no reordering happens — the combined
# list applies exactly the state transitions of the original one.
#
#   FAA             operands sum;     old_i = old_rep + prefix_sum
#   FOR/FAND/FXOR   operands fold;    old_i = binop(old_rep, prefix_fold)
#   GET             one probe;        old_i = old_rep
#   PUT             last writer wins; old_i = prev member's stored value
#   CAS             identical (a, b) rows only; losers see the chained
#                   outcome (rep won -> b, else old_rep)
# ---------------------------------------------------------------------------
def _fao_identity(code):
    return jnp.where(code == OP_FAND, jnp.int32(-1), jnp.int32(0))


def _fao_merge(code, x, y):
    return jnp.select(
        [code == OP_FAA, code == OP_FOR, code == OP_FAND, code == OP_FXOR],
        [x + y, x | y, x & y, x ^ y], y)


def combine_runs(ops, mask):
    """Merge duplicate runs of one shard's serialized op list.

    ops (m, 4) int32 [off|code|a|b]; mask (m,) bool. Returns
    (ops', mask', run_start (m,), prefix (m,)): mask' keeps only run
    representatives, ops' carries the folded operand (FAO) / last value
    (PUT) at each representative row, run_start[i] is the list index of
    op i's representative, prefix[i] the exclusive operand fold of its
    earlier run members."""
    m = ops.shape[0]
    off, code, a, b = ops[:, 0], ops[:, 1], ops[:, 2], ops[:, 3]
    same = (mask[1:] & mask[:-1] & (off[1:] == off[:-1])
            & (code[1:] == code[:-1]))
    is_cas = code == OP_CAS
    same = same & (~is_cas[1:] | ((a[1:] == a[:-1]) & (b[1:] == b[:-1])))
    run_first = jnp.concatenate([jnp.array([True]), ~same])
    idx = jnp.arange(m, dtype=jnp.int32)
    run_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(run_first, idx, -1))

    def comb(x, y):
        xf, xa, _ = x
        yf, ya, yc = y
        return xf | yf, jnp.where(yf, ya, _fao_merge(yc, xa, ya)), yc

    _, incl, _ = jax.lax.associative_scan(comb, (run_first, a, code))
    excl = jnp.where(run_first, _fao_identity(code), jnp.roll(incl, 1))
    run_last = jnp.concatenate([run_first[1:], jnp.array([True])])
    end = jnp.flip(jax.lax.associative_scan(
        jnp.minimum, jnp.flip(jnp.where(run_last, idx, m - 1))))
    is_fao = ((code == OP_FAA) | (code == OP_FOR) | (code == OP_FAND)
              | (code == OP_FXOR))
    a2 = jnp.where(run_first & is_fao, incl[end], a)
    b2 = jnp.where(run_first & (code == OP_PUT), b[end], b)
    ops2 = jnp.stack([off, code, a2, b2], axis=-1)
    mask2 = mask & run_first
    return ops2, mask2, run_start, excl


def reconstruct_runs(ops, mask, run_start, prefix, old_rep):
    """Per-op old values from the representatives' fetched values.

    old_rep (m,) is the combined apply's reply (meaningful at
    representative rows). Returns old (m,) as the uncombined serialized
    apply would have fetched it."""
    m = ops.shape[0]
    code, a, b = ops[:, 1], ops[:, 2], ops[:, 3]
    idx = jnp.arange(m, dtype=jnp.int32)
    pos = idx - run_start
    old_l = old_rep[run_start]
    prev_b = jnp.roll(b, 1)
    fao = _fao_merge(code, old_l, prefix)
    old = jnp.select(
        [code == OP_GET, code == OP_CAS, code == OP_PUT],
        [old_l,
         jnp.where(pos == 0, old_l, jnp.where(old_l == a, b, old_l)),
         jnp.where(pos == 0, old_l, prev_b)],
        fao)
    return jnp.where(mask, old, 0)


def _amo_kernel(local_ref, ops_ref, mask_ref, old_ref, out_ref):
    # local_ref: (1, L) VMEM; ops_ref: (1, m, 4); mask_ref: (1, m)
    out_ref[...] = local_ref[...]
    m = ops_ref.shape[1]

    def body(j, _):
        op = ops_ref[0, j]
        off, code, a, b = op[0], op[1], op[2], op[3]
        ok = mask_ref[0, j] != 0
        safe = jnp.where(ok, off, 0)
        cur = pl.load(out_ref, (pl.ds(0, 1), pl.ds(safe, 1)))[0, 0]
        new = jnp.select(
            [code == OP_PUT, code == OP_GET, code == OP_CAS, code == OP_FAA,
             code == OP_FOR, code == OP_FAND, code == OP_FXOR],
            [b, cur, jnp.where(cur == a, b, cur), cur + a,
             cur | a, cur & a, cur ^ a], cur)
        pl.store(out_ref, (pl.ds(0, 1), pl.ds(safe, 1)),
                 jnp.where(ok, new, cur)[None, None])
        pl.store(old_ref, (pl.ds(0, 1), pl.ds(j, 1)),
                 jnp.where(ok, cur, 0)[None, None])
        return 0

    jax.lax.fori_loop(0, m, body, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def amo_apply(local: jax.Array, ops: jax.Array, mask: jax.Array,
              *, interpret: bool = True):
    """Apply serialized AMO batches to each owner's shard.

    local (P, L) int32; ops (P, m, 4) rows [off|opcode|a|b]; mask (P, m).
    Returns (old (P, m), local' (P, L)).
    """
    P, L = local.shape
    m = ops.shape[1]
    old, new_local = pl.pallas_call(
        _amo_kernel,
        grid=(P,),
        in_specs=[
            pl.BlockSpec((1, L), lambda i: (i, 0)),
            pl.BlockSpec((1, m, 4), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, m), lambda i: (i, 0)),
            pl.BlockSpec((1, L), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((P, m), jnp.int32),
            jax.ShapeDtypeStruct((P, L), jnp.int32),
        ],
        interpret=interpret,
    )(local, ops, mask.astype(jnp.int32))
    return old, new_local


def _fused_kernel(local_ref, ops_ref, mask_ref, reply_ref, out_ref,
                  *, val_words, gather_words):
    # local_ref: (1, L); ops_ref: (1, m, 6 + V); mask_ref: (1, m);
    # reply_ref: (1, m, 1 + G); out_ref: (1, L)
    #
    # Sub-phase decomposed semantics (same spec as ref.fused_apply): one
    # serialized pass per sub-phase — atomics, compound puts, publish
    # flips, phase-end gathers.
    out_ref[...] = local_ref[...]
    m = ops_ref.shape[1]
    L = local_ref.shape[1]
    V, G = val_words, gather_words

    def is_csp(code):
        return (code == OP_CAS_PUT) | (code == OP_CAS_PUT_PUB)

    def atomic_body(j, _):
        op = ops_ref[0, j]
        off, code, a, b = op[0], op[1], op[2], op[3]
        ok = mask_ref[0, j] != 0
        safe = jnp.where(ok, off, 0)
        cur = pl.load(out_ref, (pl.ds(0, 1), pl.ds(safe, 1)))[0, 0]
        win = cur == a
        new = jnp.select(
            [code == OP_PUT, code == OP_GET, code == OP_CAS, code == OP_FAA,
             code == OP_FOR, code == OP_FAND, code == OP_FXOR,
             is_csp(code),
             code == OP_FAO_GET],
            [b, cur, jnp.where(win, b, cur), cur + a,
             cur | a, cur & a, cur ^ a,
             jnp.where(win, b, cur),
             jnp.select([b == OP_FAA, b == OP_FOR, b == OP_FAND,
                         b == OP_FXOR],
                        [cur + a, cur | a, cur & a, cur ^ a], cur)], cur)
        pl.store(out_ref, (pl.ds(0, 1), pl.ds(safe, 1)),
                 jnp.where(ok, new, cur)[None, None])
        pl.store(reply_ref, (pl.ds(0, 1), pl.ds(j, 1), pl.ds(0, 1)),
                 jnp.where(ok, cur, 0)[None, None, None])
        return 0

    jax.lax.fori_loop(0, m, atomic_body, 0)

    def won(j):
        # recompute CAS success from the recorded old value
        op = ops_ref[0, j]
        ok = mask_ref[0, j] != 0
        old = pl.load(reply_ref, (pl.ds(0, 1), pl.ds(j, 1),
                                  pl.ds(0, 1)))[0, 0, 0]
        return ok & (old == op[2])

    if V > 0:
        def put_body(j, _):
            op = ops_ref[0, j]
            aux0 = op[4]
            # compound payloads are dropped whole when out of range
            do = (won(j) & is_csp(op[1])
                  & (aux0 >= 0) & (aux0 <= L - V))
            safe_put = jnp.where(do, aux0, 0)
            cur_v = pl.load(out_ref, (pl.ds(0, 1), pl.ds(safe_put, V)))
            vals = pl.load(ops_ref, (pl.ds(0, 1), pl.ds(j, 1),
                                     pl.ds(6, V)))[0]
            pl.store(out_ref, (pl.ds(0, 1), pl.ds(safe_put, V)),
                     jnp.where(do, vals, cur_v))
            return 0

        jax.lax.fori_loop(0, m, put_body, 0)

    def flip_body(j, _):
        op = ops_ref[0, j]
        do = won(j) & (op[1] == OP_CAS_PUT_PUB)
        safe = jnp.where(do, op[0], 0)
        cur = pl.load(out_ref, (pl.ds(0, 1), pl.ds(safe, 1)))[0, 0]
        pl.store(out_ref, (pl.ds(0, 1), pl.ds(safe, 1)),
                 jnp.where(do, cur ^ op[5], cur)[None, None])
        return 0

    jax.lax.fori_loop(0, m, flip_body, 0)

    if G > 0:
        def gather_body(j, _):
            op = ops_ref[0, j]
            aux0 = op[4]
            ok = mask_ref[0, j] != 0
            is_get = (ok & (op[1] == OP_FAO_GET)
                      & (aux0 >= 0) & (aux0 <= L - G))
            safe_get = jnp.where(is_get, aux0, 0)
            g = pl.load(out_ref, (pl.ds(0, 1), pl.ds(safe_get, G)))
            pl.store(reply_ref, (pl.ds(0, 1), pl.ds(j, 1), pl.ds(1, G)),
                     jnp.where(is_get, g, 0)[:, None, :])
            return 0

        jax.lax.fori_loop(0, m, gather_body, 0)


@functools.partial(jax.jit, static_argnames=("reply_width", "interpret"))
def fused_apply(local: jax.Array, ops: jax.Array, mask: jax.Array,
                *, reply_width: int, interpret: bool = True):
    """Apply serialized fused-descriptor batches to each owner's shard.

    local (P, L) int32; ops (P, m, 6 + V) rows
    [off|opcode|a|b|aux0|aux1|vals...]; mask (P, m).
    Returns (reply (P, m, reply_width), local' (P, L)): reply word 0 is the
    old value at `off`, words 1.. are the FAO_GET gather (zeros otherwise).
    Same contract as kernels/ref.py:fused_apply, validated against it.
    """
    P, L = local.shape
    m, w = ops.shape[1], ops.shape[2]
    V = w - 6
    G = reply_width - 1
    kern = functools.partial(_fused_kernel, val_words=V, gather_words=G)
    reply, new_local = pl.pallas_call(
        kern,
        grid=(P,),
        in_specs=[
            pl.BlockSpec((1, L), lambda i: (i, 0)),
            pl.BlockSpec((1, m, w), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, m), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, m, reply_width), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, L), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((P, m, reply_width), jnp.int32),
            jax.ShapeDtypeStruct((P, L), jnp.int32),
        ],
        interpret=interpret,
    )(local, ops, mask.astype(jnp.int32))
    return reply, new_local
