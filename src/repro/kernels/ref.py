"""Pure-jnp oracles for every Pallas kernel in this package.

These are written independently of the kernel bodies (sequential/naive
semantics, no tiling) and are the ground truth for the per-kernel
shape/dtype sweep tests in tests/test_kernels.py.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

# AMO opcodes — shared integer codes with kernels/amo_apply.py and
# core.types.AmoKind.
OP_PUT, OP_GET, OP_CAS, OP_FAA, OP_FOR, OP_FAND, OP_FXOR = range(7)
# Fused descriptor opcodes (DESIGN.md §2): one request phase, compound apply.
OP_CAS_PUT, OP_CAS_PUT_PUB, OP_FAO_GET = 7, 8, 9


# ---------------------------------------------------------------------------
# amo_apply: serialized batched atomics against one local shard ("NIC lane")
# ---------------------------------------------------------------------------
def amo_apply(local: Array, ops: Array, mask: Array
              ) -> Tuple[Array, Array]:
    """Sequential oracle. local (L,) int32; ops (m, 4) int32 rows
    [off, opcode, a, b]; mask (m,) bool. Returns (old (m,), local').
    Op j observes the state left by ops < j — NIC arrival-order semantics.
    """

    def step(local, x):
        op, ok = x
        off, code, a, b = op[0], op[1], op[2], op[3]
        cur = local[off]
        new = jnp.select(
            [code == OP_PUT, code == OP_GET, code == OP_CAS, code == OP_FAA,
             code == OP_FOR, code == OP_FAND, code == OP_FXOR],
            [b, cur, jnp.where(cur == a, b, cur), cur + a,
             cur | a, cur & a, cur ^ a], cur)
        local = local.at[off].set(jnp.where(ok, new, cur))
        return local, jnp.where(ok, cur, 0)

    local2, old = jax.lax.scan(step, local, (ops, mask))
    return old, local2


def amo_apply_combined(local: Array, ops: Array, mask: Array
                       ) -> Tuple[Array, Array]:
    """Duplicate-run-combined oracle (DESIGN.md §6): merge maximal
    consecutive runs of combinable ops (FAO operand folds, last-writer
    puts, identical-row CAS, shared gets), apply the shortened list with
    the plain sequential oracle, then reconstruct every op's fetched value
    from its representative's reply. Bit-identical to `amo_apply` on the
    full list — the equivalence the duplicate-run tests pin."""
    from . import amo_apply as _amo_mod
    ops2, mask2, run_start, prefix = _amo_mod.combine_runs(ops, mask)
    old_rep, local2 = amo_apply(local, ops2, mask2)
    old = _amo_mod.reconstruct_runs(ops, mask, run_start, prefix, old_rep)
    return old, local2


def _fao(cur: Array, a: Array, code: Array) -> Array:
    return jnp.select([code == OP_FAA, code == OP_FOR, code == OP_FAND,
                       code == OP_FXOR],
                      [cur + a, cur | a, cur & a, cur ^ a], cur)


def fused_apply(local: Array, ops: Array, mask: Array, *, reply_width: int
                ) -> Tuple[Array, Array]:
    """Sequential oracle for the fused descriptor lane (DESIGN.md §2).

    local (L,) int32; ops (m, 6 + V) int32 rows
    [off, opcode, a, b, aux0, aux1, vals...]; mask (m,) bool.
    Returns (reply (m, reply_width), local'). reply[:, 0] is the old value
    at `off`; reply[:, 1:] is the gather result of FAO_GET ops (zeros for
    other opcodes).

    Semantics are SUB-PHASE decomposed — the fusion saves exchanges, not
    serialization structure, so the owner applies the batch exactly as the
    unfused engine would order its phases:

      1. all atomic components, serialized in op order (CAS_PUT[_PUB]'s CAS,
         FAO_GET's fetch-and-op with sub-kind `b`, primitive codes 0-6);
      2. all compound V-word puts of winning CAS_PUT[_PUB] ops at aux0,
         serialized (last writer wins), dropped whole when out of range;
      3. all publish flips of winning CAS_PUT_PUB ops (mem[off] ^= aux1);
      4. all FAO_GET gathers of G words from aux0 — a phase-end snapshot,
         exactly what the unfused engine's trailing get phase would read.

    Opcodes 0-6 behave as in `amo_apply` (vals/aux ignored), so
    heterogeneous batches mixing primitive and fused descriptors are legal.
    """
    L = local.shape[0]
    V = ops.shape[1] - 6
    G = reply_width - 1

    def atomic_step(local, x):
        op, ok = x
        off, code, a, b = op[0], op[1], op[2], op[3]
        cur = local[off]
        win = cur == a                         # CAS / CAS_PUT success
        new = jnp.select(
            [code == OP_PUT, code == OP_GET, code == OP_CAS, code == OP_FAA,
             code == OP_FOR, code == OP_FAND, code == OP_FXOR,
             (code == OP_CAS_PUT) | (code == OP_CAS_PUT_PUB),
             code == OP_FAO_GET],
            [b, cur, jnp.where(win, b, cur), cur + a,
             cur | a, cur & a, cur ^ a,
             jnp.where(win, b, cur),
             _fao(cur, a, b)], cur)
        local = local.at[off].set(jnp.where(ok, new, cur))
        return local, (jnp.where(ok, cur, 0), ok & win)

    local, (old, win) = jax.lax.scan(atomic_step, local, (ops, mask))

    is_csp = ((ops[:, 1] == OP_CAS_PUT) | (ops[:, 1] == OP_CAS_PUT_PUB))
    if V > 0:
        do_put = (win & is_csp & (ops[:, 4] >= 0) & (ops[:, 4] <= L - V))

        def put_step(local, x):
            op, do = x
            row = jnp.where(do, op[4], L) + jnp.arange(V)
            return local.at[row].set(op[6:], mode="drop"), None

        local, _ = jax.lax.scan(put_step, local, (ops, do_put))

    do_flip = win & (ops[:, 1] == OP_CAS_PUT_PUB)

    def flip_step(local, x):
        op, do = x
        off = op[0]
        cur = local[off]
        return local.at[off].set(jnp.where(do, cur ^ op[5], cur)), None

    local, _ = jax.lax.scan(flip_step, local, (ops, do_flip))

    if G > 0:
        is_get = (mask & (ops[:, 1] == OP_FAO_GET)
                  & (ops[:, 4] >= 0) & (ops[:, 4] <= L - G))
        idx = (jnp.where(is_get, ops[:, 4], L)[:, None] + jnp.arange(G))
        g = local.at[idx].get(mode="fill", fill_value=0)
        reply = jnp.concatenate(
            [old[:, None], jnp.where(is_get[:, None], g, 0)], axis=1)
    else:
        reply = old[:, None]
    return reply, local


# ---------------------------------------------------------------------------
# hash_probe: open-addressing probe over one local shard (AM handler body)
# ---------------------------------------------------------------------------
def hash_find(table: Array, starts: Array, keys: Array, mask: Array,
              nslots: int, rec_w: int, max_probes: int
              ) -> Tuple[Array, Array]:
    """table (L,) int32 with nslots records of rec_w words
    [flag|key|val...]; starts/keys/mask (m,). Returns (found (m,),
    vals (m, rec_w-2)). State low byte: 0 empty / 2 ready."""
    vw = rec_w - 2

    def one(start, key, ok):
        def body(j, carry):
            found, vals, stop = carry
            s = (start + j) % nslots
            rec = jax.lax.dynamic_slice(table, (s * rec_w,), (rec_w,))
            state = rec[0] & 255
            hit = (~stop) & (state == 2) & (rec[1] == key)
            empty = (~stop) & (state == 0)
            vals = jnp.where(hit, rec[2:], vals)
            return found | hit, vals, stop | hit | empty

        found, vals, _ = jax.lax.fori_loop(
            0, max_probes, body,
            (jnp.bool_(False), jnp.zeros((vw,), jnp.int32),
             jnp.bool_(False)))
        return found & ok, jnp.where(found & ok, vals, 0)

    return jax.vmap(one)(starts, keys, mask)


def hash_insert(table: Array, starts: Array, keys: Array, vals: Array,
                mask: Array, nslots: int, rec_w: int, max_probes: int
                ) -> Tuple[Array, Array, Array]:
    """Sequential insert-or-assign oracle. vals (m, rec_w-2).
    Returns (ok (m,), probes (m,), table'). probes counts slots examined
    until the op decided (hit/empty), max_probes on a full-table miss — the
    RPC-side analogue of the RDMA backend's CAS-attempt count."""
    vw = rec_w - 2

    def step(table, x):
        start, key, val, ok = x

        def body(j, carry):
            slot, kind, probes = carry  # kind 0=searching 1=hit 2=empty
            s = (start + j) % nslots
            rec = jax.lax.dynamic_slice(table, (s * rec_w,), (2,))
            state = rec[0] & 255
            searching = kind == 0
            hit = searching & (state == 2) & (rec[1] == key)
            empty = searching & (state == 0)
            slot = jnp.where(hit | empty, s, slot)
            kind = jnp.where(hit, 1, jnp.where(empty, 2, kind))
            probes = probes + searching.astype(jnp.int32)
            return slot, kind, probes

        slot, kind, probes = jax.lax.fori_loop(
            0, max_probes, body, (jnp.int32(-1), jnp.int32(0), jnp.int32(0)))
        can = ok & (kind > 0)
        rec = jnp.concatenate([jnp.array([2], jnp.int32), key[None], val])
        base = jnp.where(can, slot * rec_w, 0)
        cur = jax.lax.dynamic_slice(table, (base,), (rec_w,))
        table = jax.lax.dynamic_update_slice(
            table, jnp.where(can, rec, cur), (base,))
        return table, (can, jnp.where(ok, probes, 0))

    table2, (ok, probes) = jax.lax.scan(step, table, (starts, keys, vals,
                                                      mask))
    return ok, probes, table2


# ---------------------------------------------------------------------------
# flash attention (fwd): causal / local-window GQA attention
# ---------------------------------------------------------------------------
def mha(q: Array, k: Array, v: Array, *, causal: bool = True,
        window: int = 0, scale: float | None = None) -> Array:
    """q (B,H,S,d), k/v (B,Hkv,Skv,d). GQA by head broadcast. window > 0
    restricts attention to the last `window` positions (inclusive)."""
    B, H, S, d = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    g = H // Hkv
    kf = jnp.repeat(k, g, axis=1)
    vf = jnp.repeat(v, g, axis=1)
    scale = (d ** -0.5) if scale is None else scale
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kf.astype(jnp.float32)) * scale
    qpos = jnp.arange(S)[:, None] + (Skv - S)  # align ends (decode suffix)
    kpos = jnp.arange(Skv)[None, :]
    m = jnp.ones((S, Skv), bool)
    if causal:
        m &= kpos <= qpos
    if window > 0:
        m &= kpos > qpos - window
    logits = jnp.where(m, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      vf.astype(jnp.float32)).astype(q.dtype)


def decode_attention(q: Array, k: Array, v: Array, length: Array,
                     *, scale: float | None = None
                     ) -> Tuple[Array, Array, Array]:
    """Single-token decode with stats. q (B,H,d); k/v (B,Hkv,S,d);
    length (B,) valid cache length. Returns (o (B,H,d) — *unnormalized*
    partial numerator, m (B,H), l (B,H)) so shards combine associatively:
        o = sum_j exp(s_j - m) v_j,  l = sum_j exp(s_j - m),  m = max_j s_j.
    """
    B, H, d = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    g = H // Hkv
    kf = jnp.repeat(k, g, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, g, axis=1).astype(jnp.float32)
    scale = (d ** -0.5) if scale is None else scale
    s = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32), kf) * scale
    valid = jnp.arange(S)[None, None, :] < length[:, None, None]
    s = jnp.where(valid, s, -jnp.inf)
    m = jnp.max(s, axis=-1)
    msafe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.where(valid, jnp.exp(s - msafe[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhk,bhkd->bhd", p, vf)
    return o, m, l


def combine_decode_stats(o: Array, m: Array, l: Array) -> Array:
    """Combine per-shard (o, m, l) partials along leading axis -> (B,H,d).
    This is the RPC-style distributed decode: each KV shard returns stats."""
    mg = jnp.max(m, axis=0)
    msafe = jnp.where(jnp.isfinite(mg), mg, 0.0)
    w = jnp.exp(jnp.where(jnp.isfinite(m), m - msafe[None], -jnp.inf))
    w = jnp.where(jnp.isfinite(m), w, 0.0)
    num = jnp.sum(o * w[..., None], axis=0)
    den = jnp.sum(l * w, axis=0)
    return num / jnp.maximum(den, 1e-30)[..., None]


# ---------------------------------------------------------------------------
# moe_dispatch: expert histogram + stable positions (batched FAA lane)
# ---------------------------------------------------------------------------
def moe_dispatch(expert_ids: Array, n_experts: int
                 ) -> Tuple[Array, Array]:
    """expert_ids (T,) int32 -> (counts (E,), position (T,)) where
    position[i] = #{j < i : expert_j == expert_i} (stable rank within
    expert). Equivalent to T chained FAAs on per-expert counters."""
    onehot = (expert_ids[:, None] ==
              jnp.arange(n_experts)[None, :]).astype(jnp.int32)
    counts = jnp.sum(onehot, axis=0)
    incl = jnp.cumsum(onehot, axis=0)
    position = jnp.take_along_axis(
        incl - onehot, expert_ids[:, None], axis=1)[:, 0]
    return counts, position


# ---------------------------------------------------------------------------
# rg_lru: gated linear recurrence h_t = a_t * h_{t-1} + b_t
# ---------------------------------------------------------------------------
def rg_lru_scan(a: Array, b: Array, h0: Array | None = None) -> Array:
    """a, b (B, S, D) f32; h0 (B, D) initial state. Returns h (B, S, D)."""
    B, S, D = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, D), a.dtype)

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    _, hs = jax.lax.scan(step, h0, (jnp.swapaxes(a, 0, 1),
                                    jnp.swapaxes(b, 0, 1)))
    return jnp.swapaxes(hs, 0, 1)
