"""moe_dispatch — expert histogram + stable position assignment.

The routing hot spot of MoE dispatch is, structurally, the paper's
fetch-and-add: every token performs FAA(counter[expert], 1) and its old
value is the token's slot in that expert's buffer. The serialized
`amo_apply` lane would do this in O(T) scalar steps; this kernel is the
TPU-native *vectorized* equivalent: one-hot expansion (MXU-friendly
(bt, E) tiles) + in-tile exclusive cumsum + a per-expert running counter
carried in VMEM scratch across tiles. Same linearized semantics (token i
precedes token j if i < j), 128-lane throughput instead of a scalar loop.

Output feeds the capacity-bounded all_to_all dispatch in models/moe.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dispatch_kernel(ids_ref, pos_ref, counts_ref, carry_ref, *, nt):
    it = pl.program_id(0)

    @pl.when(it == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    ids = ids_ref[0]                                  # (bt,)
    bt = ids.shape[0]
    E = carry_ref.shape[1]
    onehot = (ids[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (bt, E), 1)).astype(jnp.int32)     # (bt, E)
    incl = jnp.cumsum(onehot, axis=0)
    excl = incl - onehot                              # rank within this tile
    rank_in_tile = jnp.sum(excl * onehot, axis=1)
    base = jnp.sum(carry_ref[...] * onehot, axis=1)   # carried counter value
    pos_ref[0] = base + rank_in_tile
    carry_ref[...] = carry_ref[...] + incl[-1:, :]

    @pl.when(it == nt - 1)
    def _finalize():
        counts_ref[...] = carry_ref[...]


@functools.partial(jax.jit, static_argnames=("n_experts", "block_t",
                                             "interpret"))
def moe_dispatch(expert_ids: jax.Array, *, n_experts: int,
                 block_t: int = 256, interpret: bool = True):
    """expert_ids (T,) int32 -> (counts (E,) int32, position (T,) int32).

    position[i] = #{j < i : expert_j == expert_i}: the FAA ticket each
    token would have drawn from its expert's counter.
    """
    T = expert_ids.shape[0]
    bt = min(block_t, T)
    nt = pl.cdiv(T, bt)
    padded = jnp.pad(expert_ids, (0, nt * bt - T),
                     constant_values=n_experts)  # pad ids hash to no expert
    padded = jnp.where(padded >= n_experts, n_experts - 1, padded)
    # Padding tokens alias expert E-1 but are sliced off the position
    # output; counts are corrected below.
    kern = functools.partial(_dispatch_kernel, nt=nt)
    pos, counts = pl.pallas_call(
        kern,
        grid=(nt,),
        in_specs=[pl.BlockSpec((1, bt), lambda i: (0, i))],
        out_specs=[
            pl.BlockSpec((1, bt), lambda i: (0, i)),
            pl.BlockSpec((1, n_experts), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, nt * bt), jnp.int32),
            jax.ShapeDtypeStruct((1, n_experts), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((1, n_experts), jnp.int32)],
        interpret=interpret,
    )(padded[None])
    counts = counts[0]
    npad = nt * bt - T
    counts = counts.at[n_experts - 1].add(-npad)
    return counts, pos[0, :T]
