# The paper's primary contribution: PGAS distributed data structures with
# selectable RDMA / RPC backends + the analytical cost model that picks
# between them. See DESIGN.md §2 for the TPU-native translation.
from . import am, costmodel, hashtable, queue, routing, types, window
from .types import AmoKind, Backend, OpStats, Promise
from .window import Window, make_window, rdma_cas, rdma_fao, rdma_get, rdma_put

__all__ = [
    "am", "costmodel", "hashtable", "queue", "routing", "types", "window",
    "AmoKind", "Backend", "OpStats", "Promise",
    "Window", "make_window", "rdma_cas", "rdma_fao", "rdma_get", "rdma_put",
]
