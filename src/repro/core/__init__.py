# The paper's primary contribution: PGAS distributed data structures with
# selectable RDMA / RPC backends + the analytical cost model that picks
# between them. See DESIGN.md §2 for the TPU-native translation.
from . import (adaptive, am, costmodel, hashtable, pipeline, queue, routing,
               types, window)
from .adaptive import AdaptiveEngine, Decision
from .pipeline import Handle, Pipeline
from .types import AmoKind, Backend, OpStats, Promise
from .window import Window, make_window, rdma_cas, rdma_fao, rdma_get, rdma_put

__all__ = [
    "adaptive", "am", "costmodel", "hashtable", "pipeline", "queue",
    "routing", "types", "window", "AdaptiveEngine", "Decision",
    "Handle", "Pipeline",
    "AmoKind", "Backend", "OpStats", "Promise",
    "Window", "make_window", "rdma_cas", "rdma_fao", "rdma_get", "rdma_put",
]
