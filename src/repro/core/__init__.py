# The paper's primary contribution: PGAS distributed data structures with
# selectable RDMA / RPC backends + the analytical cost model that picks
# between them. See DESIGN.md §2 for the TPU-native translation.
from . import (adaptive, am, costmodel, hashtable, queue, routing, types,
               window)
from .adaptive import AdaptiveEngine, Decision
from .types import AmoKind, Backend, OpStats, Promise
from .window import Window, make_window, rdma_cas, rdma_fao, rdma_get, rdma_put

__all__ = [
    "adaptive", "am", "costmodel", "hashtable", "queue", "routing", "types",
    "window", "AdaptiveEngine", "Decision",
    "AmoKind", "Backend", "OpStats", "Promise",
    "Window", "make_window", "rdma_cas", "rdma_fao", "rdma_get", "rdma_put",
]
