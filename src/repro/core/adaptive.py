"""Adaptive hybrid backend: cost-model-driven RDMA/RPC arm selection per
batch (DESIGN.md §4).

The paper's punchline is not that RDMA always wins — it is that the
analytical model *orders* the implementations correctly, "allowing us to
choose the best implementation" (§VI). This module operationalizes that at
runtime: every data-structure op batch (hash-table insert/find, queue
push/pop) picks one of four *arms*

    rdma        seed per-component one-sided engine (fused=False/planned=False)
    rdma_fused  planned + fused-descriptor one-sided engine (DESIGN.md §2)
    am          aggregated active messages
    am_pt       active messages serviced by a progress thread (Fig. 6 "PT")

driven by `costmodel.predict_arm` over calibrated ComponentCosts plus three
online signals the engine maintains itself:

  * an EWMA of measured per-batch latency per (op, arm), fed back from the
    engine's own timed executions or from `benchmarks/components.py`-style
    probes (`observe`) — measured numbers on THIS host dominate the model
    prior once available;
  * a batch *skew statistic* (max owner load / mean owner load, computed
    host-side from the route destinations — the same histogram
    `routing.owner_loads` derives from a RoutePlan's occupancy): high skew
    serializes RDMA atomics in one owner's apply lane while AM aggregation
    amortizes the round trip, so skew tilts the model toward the AM arms;
  * a batch *dedup ratio* (unique key rows / total rows, `batch_dedup` —
    the statistic `routing.Coalescing.dedup_ratio()` measures on the
    wire): duplicate traffic (dedup < 1) turns sender-side coalescing on
    for the fused/AM arms and prices them with the distinct-row factor
    (DESIGN.md §6) — hot-key batches collapse toward O(distinct) wire
    rows, tilting the model back toward the coalesced one-sided arms.

Every choice is recorded as a `Decision`; the RDMA arms run inside
`window.decision_scope` and the AM arms thread the record into
`AMEngine.dispatch`, so benchmarks can attribute every network phase to the
arm that issued it.

Under `jax.jit` tracing the destinations are abstract: the skew falls back
to 1.0, timing is skipped, and the decision degrades to the pure
(deterministic) cost-model choice — safe to stage.
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, replace
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import costmodel as cm
from . import faults as flt
from .costmodel import ARMS, ComponentCosts, DSOp
from .types import OpStats, Promise


@dataclass(frozen=True)
class Decision:
    """One per-batch backend choice — the record shared with
    `AMEngine.dispatch` and `window.decision_scope` call sites."""

    op: DSOp
    promise: Promise
    arm: str                      # one of costmodel.ARMS
    skew: float                   # batch owner-load skew (1.0 if unknown)
    scores: Dict[str, float]      # per-arm score (µs/op) the choice used
    source: str                   # "model" | "ewma" | "mixed" | "forced" | ...
    batch_ops: int                # valid ops in the batch (0 if traced)
    dedup: float = 1.0            # distinct-row fraction (1.0 if unknown);
                                  # < 1 turns sender-side coalescing on for
                                  # the fused/AM arms (DESIGN.md §6)
    coalesce: bool = False        # the executed arm ran with coalescing
    cached: bool = False          # the executed arm consulted the
                                  # hot-bucket cache (DESIGN.md §8)
    hit_rate: float = 0.0         # hit-rate EWMA the scores were priced
                                  # with (the fourth online signal)
    depth: int = 1                # pipeline depth the batch runs at
                                  # (DESIGN.md §9): 1 for the synchronous
                                  # front-ends; the async front-ends stamp
                                  # the chooser-picked (or pipe-configured)
                                  # window count here
    quarantined: bool = False     # the cost choice was an AM arm but the
                                  # batch targets a quarantined owner, so
                                  # the decision re-routed to a one-sided
                                  # arm (DESIGN.md §10 graceful
                                  # degradation; source == "quarantine")


def _concrete(x) -> Optional[np.ndarray]:
    """Host value of `x`, or None under jit tracing."""
    if x is None:
        return None
    try:
        return np.asarray(x)
    except Exception:  # TracerArrayConversionError and friends
        return None


def batch_dedup(keys, valid=None) -> float:
    """Distinct-row fraction of a batch: unique key rows / total rows —
    the third online signal (DESIGN.md §6), computed host-side like
    `batch_skew`. Mirrors `routing.Coalescing.dedup_ratio()` for batches
    whose duplicate identity is the key (the hash-table front-ends: equal
    keys place identically, so they share (owner, offset) every probe).
    Returns 1.0 (no duplicates assumed) when `keys` is a tracer."""
    k = _concrete(keys)
    if k is None:
        return 1.0
    v = _concrete(valid)
    flat = k.ravel() if v is None else k[v.astype(bool)].ravel()
    if flat.size == 0:
        return 1.0
    return float(np.unique(flat).size / flat.size)


def batch_skew(dst, nranks: int, valid=None) -> float:
    """Max owner load / mean owner load over all `nranks` owners.

    1.0 = perfectly uniform, `nranks` = single hot owner. Computed
    host-side with a bincount — the same statistic `routing.plan_skew`
    derives from a RoutePlan's exchanged occupancy mask, without paying the
    plan's occupancy exchange. Returns 1.0 when `dst` is a tracer."""
    d = _concrete(dst)
    if d is None:
        return 1.0
    v = _concrete(valid)
    flat = d.ravel() if v is None else d[v.astype(bool)].ravel()
    if flat.size == 0:
        return 1.0
    counts = np.bincount(flat, minlength=nranks)
    return float(counts.max() * nranks / counts.sum())


class AdaptiveEngine:
    """Per-batch arm chooser + data-structure front-end wrappers.

    am_engine:  AMEngine servicing the `am` / `am_pt` arms (those arms are
                disabled when absent). Handlers are auto-registered against
                the first structure each wrapper sees (one AMEngine per
                structure, as in `am.AMEngine`).
    params:     ComponentCosts prior for the model scores; `calibrate()`
                replaces it with measured component latencies.
    alpha:      EWMA step for observed per-op latencies.
    policy:     "cost" (argmin score, default) or "round_robin"
                (deterministically cycle arms — conformance testing).
    measure:    time each executed batch and feed the EWMA (forces a device
                sync per op batch; library call sites keep it off).
    explore_every: when > 0, a "cost" decision probes the runner-up arm
                instead of the winner whenever the runner-up's EWMA has not
                been refreshed for this many decisions of the same op —
                bounded-cost exploration that prevents a single bad
                measurement from starving an arm forever. A clear loser
                (score > 2x the winner's) is refreshed at a quarter of
                that rate: probing it buys little information and its
                full cost is charged to the stream.
    hysteresis: relative margin under which a decision STICKS with the
                op's incumbent arm when both its and the winner's scores
                are measured EWMAs. Measured scores carry wall-clock
                noise; without a dead band the argmin flip-flops between
                near-equal arms and every flip executes the (slightly)
                losing one — the median-regret creep of ISSUE 8. Model
                scores are deterministic, so the band never applies to
                them and the model-driven pins are unaffected.
    cache:      optional core/cache.BucketCache (DESIGN.md §8). Explicit
                opt-in, NEVER auto-created: the default engines are shared
                per-nranks across every table, and a cache is coherent for
                exactly one table (its writes flow through this engine's
                `ht_insert`). When attached, CR finds on the fused
                one-sided arm consult it, the observed hit rate feeds a
                fourth online signal (`hit_ewma`, priced via
                OpStats.hit_rate), and a write-fraction EWMA
                (`write_ewma`) disables cache READS under write-heavy
                streams — invalidation always stays on (correctness).
    """

    #: write-fraction EWMA above which cache reads are suspended: at ≥50%
    #: writes the probe-window invalidations churn faster than fills
    #: repopulate, so the lookup is pure overhead.
    WRITE_HEAVY = 0.5

    def __init__(self, nranks: int, am_engine=None,
                 params: ComponentCosts = cm.TPU_V5E_ICI,
                 alpha: float = 0.25, arms: Optional[Tuple[str, ...]] = None,
                 policy: str = "cost", measure: bool = False,
                 explore_every: int = 0, cache=None,
                 hysteresis: float = 0.10):
        if arms is None:
            arms = ARMS if am_engine is not None else ("rdma", "rdma_fused")
        for a in arms:
            if a not in ARMS:
                raise ValueError(f"unknown arm {a!r}")
            if a in ("am", "am_pt") and am_engine is None:
                raise ValueError(f"arm {a!r} needs an am_engine")
        if policy not in ("cost", "round_robin"):
            raise ValueError(f"unknown policy {policy!r}")
        self.nranks = nranks
        self.am_engine = am_engine
        self.params = params
        self.alpha = alpha
        self.arms = tuple(arms)
        self.policy = policy
        self.measure = measure
        self.explore_every = explore_every
        self.hysteresis = hysteresis
        self.force_arm: Optional[str] = None
        self.cache = cache
        self.hit_ewma = 0.0    # observed cache hit rate (4th online signal)
        self.write_ewma = 0.0  # observed write fraction of the op stream
        # sixth online signal (DESIGN.md §10): per-owner fault-pressure
        # EWMA in [0, 1] (0 = healthy), fed by the fault plane's per-owner
        # retry/unserviced counters and by the straggler monitor bridge;
        # owners past QUARANTINE_ON are quarantined — their AM traffic
        # re-routes to the one-sided arms, which need no owner attention
        self.health: Dict[int, float] = {}
        self.quarantined: set = set()
        # measured loss EWMA (retransmits / transmissions) — folded into
        # OpStats.loss_rate so predict_arm prices arms under the observed
        # fault rate (costmodel retry_penalty term)
        self.loss_ewma = 0.0
        self.ewma: Dict[Tuple[DSOp, str], float] = {}
        # fifth online signal (DESIGN.md §9): observed per-op batch latency
        # per (op, depth) — overlays the predict_pipelined prior in
        # choose_depth the same way `ewma` overlays predict_arm
        self.depth_ewma: Dict[Tuple[DSOp, int], float] = {}
        # bounded ring: the default AUTO front-ends log every batch here
        # and nothing drains it
        self.log: collections.deque = collections.deque(maxlen=4096)
        self.last_decision: Optional[Decision] = None
        self._rr = 0
        self._op_count: Dict[DSOp, int] = {}        # decisions per op
        self._seen: Dict[Tuple[DSOp, str], int] = {}  # last observe tick
        self._last_arm: Dict[DSOp, str] = {}        # hysteresis incumbent

    # -- signals ------------------------------------------------------------
    def calibrate(self, measured: Dict[str, float]) -> ComponentCosts:
        """Replace the model prior with measured component latencies
        (benchmarks/components.py row dict)."""
        self.params = cm.calibrate(measured, base=self.params)
        return self.params

    #: a single observation may exceed the arm's EWMA by at most this
    #: factor before it is clipped: a contended-host spike (the usual CI
    #: artifact) otherwise inflates the winner's EWMA in one step and the
    #: argmin flips to a genuinely slower arm for the several batches the
    #: EWMA needs to recover. A real slowdown still gets through — each
    #: clipped update raises the EWMA by alpha * (CLIP - 1) * prev, so a
    #: few consecutive slow batches reprice the arm anyway.
    OBSERVE_CLIP = 4.0

    def observe(self, decision: Decision, us_per_op: float) -> None:
        """EWMA-update the measured latency of (op, arm)."""
        key = (decision.op, decision.arm)
        prev = self.ewma.get(key)
        if prev is not None:
            us_per_op = min(us_per_op, self.OBSERVE_CLIP * prev)
        self.ewma[key] = (us_per_op if prev is None
                          else prev + self.alpha * (us_per_op - prev))
        self._seen[key] = self._op_count.get(decision.op, 0)
        if decision.depth > 1 or (decision.op, decision.depth) \
                in self.depth_ewma:
            self.observe_depth(decision.op, decision.depth, us_per_op)

    def observe_depth(self, op: DSOp, depth: int, us_per_op: float) -> None:
        """EWMA-update the measured per-op latency of (op, depth) — the
        fifth online signal. Fed by the pipelined benches and by `observe`
        whenever a Decision carries a depth; `choose_depth` prefers these
        measured numbers over the predict_pipelined prior."""
        key = (op, max(1, int(depth)))
        prev = self.depth_ewma.get(key)
        self.depth_ewma[key] = (us_per_op if prev is None
                                else prev + self.alpha * (us_per_op - prev))

    def attach_cache(self, cache) -> None:
        """Attach a hot-bucket cache (DESIGN.md §8). One cache per table:
        coherence holds only for writes issued through THIS engine."""
        self.cache = cache

    def cache_reads_on(self) -> bool:
        """Whether CR finds should consult the cache right now: one is
        attached, enabled, and the stream is not write-heavy (the chooser
        disables reads — not invalidation — past WRITE_HEAVY, where the
        probe-window version churn outruns the fills)."""
        return (self.cache is not None and self.cache.enabled
                and self.write_ewma < self.WRITE_HEAVY)

    def _observe_rw(self, is_write: bool) -> None:
        self.write_ewma += self.alpha * (float(is_write) - self.write_ewma)

    # -- owner health (sixth online signal, DESIGN.md §10) ------------------
    #: health EWMA at/above which an owner is quarantined; released once
    #: the EWMA decays below half of it (a hysteresis band, like the arm
    #: chooser's — flapping in and out of quarantine re-routes traffic for
    #: no information gain)
    QUARANTINE_ON = 0.5

    def quarantine(self, rank: int, pressure: float = 1.0) -> None:
        """Mark `rank` unhealthy (health EWMA raised to >= `pressure`);
        at QUARANTINE_ON or above its AM traffic re-routes one-sided."""
        self.health[rank] = max(self.health.get(rank, 0.0), float(pressure))
        if self.health[rank] >= self.QUARANTINE_ON:
            self.quarantined.add(rank)

    def ingest_fault_stats(self, plane) -> None:
        """Fold the fault plane's per-owner counters into the health EWMA.

        Pressure per owner = (unserviced + 0.25 * retries) / rows, clamped
        to [0, 1] — a fully dead owner scores 1.0 and, because the first
        sample seeds the EWMA directly, is quarantined after ONE batch.
        Also refreshes the measured loss EWMA (retransmits over total
        transmissions) that prices arms via OpStats.loss_rate."""
        taken = plane.take_owner_stats()
        if not taken:
            return
        for r, st in taken.items():
            rows = max(1, st["rows"])
            pressure = min(1.0, (st["unserviced"] + 0.25 * st["retries"])
                           / rows)
            prev = self.health.get(r)
            h = (pressure if prev is None
                 else prev + self.alpha * (pressure - prev))
            self.health[r] = h
            if h >= self.QUARANTINE_ON:
                self.quarantined.add(r)
            elif h < self.QUARANTINE_ON / 2:
                self.quarantined.discard(r)
        rows = sum(st["rows"] for st in taken.values())
        ret = sum(st["retries"] for st in taken.values())
        lr = ret / max(1, rows + ret)
        self.loss_ewma = (lr if self.loss_ewma == 0.0
                          else self.loss_ewma
                          + self.alpha * (lr - self.loss_ewma))

    def quarantine_from_monitor(self, classes: Dict[int, str],
                                ranks_per_host: int = 1) -> None:
        """Bridge `runtime/straggler.StragglerMonitor.classify()` verdicts
        into the health signal: a slow/replace/dead host marks its ranks
        quarantined (their AM traffic re-routes to the one-sided arms,
        which a distracted or dead host CPU cannot stall); a healthy
        verdict decays the rank back toward release. Host h owns ranks
        [h * ranks_per_host, (h + 1) * ranks_per_host)."""
        severity = {"dead": 1.0, "replace": 0.9, "slow": 0.6}
        for host, cls in classes.items():
            for r in range(host * ranks_per_host,
                           (host + 1) * ranks_per_host):
                if not 0 <= r < self.nranks:
                    continue
                if cls in severity:
                    self.quarantine(r, severity[cls])
                elif r in self.health:
                    h = (1.0 - self.alpha) * self.health[r]
                    self.health[r] = h
                    if h < self.QUARANTINE_ON / 2:
                        self.quarantined.discard(r)

    def _after_am(self) -> Optional[np.ndarray]:
        """Post-execution fault bookkeeping: with a fault plane in scope,
        ingest its per-owner pressure and return the last AM dispatch's
        unserviced-row mask (None when everything was serviced) so the
        wrapper can fail those rows over to the one-sided lane."""
        plane = flt.active_plane()
        if plane is None:
            return None
        uns = plane.take_unserviced()
        self.ingest_fault_stats(plane)
        return uns

    def _fault_stats(self, s: OpStats) -> OpStats:
        """Fold the measured loss EWMA into OpStats.loss_rate (pre-set
        values win) so predict_arm prices arms under the observed fault
        rate — the §10 retry term."""
        if self.loss_ewma > 0.0 and s.loss_rate == 0.0:
            s = replace(s, loss_rate=min(0.95, self.loss_ewma))
        return s

    # -- decision -----------------------------------------------------------
    def scores(self, op: DSOp, promise: Promise,
               stats: Optional[OpStats] = None,
               skew: Optional[float] = None) -> Tuple[Dict[str, float], str]:
        """Per-arm score in µs/op: the measured EWMA when one exists for
        (op, arm), else the cost-model prediction. Returns (scores, source)
        with source describing which inputs were used. `skew` (when given)
        overrides stats.skew for the model predictions — `decide` passes
        the host-computed batch skew this way so the OpStats fold is paid
        only on the model path."""
        stats = self._fault_stats(stats or OpStats())
        ew = self.ewma
        out = {}
        for arm in self.arms:
            v = ew.get((op, arm))
            if v is None:
                break
            out[arm] = v
        else:
            # fast path: every arm measured — no OpStats folding, no model
            # evaluation. decide() sits on the application's critical path
            # (charged per batch by the §4 regret accounting), and in the
            # steady state this is the only path taken.
            return out, "ewma"
        s = stats or OpStats()
        if skew is not None and skew != s.skew:
            s = replace(s, skew=skew)
        if s.nranks == 0:
            s = replace(s, nranks=self.nranks)
        out, used = {}, set()
        for arm in self.arms:
            v = ew.get((op, arm))
            if v is not None:
                out[arm] = v
                used.add("ewma")
            else:
                out[arm] = cm.predict_arm(op, promise, arm, s, self.params)
                used.add("model")
        return out, ("mixed" if len(used) > 1 else used.pop())

    def peek_arm(self, op: DSOp, promise: Promise,
                 stats: Optional[OpStats] = None) -> str:
        """The arm `decide` WOULD pick for this (op, promise, stats) —
        without logging a Decision, advancing the round-robin cursor, or
        consuming an exploration probe.

        The async front-ends (hashtable.insert_async & friends, DESIGN.md
        §7) call this at submit time to route AM-arm batches through the
        deferred-dispatch queue (`Pipeline.submit(deferred=True)`); the
        authoritative, logged decision still happens when the batch
        stages. A peek/stage mismatch (an EWMA update landing in between)
        is harmless — deferral only moves WHEN the batch stages, never
        which arm runs it."""
        if self.force_arm is not None:
            return self.force_arm
        if self.policy == "round_robin":
            return self.arms[self._rr % len(self.arms)]
        scores, _ = self.scores(op, promise, stats)
        return self._cost_choice(op, scores)[0]

    # tie-break toward the cheaper-at-runtime engine: the planned + fused
    # arm strictly dominates the seed arm at equal predicted cost (the
    # queue has no fused formula, so they tie there)
    _ARM_RANK = {"rdma_fused": 0, "am": 1, "am_pt": 2, "rdma": 3}

    def _cost_choice(self, op: DSOp, scores: Dict[str, float]):
        """(arm, ranked arms) under the "cost" policy: argmin score with a
        hysteresis dead band — when the incumbent's and the winner's
        scores are BOTH measured EWMAs and the incumbent is within
        `hysteresis` of the winner, keep the incumbent (measured numbers
        jitter; flipping inside the noise band just executes the loser).
        Model scores are deterministic, so they never engage the band."""
        ranked = sorted(scores, key=lambda a: (scores[a], self._ARM_RANK[a]))
        arm = ranked[0]
        last = self._last_arm.get(op)
        if (last is not None and last != arm and last in scores
                and (op, last) in self.ewma and (op, arm) in self.ewma
                and scores[last] <= scores[arm] * (1.0 + self.hysteresis)):
            arm = last
        return arm, ranked

    def choose_depth(self, op: DSOp, promise: Promise,
                     stats: Optional[OpStats] = None,
                     arm: Optional[str] = None,
                     max_depth: Optional[int] = None) -> int:
        """Pipeline depth the engine recommends for this (op, promise,
        stats) — the §9 auto-depth decision. Model prior: argmin of
        `predict_pipelined` over `costmodel.DEPTH_CANDIDATES` for the arm
        `peek_arm` would run (or the given one). Measured overlay: any
        (op, depth) latency recorded via `observe_depth` (the fifth online
        signal) replaces the model's number for that depth, so one bad
        depth — e.g. the depth-4 queueing regression — is learned from a
        single measured sweep even when the calibrated
        `pipe_depth_overhead` underprices it. Ties break toward the
        SHALLOWEST depth (extra windows are never free). Like `peek_arm`,
        this logs nothing — the Decision that records the depth is cut at
        stage time."""
        s = stats or OpStats()
        if s.nranks == 0:
            s = replace(s, nranks=self.nranks)
        if arm is None:
            arm = self.peek_arm(op, promise, s)
        cands = [d for d in sorted(set(int(x) for x in cm.DEPTH_CANDIDATES))
                 if d >= 1 and (max_depth is None or d <= max_depth)]
        model = {d: cm.predict_pipelined(op, promise, arm, s, self.params,
                                         depth=d) for d in cands}
        obs = {d: self.depth_ewma[(op, d)] for d in cands
               if (op, d) in self.depth_ewma}
        # Measured numbers carry host overheads the model doesn't, so an
        # unobserved depth cannot compete on the raw model scale — anchor
        # it by the mean measured/model ratio of the observed depths (the
        # calibration-transfer idiom) before comparing.
        factor = 1.0
        if obs:
            ratios = [obs[d] / model[d] for d in obs if model[d] > 0.0]
            if ratios:
                factor = sum(ratios) / len(ratios)
        best_d, best_t = 1, float("inf")
        for d in cands:
            t = obs.get(d, model[d] * factor)
            if t < best_t - 1e-9:
                best_d, best_t = d, t
        return best_d

    def auto_depth(self, pipe, op: DSOp, promise: Promise,
                   stats: Optional[OpStats] = None) -> OpStats:
        """Submit-time §9 hook shared by the async front-ends: when `pipe`
        opted into auto-depth, pick the window count via `choose_depth`,
        retarget the pipeline (`Pipeline.set_depth`, capped at the pipe's
        constructor depth), and return the stats priced at the chosen
        depth — so the stage-time Decision records `depth` faithfully.
        A fixed-depth pipeline passes through untouched."""
        s = stats or OpStats()
        if not getattr(pipe, "auto_depth", False):
            return s
        d = self.choose_depth(op, promise, s, max_depth=pipe.max_depth)
        pipe.set_depth(d)
        return replace(s, pipeline_depth=d)

    def decide(self, op: DSOp, promise: Promise, dst=None, valid=None,
               stats: Optional[OpStats] = None,
               nops: Optional[int] = None,
               owners: Optional[Tuple[int, ...]] = None) -> Decision:
        """Choose the arm for one batch. `dst` (P, n) feeds the skew
        statistic (skipped when `stats.skew` is already set — e.g. the
        hosted queue's skew is `nranks` by construction, no device read
        needed); `stats` carries the remaining workload signals
        (expected_probes, target_busy_us, ...). `owners`, when given, is
        the static owner set the batch targets (the hosted queue passes
        `(q.host,)`) — used for the §10 quarantine test without reading
        `dst` off the device."""
        s = stats or OpStats()
        skew = s.skew
        # the skew statistic feeds the MODEL's owner-serialization term;
        # once every arm has a measured EWMA the decision never reads it,
        # so the host-side bincount (the single largest decide() cost —
        # this sits on the application's per-batch critical path) is
        # computed only when some arm still needs a model price. Pure-EWMA
        # decisions record the caller's stats.skew as-is.
        ewma_complete = all((op, a) in self.ewma for a in self.arms)
        if not ewma_complete and dst is not None and skew == 1.0:
            skew = batch_skew(dst, self.nranks, valid)
        dedup = s.dedup
        if nops is None:
            v = _concrete(valid)
            if v is not None:
                nops = int(v.sum())
            elif dst is not None and not isinstance(dst, jax.core.Tracer):
                # static shape — never materialize dst here: on the §7
                # staging path that would serialize batch k+1 behind
                # batch k's in-flight device work. Traced batches keep
                # the documented batch_ops == 0 sentinel.
                nops = int(dst.size)
            else:
                nops = 0
        scores, source = self.scores(op, promise, s, skew=skew)
        tick = self._op_count.get(op, 0) + 1
        self._op_count[op] = tick
        if self.force_arm is not None:
            arm, source = self.force_arm, "forced"
        elif self.policy == "round_robin":
            arm = self.arms[self._rr % len(self.arms)]
            self._rr += 1
            source = "round_robin"
        else:
            arm, ranked = self._cost_choice(op, scores)
            self._last_arm[op] = arm
            if self.explore_every > 0 and len(ranked) > 1:
                runner = ranked[1] if ranked[0] == arm else ranked[0]
                need = self.explore_every
                if scores[runner] > 2.0 * scores[arm]:
                    # clear loser: its full cost is charged to the stream
                    # and one probe per explore_every buys almost no
                    # information — refresh it at a quarter of the rate
                    need *= 4
                if tick - self._seen.get((op, runner), 0) >= need:
                    arm, source = runner, "explore"
                    # mark the probe attempt NOW: if the caller never
                    # observes a latency, the staleness clock still resets
                    # and exploration stays bounded at 1/explore_every
                    # instead of locking onto the runner-up forever
                    self._seen[(op, runner)] = tick
        # §10 graceful degradation: an AM arm needs the owner's CPU to
        # reach a dispatch point, and a quarantined owner's won't (dead or
        # chronically inattentive). Re-route the batch to the cheapest
        # non-AM arm — the one-sided lane needs only the target NIC, which
        # the fault model keeps live. `force_arm` is exempt (conformance
        # tests pin arms on purpose); tracer batches can't be hit-tested
        # and fall through to the AM-side unserviced failover instead.
        quarantined_flag = False
        if (self.quarantined and source != "forced"
                and arm in ("am", "am_pt")):
            if owners is not None:
                hit = any(int(r) in self.quarantined for r in owners)
            else:
                d = _concrete(dst)
                hit = False
                if d is not None:
                    v = _concrete(valid)
                    flat = (d.ravel() if v is None
                            else d[v.astype(bool)].ravel())
                    hit = bool(np.isin(
                        flat, np.fromiter(self.quarantined,
                                          dtype=np.int64)).any())
            if hit:
                cands = [a for a in scores if a not in ("am", "am_pt")]
                if cands:
                    arm = min(cands,
                              key=lambda a: (scores[a], self._ARM_RANK[a]))
                    source = "quarantine"
                    quarantined_flag = True
                    self._last_arm[op] = arm
        dec = Decision(op=op, promise=promise, arm=arm, skew=skew,
                       scores=scores, source=source, batch_ops=nops,
                       dedup=dedup,
                       coalesce=cm.arm_coalesces(op, arm, dedup),
                       cached=(self.cache_reads_on()
                               and cm.arm_caches(op, promise, arm)),
                       hit_rate=s.hit_rate,
                       depth=max(1, int(s.pipeline_depth)),
                       quarantined=quarantined_flag)
        self.log.append(dec)
        self.last_decision = dec
        return dec

    # -- execution helpers --------------------------------------------------
    def _timed(self, dec: Decision, fn):
        """Run fn(), feeding the EWMA when measuring is on and the batch is
        concrete. am_pt accounts the progress-thread contention factor on
        top of the measured dispatch (the Fig. 6 "PT" accounting, as in
        benchmarks/attentiveness.py)."""
        if not (self.measure and dec.batch_ops):
            return fn()
        t0 = time.perf_counter()
        out = fn()
        try:
            jax.block_until_ready(out)
        except Exception:
            return out  # traced values: skip the observation
        us = (time.perf_counter() - t0) * 1e6 / dec.batch_ops
        if dec.arm == "am_pt":
            us *= self.params.pt_overhead
        self.observe(dec, us)
        return out

    def _host_stats(self, stats: Optional[OpStats]) -> OpStats:
        """Stats for a hosted (single-owner) structure: every op targets
        the host rank, so the skew is `nranks` by construction — no
        destination array needs to leave the device to know it."""
        s = stats or OpStats()
        return s if s.skew != 1.0 else replace(s, skew=float(self.nranks))

    def _need_am(self, name: str, register):
        eng = self.am_engine
        assert eng is not None
        if name not in eng._handlers:
            register(eng)
        return eng

    # -- data-structure wrappers -------------------------------------------
    def _ht_stats(self, keys, valid, stats: Optional[OpStats]) -> OpStats:
        """Hash-table batch stats: fold the observed dedup ratio (unique
        keys / total — the third online signal, DESIGN.md §6) into the
        workload stats; pre-set `stats.dedup` to skip the host read."""
        s = stats or OpStats()
        if s.dedup == 1.0:
            s = replace(s, dedup=batch_dedup(keys, valid))
        return s

    def ht_insert(self, ht, keys, vals, promise: Promise = Promise.CRW,
                  valid=None, max_probes: int = 8,
                  stats: Optional[OpStats] = None):
        """Adaptive hash-table insert: returns (table', ok, probes).

        The skew statistic reads the batch's owner placement on the host
        (one device read per batch); pre-set `stats.skew` to skip it.
        Duplicate-key batches (dedup < 1) run the fused/AM arms with
        sender-side coalescing on. With a cache attached (DESIGN.md §8)
        every insert — ANY arm, the AM insert-or-assign included — bumps
        the probe-window versions of its keys BEFORE executing, so stale
        cached records can never be served after this call returns."""
        from . import hashtable as ht_mod
        from . import window as win_mod
        dst, _ = ht_mod._place(ht, keys)
        dec = self.decide(DSOp.HT_INSERT, promise, dst, valid,
                          self._ht_stats(keys, valid, stats))
        self._observe_rw(is_write=True)
        if self.cache is not None:
            # authoritative invalidation: versions bump before any write
            # lands, so a racing deferred fill tick-mismatches and drops
            self.cache.on_insert_keys(keys, valid, max_probes)
        if dec.arm in ("am", "am_pt"):
            eng = self._need_am(
                "ht_insert",
                lambda e: ht_mod.build_am_handlers(ht, e,
                                                   max_probes=max_probes))
            ht2, ok, probes = self._timed(dec, lambda: ht_mod.insert_rpc(
                ht, eng, keys, vals, valid=valid, decision=dec,
                coalesce=dec.coalesce))
            uns = self._after_am()
            if uns is not None:
                # §10 failover: rows whose owner never serviced the AM
                # (dead/stalled) land via the one-sided lane — the target
                # NIC stays live even when the host CPU is inattentive.
                # All of a dead owner's rows move together, so per-owner
                # apply order is preserved and the result matches the
                # fault-free oracle.
                m = jnp.asarray(uns)
                rv = m if valid is None else (valid & m)
                with win_mod.decision_scope(dec), \
                        win_mod.cache_scope(self.cache):
                    # coalesce rides along: duplicate keys in the subset
                    # must collapse to ONE record, exactly as the AM
                    # insert-or-assign handler would have applied them
                    ht2, ok2, pr2 = ht_mod.insert_rdma(
                        ht2, keys, vals, promise=promise, valid=rv,
                        max_probes=max_probes, fused=True,
                        coalesce=dec.coalesce)
                ok = jnp.where(m, ok2, ok)
                probes = jnp.where(m, pr2, probes)
            return ht2, ok, probes

        def run():
            with win_mod.decision_scope(dec), \
                    win_mod.cache_scope(self.cache):
                return ht_mod.insert_rdma(
                    ht, keys, vals, promise=promise, valid=valid,
                    max_probes=max_probes, fused=dec.arm == "rdma_fused",
                    coalesce=dec.coalesce)
        out = self._timed(dec, run)
        self._after_am()  # ingest wire-retry pressure from the phases
        return out

    def ht_find(self, ht, keys, promise: Promise = Promise.CR,
                valid=None, max_probes: int = 8,
                stats: Optional[OpStats] = None, max_stale: int = 0):
        """Adaptive hash-table find: returns (table', found, vals).

        max_stale (DESIGN.md §10): bounded-staleness tolerance for the
        cached arm — cached records at most this many publishes behind
        the authoritative version still count as hits (0 = bit-exact §8
        reads). Only the cache consult is affected; wire reads are always
        authoritative.

        With a cache attached and reads on (see `cache_reads_on`), the
        hit-rate EWMA is folded into the stats (OpStats.hit_rate — the
        fourth online signal) so the chooser prices the cached fused arm
        with the §8 discount, and the executed CR fused find consults the
        cache; the batch's observed hit rate then refreshes the EWMA."""
        from . import hashtable as ht_mod
        from . import window as win_mod
        dst, _ = ht_mod._place(ht, keys)
        s = self._ht_stats(keys, valid, stats)
        reads_cached = (self.cache_reads_on() and promise == Promise.CR)
        if reads_cached and s.hit_rate == 0.0:
            s = replace(s, hit_rate=self.hit_ewma)
        dec = self.decide(DSOp.HT_FIND, promise, dst, valid, s)
        self._observe_rw(is_write=False)
        if dec.arm in ("am", "am_pt"):
            eng = self._need_am(
                "ht_find",
                lambda e: ht_mod.build_am_handlers(ht, e,
                                                   max_probes=max_probes))
            found, vals = self._timed(dec, lambda: ht_mod.find_rpc(
                ht, eng, keys, valid=valid, decision=dec,
                coalesce=dec.coalesce))
            uns = self._after_am()
            if uns is not None:
                # §10 failover: unserviced finds re-read one-sided (reply
                # words of undelivered ops are garbage by contract, so the
                # merge below overwrites exactly those rows)
                m = jnp.asarray(uns)
                rv = m if valid is None else (valid & m)
                with win_mod.decision_scope(dec):
                    _, f2, v2 = ht_mod.find_rdma(
                        ht, keys, promise=promise, valid=rv,
                        max_probes=max_probes, fused=True)
                found = jnp.where(m, f2, found)
                vals = jnp.where(m[..., None], v2, vals)
            return ht, found, vals

        def run():
            with win_mod.decision_scope(dec):
                return ht_mod.find_rdma(
                    ht, keys, promise=promise, valid=valid,
                    max_probes=max_probes, fused=dec.arm == "rdma_fused",
                    coalesce=dec.coalesce,
                    cache=self.cache if dec.cached else None,
                    max_stale=max_stale)
        out = self._timed(dec, run)
        self._after_am()  # ingest wire-retry pressure from the phases
        if dec.cached and self.cache.last_hit_rate is not None:
            self.hit_ewma += self.alpha * (self.cache.last_hit_rate
                                           - self.hit_ewma)
        return out

    def q_push(self, q, vals, promise: Promise = Promise.CRW, valid=None,
               max_cas_rounds: int = 8, stats: Optional[OpStats] = None):
        """Adaptive queue push: returns (queue', pushed). The queue's
        `rdma_fused` arm is the planned engine (one RoutePlan shared by all
        component phases — the hosted queue has no compound descriptors)."""
        from . import queue as q_mod
        from . import window as win_mod
        P, n, _ = vals.shape
        dec = self.decide(DSOp.Q_PUSH, promise, valid=valid,
                          stats=self._host_stats(stats),
                          nops=P * n if valid is None else None,
                          owners=(q.host,))
        if dec.arm in ("am", "am_pt"):
            eng = self._need_am(
                "q_push", lambda e: q_mod.build_am_handlers(q, e))
            q2, ok = self._timed(dec, lambda: q_mod.push_rpc(
                q, eng, vals, valid=valid, decision=dec))
            uns = self._after_am()
            if uns is not None:
                # §10 failover: the queue is hosted on ONE rank, so a dead
                # host leaves the whole batch unserviced and the re-run is
                # a full one-sided push — single-host, so FIFO order is
                # whatever the one-sided reservation hands out, exactly as
                # in the fault-free rdma arm.
                m = jnp.asarray(uns)
                rv = m if valid is None else (valid & m)
                with win_mod.decision_scope(dec):
                    q2, ok2 = q_mod.push_rdma(
                        q2, vals, promise=promise, valid=rv,
                        max_cas_rounds=max_cas_rounds, planned=True)
                ok = jnp.where(m, ok2, ok)
            return q2, ok

        def run():
            with win_mod.decision_scope(dec):
                return q_mod.push_rdma(
                    q, vals, promise=promise, valid=valid,
                    max_cas_rounds=max_cas_rounds,
                    planned=dec.arm == "rdma_fused",
                    coalesce=dec.coalesce)
        out = self._timed(dec, run)
        self._after_am()  # ingest wire-retry pressure from the phases
        return out

    def q_pop(self, q, n: int, promise: Promise = Promise.CR, valid=None,
              max_cas_rounds: int = 8, stats: Optional[OpStats] = None):
        """Adaptive queue pop: returns (queue', got, vals)."""
        from . import queue as q_mod
        from . import window as win_mod
        dec = self.decide(DSOp.Q_POP, promise, valid=valid,
                          stats=self._host_stats(stats),
                          nops=q.nranks * n if valid is None else None,
                          owners=(q.host,))
        if dec.arm in ("am", "am_pt"):
            eng = self._need_am(
                "q_pop", lambda e: q_mod.build_am_handlers(q, e))
            q2, got, pvals = self._timed(dec, lambda: q_mod.pop_rpc(
                q, eng, n, valid=valid, decision=dec))
            uns = self._after_am()
            if uns is not None:
                # §10 failover: unserviced pops never consumed anything —
                # re-issue them one-sided against the updated queue state
                m = jnp.asarray(uns)
                rv = m if valid is None else (valid & m)
                with win_mod.decision_scope(dec):
                    q2, g2, v2 = q_mod.pop_rdma(
                        q2, n, promise=promise, valid=rv,
                        max_cas_rounds=max_cas_rounds, planned=True)
                got = jnp.where(m, g2, got)
                pvals = jnp.where(m[..., None], v2, pvals)
            return q2, got, pvals

        def run():
            with win_mod.decision_scope(dec):
                return q_mod.pop_rdma(
                    q, n, promise=promise, valid=valid,
                    max_cas_rounds=max_cas_rounds,
                    planned=dec.arm == "rdma_fused",
                    coalesce=dec.coalesce)
        out = self._timed(dec, run)
        self._after_am()  # ingest wire-retry pressure from the phases
        return out


# ---------------------------------------------------------------------------
# Default engines for the `backend="auto"` front-ends, cached so EWMA state
# and the decision log persist across calls that don't pass an explicit
# AdaptiveEngine. The with-AMEngine case hangs the chooser off the AMEngine
# itself (same lifecycle — no global registry pinning dead engines); the
# engine-less case is one chooser per nranks.
# ---------------------------------------------------------------------------
_DEFAULT: Dict[int, AdaptiveEngine] = {}


def default_engine(nranks: int, am_engine=None) -> AdaptiveEngine:
    if am_engine is not None:
        eng = getattr(am_engine, "_default_adaptive", None)
        if eng is None or eng.nranks != nranks:
            eng = AdaptiveEngine(nranks, am_engine=am_engine)
            am_engine._default_adaptive = eng
        return eng
    eng = _DEFAULT.get(nranks)
    if eng is None:
        eng = AdaptiveEngine(nranks)
        _DEFAULT[nranks] = eng
    return eng
