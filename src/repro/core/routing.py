"""Owner-routing engine: bucket a batch of ops by destination rank and
exchange the buckets across ranks.

This is the *one network phase* primitive out of which both backends are
built:

- an RDMA component op (put/get/CAS/FAO) is exactly one routed phase
  (plus one reply phase when it fetches something), with NO target-side
  control flow other than the fixed-function AMO apply;
- an RPC dispatch is one routed request phase, an arbitrary local handler,
  and one routed reply phase.

Representation: every participant ("virtual rank") owns row `r` of a
`(P, ...)` array. The leading P axis is mapped onto physical mesh axes by
the launch layer via `sharding_hint`; on a single CPU device everything is
local and the exchange is a transpose. When P is sharded over a mesh axis,
`exchange` lowers to an XLA all-to-all — one per network phase, which is
what the roofline collective counter sees.
"""
from __future__ import annotations

import contextlib
import functools
from dataclasses import dataclass
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Sharding hook: the launch layer installs a constraint function so that the
# P axis stays pinned to its mesh axes across phases (forcing all_to_all
# lowering instead of gather/slice chains). Default is identity (single dev).
# ---------------------------------------------------------------------------
_SHARD_HOOK: Callable[[jax.Array, str], jax.Array] = lambda x, role: x


def set_sharding_hook(fn: Optional[Callable[[jax.Array, str], jax.Array]]):
    global _SHARD_HOOK
    _SHARD_HOOK = fn if fn is not None else (lambda x, role: x)


@contextlib.contextmanager
def sharding_hook(fn):
    global _SHARD_HOOK
    prev = _SHARD_HOOK
    _SHARD_HOOK = fn
    try:
        yield
    finally:
        _SHARD_HOOK = prev


def _hint(x: jax.Array, role: str) -> jax.Array:
    return _SHARD_HOOK(x, role)


# ---------------------------------------------------------------------------
# Binning: per-origin scatter of ops into per-destination capacity slots.
# ---------------------------------------------------------------------------
@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=["buf", "mask", "op_slot", "op_ok", "dropped"],
                   meta_fields=[])
@dataclass
class Binned:
    """Result of binning one origin's op batch by destination rank.

    buf:      (nranks, cap, W) payload words routed to each destination
    mask:     (nranks, cap)    slot occupancy
    op_slot:  (n,)             slot index assigned to each original op
    op_ok:    (n,)             op was delivered (not dropped by capacity)
    dropped:  ()               number of ops dropped (capacity overflow)
    """

    buf: jax.Array
    mask: jax.Array
    op_slot: jax.Array
    op_ok: jax.Array
    dropped: jax.Array


def bin_by_dest(dst: jax.Array, payload: jax.Array, nranks: int, cap: int,
                valid: Optional[jax.Array] = None) -> Binned:
    """Bucket `n` ops (single origin) by destination rank.

    dst:     (n,) int32 destination rank per op
    payload: (n, W) payload words per op (W static)
    cap:     per-destination slot capacity. cap >= n is always lossless.
    """
    n = dst.shape[0]
    if valid is None:
        valid = jnp.ones((n,), dtype=bool)
    # Invalid ops route to a sentinel rank (dropped by out-of-bounds scatter).
    dst_eff = jnp.where(valid, dst, nranks)
    order = jnp.argsort(dst_eff, stable=True)
    dst_sorted = dst_eff[order]
    # Position of each op within its destination group.
    group_start = jnp.searchsorted(dst_sorted, dst_sorted, side="left")
    pos_sorted = jnp.arange(n, dtype=jnp.int32) - group_start.astype(jnp.int32)
    payload_sorted = payload[order]

    buf = jnp.zeros((nranks, cap) + payload.shape[1:], dtype=payload.dtype)
    # mode="drop" silently drops dst==nranks (invalid) and pos>=cap (overflow)
    buf = buf.at[dst_sorted, pos_sorted].set(payload_sorted, mode="drop")
    mask = jnp.zeros((nranks, cap), dtype=bool)
    ok_sorted = (pos_sorted < cap) & (dst_sorted < nranks)
    mask = mask.at[dst_sorted, pos_sorted].set(ok_sorted, mode="drop")

    # Scatter slot assignments back to original op order.
    op_slot = jnp.zeros((n,), dtype=jnp.int32).at[order].set(pos_sorted)
    op_ok = jnp.zeros((n,), dtype=bool).at[order].set(ok_sorted)
    dropped = jnp.sum(valid) - jnp.sum(ok_sorted & (dst_sorted < nranks))
    return Binned(buf=buf, mask=mask, op_slot=op_slot, op_ok=op_ok,
                  dropped=dropped.astype(jnp.int32))


# ---------------------------------------------------------------------------
# Exchange: the network phase. (P_src, P_dst, ...) -> (P_dst, P_src, ...)
# ---------------------------------------------------------------------------
def exchange(x: jax.Array, role: str = "exchange") -> jax.Array:
    """Transpose the (src, dst) leading axes: each rank receives the buckets
    addressed to it. With the leading axis sharded over the owner mesh axes
    this lowers to a single all-to-all; on one device it is a transpose.
    """
    x = _hint(x, role + "_pre")
    out = jnp.swapaxes(x, 0, 1)
    return _hint(out, role + "_post")


# ---------------------------------------------------------------------------
# Full routed phases, vmapped over all P origins.
# ---------------------------------------------------------------------------
@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=["at_owner", "mask", "op_slot", "op_ok",
                                "dropped"],
                   meta_fields=[])
@dataclass
class Routed:
    """A request batch delivered to owners.

    at_owner: (P_owner, P_src, cap, W) payloads as seen by each owner
    mask:     (P_owner, P_src, cap)
    op_slot:  (P_src, n) slot index of each original op
    op_ok:    (P_src, n)
    dropped:  (P_src,)
    """

    at_owner: jax.Array
    mask: jax.Array
    op_slot: jax.Array
    op_ok: jax.Array
    dropped: jax.Array


# ---------------------------------------------------------------------------
# Route plans: the routing computation (stable argsort + slot binning + the
# owner-side occupancy exchange) factored out of the per-phase data path so
# that a probe loop issuing `max_probes + 2` phases to the SAME destinations
# pays for ONE sort instead of one per phase (DESIGN.md §2).
# ---------------------------------------------------------------------------
@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=["dst_eff", "op_slot", "op_ok", "mask",
                                "dropped"],
                   meta_fields=["cap"])
@dataclass
class RoutePlan:
    """A reusable (dst, slot) assignment for a batch of ops.

    dst_eff: (P, n)  destination per op, invalid ops -> sentinel `nranks`
    op_slot: (P, n)  slot within the destination bucket (raw rank in group;
                     may be >= cap for capacity-dropped ops)
    op_ok:   (P, n)  op was delivered (valid, in-capacity)
    mask:    (P_owner, P_src, cap) owner-side occupancy, exchanged ONCE at
             plan time; reused phases exchange only payload words
    dropped: (P,)    per-origin capacity drops
    cap:     static per-destination slot capacity
    """

    dst_eff: jax.Array
    op_slot: jax.Array
    op_ok: jax.Array
    mask: jax.Array
    dropped: jax.Array
    cap: int

    @property
    def nranks(self) -> int:
        return self.dst_eff.shape[0]


def make_plan(dst: jax.Array, valid: Optional[jax.Array] = None,
              cap: Optional[int] = None, role: str = "plan") -> RoutePlan:
    """Compute the routing assignment for a batch (ONE stable argsort) and
    exchange the occupancy mask (ONE exchange). Payload-only phases are then
    issued against the plan with `route_with_plan`.

    The binning is bin_by_dest itself (run with a zero-width payload), so
    plan slots are bit-identical to route()'s by construction."""
    nranks, n = dst.shape
    cap = n if cap is None else cap
    if valid is None:
        valid = jnp.ones(dst.shape, dtype=bool)
    empty = jnp.zeros(dst.shape + (0,), dtype=jnp.int32)
    binned = jax.vmap(
        lambda d, p, v: bin_by_dest(d, p, nranks, cap, v))(dst, empty, valid)
    dst_eff = jnp.where(valid, dst, nranks)
    mask_at_owner = exchange(binned.mask, role + "_mask")
    return RoutePlan(dst_eff=dst_eff, op_slot=binned.op_slot,
                     op_ok=binned.op_ok, mask=mask_at_owner,
                     dropped=binned.dropped, cap=cap)


def make_plan_np(dst, valid=None, cap: Optional[int] = None,
                 role: str = "plan") -> RoutePlan:
    """Host-side (numpy) mirror of `make_plan` — bit-identical slot
    assignment, computed on the Python thread instead of the device stream.

    This is how the pipeline engine (core/pipeline.py, DESIGN.md §7) takes
    plan construction off the critical path: batch *k+1*'s stable argsort
    and slot binning run on the host while the device is still executing
    batch *k*'s phases. The occupancy mask still crosses the network as ONE
    `exchange` (same PLAN_EXCHANGES accounting as `make_plan` — only the
    sort moved to the host), so phase counts are unchanged.

    dst/valid must be host-concrete (numpy or non-tracer jax arrays);
    under jit tracing use `make_plan`. Bit-equality with `make_plan` is
    pinned by tests/test_pipeline.py.
    """
    import numpy as np
    dst = np.asarray(dst)
    nranks, n = dst.shape
    cap = n if cap is None else cap
    valid = (np.ones(dst.shape, dtype=bool) if valid is None
             else np.asarray(valid).astype(bool))
    dst_eff = np.where(valid, dst, nranks).astype(np.int32)
    op_slot = np.zeros((nranks, n), np.int32)
    op_ok = np.zeros((nranks, n), bool)
    mask = np.zeros((nranks, nranks, cap), bool)
    dropped = np.zeros((nranks,), np.int32)
    for r in range(nranks):
        order = np.argsort(dst_eff[r], kind="stable")
        dst_s = dst_eff[r][order]
        group_start = np.searchsorted(dst_s, dst_s, side="left")
        pos = (np.arange(n) - group_start).astype(np.int32)
        ok = (pos < cap) & (dst_s < nranks)
        sel = ok
        mask[r][dst_s[sel], pos[sel]] = True
        op_slot[r][order] = pos
        op_ok[r][order] = ok
        dropped[r] = int(valid[r].sum()) - int(ok.sum())
    mask_at_owner = exchange(jnp.asarray(mask), role + "_mask")
    return RoutePlan(dst_eff=jnp.asarray(dst_eff),
                     op_slot=jnp.asarray(op_slot),
                     op_ok=jnp.asarray(op_ok), mask=mask_at_owner,
                     dropped=jnp.asarray(dropped), cap=cap)


def owner_loads(plan: RoutePlan) -> jax.Array:
    """Delivered ops per owner rank, from the plan's occupancy mask —
    the (P,) histogram behind the adaptive layer's skew statistic."""
    return plan.mask.sum(axis=(1, 2)).astype(jnp.int32)


def plan_skew(plan: RoutePlan) -> jax.Array:
    """Batch skew statistic: max owner load / mean owner load (over all P
    owners). 1.0 = perfectly uniform; P = single hot owner. High skew
    serializes RDMA atomics in one owner's apply lane (DESIGN.md §4);
    `adaptive.batch_skew` computes the same statistic host-side from `dst`
    without paying the plan's occupancy exchange."""
    loads = owner_loads(plan).astype(jnp.float32)
    total = jnp.maximum(loads.sum(), 1.0)
    return loads.max() * loads.shape[0] / total


def route_with_plan(plan: RoutePlan, payload: jax.Array,
                    active: Optional[jax.Array] = None,
                    role: str = "req") -> Routed:
    """Issue one payload phase against a precomputed plan: a pure scatter
    (no sort) + ONE exchange.

    active, when given, must be a subset of the plan's valid mask; it is
    ANDed into the plan's occupancy by riding along as one extra payload
    word, so a shrinking probe-loop mask costs no extra exchange. Slot
    assignments are the plan's: inactive ops leave holes instead of
    compacting, which preserves the (src_rank, slot) serialization order of
    the surviving ops — reuse is bit-exact (DESIGN.md §2).
    """
    nranks, n = plan.dst_eff.shape
    cap = plan.cap
    if active is not None:
        payload = jnp.concatenate(
            [payload, active.astype(payload.dtype)[..., None]], axis=-1)

    def scatter_one(dst_eff_r, slot_r, pay_r):
        buf = jnp.zeros((nranks, cap) + pay_r.shape[1:], dtype=pay_r.dtype)
        # mode="drop" discards invalid (dst==nranks) and overflow (slot>=cap)
        return buf.at[dst_eff_r, slot_r].set(pay_r, mode="drop")

    buf = jax.vmap(scatter_one)(plan.dst_eff, plan.op_slot, payload)
    at_owner = exchange(buf, role)                 # (P_owner, P_src, cap, W')
    if active is not None:
        mask = plan.mask & (at_owner[..., -1] != 0)
        at_owner = at_owner[..., :-1]
        op_ok = plan.op_ok & active
    else:
        mask = plan.mask
        op_ok = plan.op_ok
    return Routed(at_owner=at_owner, mask=mask, op_slot=plan.op_slot,
                  op_ok=op_ok, dropped=plan.dropped)


def route(dst: jax.Array, payload: jax.Array, cap: int,
          valid: Optional[jax.Array] = None, role: str = "req") -> Routed:
    """Route op batches from all P origins to their owners (one phase).

    dst:     (P, n) destination ranks
    payload: (P, n, W) payload words
    valid:   (P, n) optional mask

    One-shot path: plan + payload phase fused (the plan is not returned).
    Loops issuing several phases to the same destinations should call
    `make_plan` once and `route_with_plan` per phase instead.
    """
    nranks = dst.shape[0]

    def one(dst_r, pay_r, val_r):
        return bin_by_dest(dst_r, pay_r, nranks, cap, val_r)

    if valid is None:
        valid = jnp.ones(dst.shape, dtype=bool)
    binned = jax.vmap(one)(dst, payload, valid)
    at_owner = exchange(binned.buf, role)          # (P_owner, P_src, cap, W)
    mask = exchange(binned.mask, role + "_mask")   # (P_owner, P_src, cap)
    return Routed(at_owner=at_owner, mask=mask, op_slot=binned.op_slot,
                  op_ok=binned.op_ok, dropped=binned.dropped)


def route_replies(routed: Routed, replies: jax.Array, dst: jax.Array,
                  role: str = "rep") -> jax.Array:
    """Return replies to origins and align them with the original op order.

    replies: (P_owner, P_src, cap, W) — owner-side, aligned with routed.at_owner
    dst:     (P, n) original destination ranks
    returns: (P, n, W) reply words per original op (garbage where ~op_ok)
    """
    back = exchange(replies, role)                 # (P_origin, P_owner, cap, W)

    def gather_one(back_r, dst_r, slot_r):
        return back_r[dst_r, slot_r]               # (n, W)

    return jax.vmap(gather_one)(back, dst, routed.op_slot)


# ---------------------------------------------------------------------------
# Sender-side coalescing (DESIGN.md §6): dedup duplicate (dst, off)
# descriptor rows per origin BEFORE the exchange, so the request exchange,
# the owner apply lanes, and the reply exchange all operate on *distinct*
# rows. The structure is computed locally (one lexsort, ZERO extra
# exchanges); replies fan back out to every duplicate requester via `lead`.
#
# A *run* is a maximal group of ops from one origin that (a) target the
# same (dst, off), (b) agree on every `match` column, and (c) are
# consecutive once the batch is stably sorted by (dst, off). Ops of the
# same origin at the same (dst, off) occupy consecutive serialization
# slots at the owner (interleaved only with commuting other-offset ops),
# so combining a run and shipping one representative row preserves the
# (src_rank, slot) serialization contract bit-exactly; per-op replies are
# reconstructed sender-side (operand prefix for FAOs, the chained-CAS
# formula, the leader's reply for gets/puts).
# ---------------------------------------------------------------------------
def _suffix_min(x: jax.Array) -> jax.Array:
    return jnp.flip(jax.lax.associative_scan(jnp.minimum, jnp.flip(x)))


def _prefix_max(x: jax.Array) -> jax.Array:
    return jax.lax.associative_scan(jnp.maximum, x)


@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=["rep", "leader", "pos", "order", "run_first",
                                "rows_in", "rows_out"],
                   meta_fields=[])
@dataclass
class Coalescing:
    """Duplicate-run structure for one batch (per-origin, sender-side).

    rep:       (P, n) op is its run's representative (first in op order)
    leader:    (P, n) op index (within n) of each op's representative
    pos:       (P, n) rank of the op within its run (0 == rep)
    order:     (P, n) the (dst, off)-stable sort permutation runs live in
    run_first: (P, n) run boundaries, in sorted space
    rows_in:   (P,)   valid rows before combining
    rows_out:  (P,)   representative rows after combining
    """

    rep: jax.Array
    leader: jax.Array
    pos: jax.Array
    order: jax.Array
    run_first: jax.Array
    rows_in: jax.Array
    rows_out: jax.Array

    def dedup_ratio(self) -> jax.Array:
        """Distinct-row fraction rows_out / rows_in over all origins."""
        tot = jnp.maximum(self.rows_in.sum(), 1)
        return self.rows_out.sum().astype(jnp.float32) / tot


def coalesce(dst: jax.Array, off: jax.Array,
             match: Optional[jax.Array] = None,
             valid: Optional[jax.Array] = None) -> Coalescing:
    """Find duplicate runs in a batch of (dst, off[, match]) descriptors.

    dst, off: (P, n) int32; match: optional (P, n, K) extra descriptor
    words that must ALL agree for two rows to share a run (CAS cmp/new,
    fused-descriptor payload words, ...). Invalid ops never join a run.
    Pure local compute — no exchange, one lexsort per origin.
    """
    nranks, n = dst.shape
    if valid is None:
        valid = jnp.ones(dst.shape, dtype=bool)

    def one(dst_r, off_r, match_r, valid_r):
        seq = jnp.arange(n, dtype=jnp.int32)
        dst_eff = jnp.where(valid_r, dst_r, nranks)
        off_eff = jnp.where(valid_r, off_r, -1)
        order = jnp.lexsort((seq, off_eff, dst_eff)).astype(jnp.int32)
        d_s, o_s, v_s = dst_eff[order], off_eff[order], valid_r[order]
        same = ((d_s[1:] == d_s[:-1]) & (o_s[1:] == o_s[:-1])
                & v_s[1:] & v_s[:-1])
        if match_r is not None:
            m_s = match_r[order]
            same = same & jnp.all(m_s[1:] == m_s[:-1], axis=-1)
        run_first = jnp.concatenate([jnp.array([True]), ~same])
        idx = jnp.arange(n, dtype=jnp.int32)
        run_start = _prefix_max(jnp.where(run_first, idx, -1))
        pos_s = idx - run_start
        leader_s = order[run_start]
        rep_s = run_first & v_s
        leader = jnp.zeros((n,), jnp.int32).at[order].set(leader_s)
        pos = jnp.zeros((n,), jnp.int32).at[order].set(pos_s)
        rep = jnp.zeros((n,), bool).at[order].set(rep_s)
        return rep, leader, pos, order, run_first

    if match is None:
        rep, leader, pos, order, run_first = jax.vmap(
            lambda d, o, v: one(d, o, None, v))(dst, off, valid)
    else:
        rep, leader, pos, order, run_first = jax.vmap(one)(
            dst, off, match, valid)
    return Coalescing(rep=rep, leader=leader, pos=pos, order=order,
                      run_first=run_first,
                      rows_in=valid.sum(axis=1).astype(jnp.int32),
                      rows_out=rep.sum(axis=1).astype(jnp.int32))


def lead(co: Coalescing, x: jax.Array) -> jax.Array:
    """Reply fan-out: every op reads its run representative's row of `x`.

    x: (P, n, ...) per-op values (meaningful at representative rows).
    Representatives read their own row; duplicates read their leader's.
    """
    return jax.vmap(lambda xr, lr: xr[lr])(x, co.leader)


def coalesce_fold(co: Coalescing, operand: jax.Array, binop,
                  identity) -> Tuple[jax.Array, jax.Array]:
    """Associative-fold duplicate runs of `operand` (P, n).

    Returns (combined, prefix): `combined` carries each run's total fold at
    its representative row (other rows unchanged — they are never shipped);
    `prefix[i]` is the exclusive fold of the op's EARLIER run members
    (identity at representatives), so per-op old values reconstruct as
    binop(owner_old_at_rep, prefix) — exactly the value each duplicate
    would have fetched had it been shipped separately.
    """
    n = operand.shape[1]

    def one(order, run_first, op_r):
        op_s = op_r[order]

        def comb(a, b):
            af, av = a
            bf, bv = b
            return af | bf, jnp.where(bf, bv, binop(av, bv))

        _, incl = jax.lax.associative_scan(comb, (run_first, op_s))
        ident = jnp.full_like(op_s, identity)
        excl = jnp.where(run_first, ident, jnp.roll(incl, 1))
        idx = jnp.arange(n, dtype=jnp.int32)
        run_last = jnp.concatenate([run_first[1:], jnp.array([True])])
        end = _suffix_min(jnp.where(run_last, idx, n - 1))
        combined_s = jnp.where(run_first, incl[end], op_s)
        combined = jnp.zeros_like(op_r).at[order].set(combined_s)
        prefix = jnp.zeros_like(op_r).at[order].set(excl)
        return combined, prefix

    return jax.vmap(one)(co.order, co.run_first, operand)


def coalesce_last(co: Coalescing, vals: jax.Array) -> jax.Array:
    """Last-writer-wins combine for put payloads: each representative row
    is replaced by the LAST value of its run (what the serialized owner
    apply would have left); other rows are unchanged (never shipped)."""
    n = vals.shape[1]

    def one(order, run_first, vals_r):
        idx = jnp.arange(n, dtype=jnp.int32)
        run_last = jnp.concatenate([run_first[1:], jnp.array([True])])
        end = _suffix_min(jnp.where(run_last, idx, n - 1))
        vals_s = vals_r[order]
        out_s = jnp.where(run_first[:, None], vals_s[end], vals_s)
        return jnp.zeros_like(vals_r).at[order].set(out_s)

    return jax.vmap(one)(co.order, co.run_first, vals)


@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=["plan", "co"], meta_fields=[])
@dataclass
class CoalescedPlan:
    """A RoutePlan whose occupancy covers only duplicate-run
    representatives, plus the Coalescing structure that maps every op to
    its representative. Built ONCE per batch (one plan argsort + one
    coalescing lexsort, still ONE occupancy exchange — coalescing adds
    zero exchanges); probe loops pass their shrinking active mask per
    phase exactly as with a plain plan.

    Contract for reuse across phases: the caller's per-phase active mask
    must be RUN-UNIFORM (a run deactivates as a whole — e.g. the
    hash-table loops, where duplicates adopt their representative's
    outcome). Phase-local coalescing (`coalesce=True` on a window op
    without a CoalescedPlan) recomputes the runs per call and has no such
    requirement.
    """

    plan: RoutePlan
    co: Coalescing

    @property
    def cap(self) -> int:
        return self.plan.cap


def coalesce_plan(dst: jax.Array, off: jax.Array,
                  match: Optional[jax.Array] = None,
                  valid: Optional[jax.Array] = None,
                  cap: Optional[int] = None,
                  role: str = "plan") -> CoalescedPlan:
    """Coalescing + route plan for a batch: runs found on one local
    lexsort, plan occupancy exchanged ONCE for the representative rows
    only — the wire and the owner lanes see distinct rows from the first
    phase on."""
    co = coalesce(dst, off, match=match, valid=valid)
    plan = make_plan(dst, valid=co.rep, cap=cap, role=role)
    return CoalescedPlan(plan=plan, co=co)


def miss_subset_plan(dst: jax.Array, off: jax.Array, hit: Optional[jax.Array],
                     match: Optional[jax.Array] = None,
                     valid: Optional[jax.Array] = None,
                     cap: Optional[int] = None,
                     role: str = "plan") -> CoalescedPlan:
    """`coalesce_plan` restricted to the cache-miss subset (DESIGN.md §8).

    `hit` is the origin-local hot-bucket cache's hit mask for the batch
    (None = no cache consulted — degenerates to `coalesce_plan` exactly).
    Cache hits are carved out of the plan's validity BEFORE the occupancy
    exchange, so the wire and owner lanes see only the misses; because
    `make_plan` shapes its occupancy by the valid mask, the resulting plan
    is bit-identical to one built for a batch that never contained the hit
    rows. Still ONE occupancy exchange; all-hit batches should skip the
    plan entirely (zero exchanges) — the caller's job, since building any
    plan costs the occupancy exchange."""
    if hit is not None:
        hit = jnp.asarray(hit)
        valid = ~hit if valid is None else (jnp.asarray(valid) & ~hit)
    return coalesce_plan(dst, off, match=match, valid=valid, cap=cap,
                         role=role)


def flatten_owner_view(routed: Routed):
    """Flatten an owner's (P_src, cap) request grid into a serialized op list.

    The serialization order (src_rank, slot) is the deterministic order in
    which the owner's "NIC lane" applies conflicting atomics — the analogue
    of NIC arrival-order serialization on Aries.

    returns payload (P_owner, m, W), mask (P_owner, m) with m = P_src*cap.
    """
    p, s, c = routed.mask.shape
    flat = routed.at_owner.reshape(p, s * c, *routed.at_owner.shape[3:])
    mask = routed.mask.reshape(p, s * c)
    return flat, mask


def unflatten_owner_view(flat: jax.Array, p_src: int, cap: int) -> jax.Array:
    p = flat.shape[0]
    return flat.reshape(p, p_src, cap, *flat.shape[2:])
