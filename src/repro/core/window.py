"""PGAS symmetric window + one-sided (RDMA-style) component operations.

A `Window` is the TPU-native analogue of a registered RDMA memory region:
every rank owns row `r` of a `(P, L)` word array. Component ops are batched
per step (see DESIGN.md §2) and each op is ONE network phase:

    rdma_put   — 1 exchange  (origin → owner scatter; completion at phase end)
    rdma_get   — 2 exchanges (request → owner gather → reply)
    rdma_cas   — 2 exchanges (request → serialized apply → old values back)
    rdma_fao   — 2 exchanges (FAA / FOR / FAND / FXOR)

Conflicting atomics at an owner are applied in deterministic (src_rank, slot)
order — the analogue of NIC arrival-order serialization. The vectorized
appliers below implement that order exactly; `kernels/amo_apply.py` is the
TPU hot-path implementation of the same contract and `kernels/ref.py` is the
independently written sequential oracle both are tested against.

Guarantees shared by every `rdma_*` op (the public one-sided API):

- tracer-safe: pure JAX on the array arguments — stage freely under
  `jax.jit` / `vmap` / `scan` (the diagnostic phase log records at trace
  time only, and coalescing stats degrade gracefully under tracing);
- plan reuse (`plan=`, DESIGN.md §2) and sender-side coalescing
  (`coalesce=True`, §6) are bit-exact vs. the plain phase — same
  serialization positions, same visible replies, same window state;
- reply words of invalid/undelivered ops are garbage by contract (callers
  mask with their own valid/delivered flags — the data-structure layer
  converts this to the zeros-when-failed contract of
  tests/test_conformance.py); put completion is phase-end (flush model).
"""
from __future__ import annotations

import contextlib
import functools
import weakref
from dataclasses import dataclass
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import faults as flt
from . import routing
from .types import AmoKind

Array = jax.Array

# ---------------------------------------------------------------------------
# Decision tagging: the adaptive layer (core/adaptive.py) wraps the RDMA
# arms it executes in `decision_scope(dec)`; every routed phase issued
# inside the scope is logged as (role, decision) so benchmarks can attribute
# phases to the arm that issued them. Logging happens at trace time — the
# adaptive layer dispatches arms at the Python level, once per batch. The
# log is a bounded ring (library callers on the default AUTO path never
# drain it; unbounded growth would leak).
# ---------------------------------------------------------------------------
_CURRENT_DECISION = None
# Pipeline slot tagging (DESIGN.md §7): core/pipeline.py wraps each staged
# batch in `slot_scope(slot, seq)` so every routed phase is attributable to
# the in-flight window slot that issued it.
_CURRENT_SLOT: Optional[Tuple[int, int]] = None
# Hot-bucket cache tagging (DESIGN.md §8): the adaptive layer wraps cached
# table ops in `cache_scope(cache)`; publish-capable phases notify the
# active cache of concrete publish flips (precision invalidation) and the
# cache logs fill/invalidate events into the same phase log.
_CURRENT_CACHE = None
# Pipelines with unforced in-flight batches (DESIGN.md §7/§9): while any
# exist, host-side cache maintenance between submits must stay
# opportunistic — core/cache.BucketCache.drain_fills consults this so a
# deferred-fill drain never blocks on an in-flight window's device values
# (which would serialize the very overlap the pipeline exists to create).
# WeakSet: an abandoned pipeline can never wedge the drain into
# non-blocking mode forever.
_INFLIGHT_PIPES: "weakref.WeakSet" = weakref.WeakSet()


def note_pipeline_inflight(pipe, active: bool) -> None:
    """Record whether `pipe` currently holds unforced in-flight batches
    (called by core/pipeline.Pipeline on every in-flight transition)."""
    if active:
        _INFLIGHT_PIPES.add(pipe)
    else:
        _INFLIGHT_PIPES.discard(pipe)


def pipeline_inflight() -> bool:
    """True while ANY pipeline holds unforced in-flight batches."""
    return len(_INFLIGHT_PIPES) > 0
# Explicit bound on the diagnostic ring: phases beyond this are dropped
# oldest-first (library callers on the default AUTO path never drain it).
PHASE_LOG_MAX = 4096
_PHASE_LOG: List[Tuple[str, object, Optional[dict]]] = []


@contextlib.contextmanager
def decision_scope(decision):
    global _CURRENT_DECISION
    prev = _CURRENT_DECISION
    _CURRENT_DECISION = decision
    try:
        yield
    finally:
        _CURRENT_DECISION = prev


@contextlib.contextmanager
def slot_scope(slot: int, seq: int):
    """Tag every phase issued inside the scope with its pipeline slot.

    `slot` is the in-flight window slot (0 .. depth-1, double-buffered at
    the default depth 2); `seq` is the submission sequence number of the
    batch. Entries land in the same bounded phase log as `decision_scope`
    with {"slot", "seq"} merged into the info dict — trace-time only, like
    decision tagging (a jitted batch logs on its first trace)."""
    global _CURRENT_SLOT
    prev = _CURRENT_SLOT
    _CURRENT_SLOT = (int(slot), int(seq))
    try:
        yield
    finally:
        _CURRENT_SLOT = prev


@contextlib.contextmanager
def cache_scope(cache):
    """Make `cache` (core/cache.BucketCache) the active hot-bucket cache.

    Inside the scope, publish-capable phases (`rdma_cas_put_publish`,
    `rdma_cas_put`, FXOR `rdma_fao`) forward concrete (dst, off) flips to
    `cache.on_publish` — the precision invalidation channel; the cache
    itself logs cache_fill / cache_hit / cache_invalidate events into the
    phase log via `log_cache_event`. Cache hits issue NO phases — the
    zero-exchange property tests/test_cache.py pins."""
    global _CURRENT_CACHE
    prev = _CURRENT_CACHE
    _CURRENT_CACHE = cache
    try:
        yield
    finally:
        _CURRENT_CACHE = prev


def log_cache_event(role: str, info: Optional[dict] = None) -> None:
    """Log one cache event into the phase log (same tagging rules as
    `_route_phase`: only while a decision/slot scope is active). Cache
    events are NOT network phases — diagnostics count exchanges by the
    routing hook, so these entries never inflate phase counts."""
    if _CURRENT_DECISION is None and _CURRENT_SLOT is None:
        return
    merged = dict(info or {})
    if _CURRENT_SLOT is not None:
        merged["slot"], merged["seq"] = _CURRENT_SLOT
    _PHASE_LOG.append((role, _CURRENT_DECISION, merged or None))
    if len(_PHASE_LOG) > PHASE_LOG_MAX:
        del _PHASE_LOG[:-PHASE_LOG_MAX]


def _notify_publish(dst: Array, off: Array,
                    valid: Optional[Array]) -> None:
    """Forward a publish flip to the active cache (no-op without one).
    Tracer args degrade inside on_publish to the conservative channel."""
    if _CURRENT_CACHE is not None:
        _CURRENT_CACHE.on_publish(dst, off, valid)


def drain_phase_log() -> List[Tuple[str, object, Optional[dict]]]:
    """Return and clear the (role, decision, info) log of tagged phases.

    Phases are logged while a `decision_scope` and/or a `slot_scope` is
    active. `info` is None for plain uncoalesced phases; coalesced phases
    record the sender-side combining stats {"coalesced": True, "rows_in",
    "rows_out", "dedup_ratio"} when the batch is concrete (host-side ints;
    absent under jit tracing, where only {"coalesced": True} is recorded),
    and phases issued inside a pipeline slot additionally carry
    {"slot": int, "seq": int} (DESIGN.md §7)."""
    out = list(_PHASE_LOG)
    _PHASE_LOG.clear()
    return out


def _coalesce_info(co: Optional[routing.Coalescing]) -> Optional[dict]:
    if co is None:
        return None
    try:
        import numpy as np
        ri = int(np.asarray(co.rows_in).sum())
        ro = int(np.asarray(co.rows_out).sum())
    except Exception:  # tracers: stats stay on-device
        return {"coalesced": True}
    return {"coalesced": True, "rows_in": ri, "rows_out": ro,
            "dedup_ratio": ro / max(ri, 1)}


@functools.partial(jax.tree_util.register_dataclass, data_fields=["data"],
                   meta_fields=[])
@dataclass
class Window:
    """Symmetric PGAS window: rank r owns data[r]. Word-addressed."""

    data: Array  # (P, L)

    @property
    def nranks(self) -> int:
        return self.data.shape[0]

    @property
    def local_size(self) -> int:
        return self.data.shape[1]


def make_window(nranks: int, local_size: int, dtype=jnp.int32,
                fill=0) -> Window:
    return Window(data=jnp.full((nranks, local_size), fill, dtype=dtype))


# ---------------------------------------------------------------------------
# Owner-side appliers (shard-local, vmapped over owners).
# All take a *serialized* op list: ops earlier in the list happen first.
# ---------------------------------------------------------------------------
def _segmented_combine(off_sorted, vals_sorted, init_vals, binop, identity):
    """Segmented exclusive scan over same-offset groups (sorted by offset).

    Returns (old_per_op_sorted, final_value_per_group_positions, is_last).
    old_i = init ⊕ (operands of earlier ops at the same offset).
    """
    n = off_sorted.shape[0]
    is_first = jnp.concatenate([jnp.array([True]),
                                off_sorted[1:] != off_sorted[:-1]])
    is_last = jnp.concatenate([off_sorted[1:] != off_sorted[:-1],
                               jnp.array([True])])

    # Segmented inclusive scan via associative_scan on (reset_flag, value).
    def combine(a, b):
        a_flag, a_val = a
        b_flag, b_val = b
        out_val = jnp.where(b_flag, b_val, binop(a_val, b_val))
        return a_flag | b_flag, out_val

    _, incl = jax.lax.associative_scan(combine, (is_first, vals_sorted))
    ident = jnp.full_like(vals_sorted, identity)
    excl = jnp.where(is_first, ident, jnp.roll(incl, 1))
    old = binop(init_vals, excl)
    final = binop(init_vals, incl)
    return old, final, is_last


_FAO_BINOPS = {
    int(AmoKind.FAA): (lambda a, b: a + b, 0),
    int(AmoKind.FOR): (lambda a, b: a | b, 0),
    int(AmoKind.FAND): (lambda a, b: a & b, -1),
    int(AmoKind.FXOR): (lambda a, b: a ^ b, 0),
}


def apply_fao_local(local: Array, off: Array, operand: Array, mask: Array,
                    kind: int) -> Tuple[Array, Array]:
    """Apply a homogeneous batch of fetch-and-op atomics to a local shard.

    local: (L,), off/operand/mask: (m,) in serialized order.
    Returns (old_per_op, new_local). Masked ops are no-ops returning 0.
    """
    L = local.shape[0]
    binop, identity = _FAO_BINOPS[int(kind)]
    ident = jnp.asarray(identity, dtype=local.dtype)
    off_eff = jnp.where(mask, off, L)  # sentinel → dropped by scatter
    operand_eff = jnp.where(mask, operand, ident)
    seq = jnp.arange(off.shape[0])
    order = jnp.lexsort((seq, off_eff))
    off_s, op_s = off_eff[order], operand_eff[order]
    init_vals = local.at[off_s].get(mode="fill", fill_value=0)
    old_s, final_s, is_last = _segmented_combine(off_s, op_s, init_vals,
                                                 binop, ident)
    new_local = local.at[jnp.where(is_last, off_s, L)].set(final_s,
                                                           mode="drop")
    old = jnp.zeros_like(old_s).at[order].set(old_s)
    return jnp.where(mask, old, 0), new_local


def apply_cas_local(local: Array, off: Array, cmp: Array, new: Array,
                    mask: Array) -> Tuple[Array, Array]:
    """Serialized batch of CAS ops against a local shard.

    Exact chained semantics (op k sees the value left by ops <k at the same
    offset) via a length-m sequential scan — the XLA analogue of the NIC's
    serialized AMO pipeline. m is small (P*cap); the TPU hot path is the
    amo_apply Pallas kernel.
    """
    L = local.shape[0]
    m = off.shape[0]
    off_eff = jnp.where(mask, off, L)
    seq = jnp.arange(m)
    order = jnp.lexsort((seq, off_eff))
    off_s, cmp_s, new_s = off_eff[order], cmp[order], new[order]
    is_first = jnp.concatenate([jnp.array([True]), off_s[1:] != off_s[:-1]])
    init_vals = local.at[off_s].get(mode="fill", fill_value=0)

    def step(carry, x):
        prev_val = carry
        first, init_v, c, nw = x
        cur = jnp.where(first, init_v, prev_val)
        nxt = jnp.where(cur == c, nw, cur)
        return nxt, (cur, nxt)

    _, (old_s, val_s) = jax.lax.scan(step, jnp.zeros((), local.dtype),
                                     (is_first, init_vals, cmp_s, new_s))
    is_last = jnp.concatenate([off_s[1:] != off_s[:-1], jnp.array([True])])
    new_local = local.at[jnp.where(is_last, off_s, L)].set(val_s, mode="drop")
    old = jnp.zeros_like(old_s).at[order].set(old_s)
    return jnp.where(mask, old, 0), new_local


def apply_put_local(local: Array, off: Array, vals: Array,
                    mask: Array) -> Array:
    """Last-writer-wins vector puts. off addresses word 0 of a V-word row."""
    L = local.shape[0]
    m, V = vals.shape
    off_eff = jnp.where(mask, off, L)
    seq = jnp.arange(m)
    order = jnp.lexsort((seq, off_eff))
    off_s, vals_s = off_eff[order], vals[order]
    is_last = jnp.concatenate([off_s[1:] != off_s[:-1], jnp.array([True])])
    row = jnp.where(is_last, off_s, L)[:, None] + jnp.arange(V)[None, :]
    return local.at[row].set(vals_s, mode="drop")


def gather_local(local: Array, off: Array, width: int) -> Array:
    idx = off[:, None] + jnp.arange(width)[None, :]
    return local.at[idx].get(mode="fill", fill_value=0)


# ---------------------------------------------------------------------------
# One-sided phases (the public RDMA-style API).
#
# Every phase accepts an optional precomputed RoutePlan (routing.make_plan):
# probe loops that issue `max_probes + 2` phases to fixed destinations build
# ONE plan per batch and each phase becomes a pure scatter + one exchange,
# with the (possibly shrinking) `valid` mask ANDed into the plan occupancy —
# bit-exact reuse (DESIGN.md §2).
# ---------------------------------------------------------------------------
def _default_cap(dst: Array, cap: Optional[int]) -> int:
    return dst.shape[1] if cap is None else cap


def _phase_info(co: Optional[routing.Coalescing]) -> Optional[dict]:
    """Info dict for one logged phase: coalescing stats + pipeline slot."""
    info = _coalesce_info(co)
    if _CURRENT_SLOT is not None:
        info = dict(info or {})
        info["slot"], info["seq"] = _CURRENT_SLOT
    return info


def _route_phase(dst: Array, payload: Array, cap: int,
                 valid: Optional[Array],
                 plan: Optional[routing.RoutePlan],
                 role: str,
                 co: Optional[routing.Coalescing] = None) -> routing.Routed:
    if _CURRENT_DECISION is not None or _CURRENT_SLOT is not None:
        _PHASE_LOG.append((role, _CURRENT_DECISION, _phase_info(co)))
        if len(_PHASE_LOG) > PHASE_LOG_MAX:
            del _PHASE_LOG[:-PHASE_LOG_MAX]
    plane = flt.active_plane()
    if plane is not None:
        # DESIGN.md §10: the fault plane simulates wire loss + origin
        # retransmit + owner dedup INSIDE this phase; rows that never
        # deliver are masked out of the effective valid (`valid` comes
        # back unchanged when every row survives — the common case).
        valid = plane.inject_phase(role, dst, valid)
    if plan is None:
        return routing.route(dst, payload, cap, valid, role=role)
    # valid=None -> active=None: reuse the plan occupancy as-is instead of
    # shipping an all-ones activity word
    return routing.route_with_plan(plan, payload, active=valid, role=role)


def _coalesce_for(plan, coalesce: bool, dst: Array, off: Array,
                  match: Optional[Array], valid: Optional[Array]):
    """Resolve the coalescing structure for one phase (DESIGN.md §6).

    plan may be a RoutePlan, a CoalescedPlan (its precomputed runs are
    reused — caller guarantees the active mask is run-uniform), or None.
    coalesce=True without a CoalescedPlan computes fresh runs for THIS
    phase (one local lexsort, zero exchanges) — exact under any mask.
    Returns (base_plan, co, eff_valid) where eff_valid restricts the
    phase to representative rows."""
    if isinstance(plan, routing.CoalescedPlan):
        co, plan = plan.co, plan.plan
    elif coalesce:
        co = routing.coalesce(dst, off, match=match, valid=valid)
    else:
        return plan, None, valid
    eff = co.rep if valid is None else (valid & co.rep)
    return plan, co, eff


def rdma_put(win: Window, dst: Array, off: Array, vals: Array,
             valid: Optional[Array] = None, cap: Optional[int] = None,
             plan: Optional[routing.RoutePlan] = None,
             coalesce: bool = False) -> Window:
    """One-sided put: vals (P, n, V) written at word offsets off on rank dst.

    ONE network phase. Completion semantics: remote-complete at phase end
    (the paper's put is likewise only guaranteed complete at the next flush).
    coalesce=True dedups duplicate (dst, off) rows sender-side
    (last-writer-wins — bit-exact, DESIGN.md §6).
    """
    plan, co, eff_valid = _coalesce_for(plan, coalesce, dst, off, None,
                                        valid)
    cap = plan.cap if plan is not None else _default_cap(dst, cap)
    V = vals.shape[-1]
    vals = vals.astype(jnp.int32)
    if co is not None:
        vals = routing.coalesce_last(co, vals)
    payload = jnp.concatenate([off[..., None].astype(jnp.int32), vals],
                              axis=-1)
    routed = _route_phase(dst, payload, cap, eff_valid, plan, role="put",
                          co=co)
    flat, mask = routing.flatten_owner_view(routed)
    offs, vwords = flat[..., 0], flat[..., 1:1 + V]
    new_data = jax.vmap(apply_put_local)(win.data, offs, vwords, mask)
    return Window(data=new_data)


def rdma_get(win: Window, dst: Array, off: Array, width: int,
             valid: Optional[Array] = None, cap: Optional[int] = None,
             plan: Optional[routing.RoutePlan] = None,
             coalesce: bool = False) -> Array:
    """One-sided get of `width` words: TWO exchanges (request, data back).

    coalesce=True probes each duplicate (dst, off) ONCE and fans the reply
    out to every duplicate requester (bit-exact, DESIGN.md §6)."""
    plan, co, eff_valid = _coalesce_for(plan, coalesce, dst, off, None,
                                        valid)
    cap = plan.cap if plan is not None else _default_cap(dst, cap)
    payload = off[..., None].astype(jnp.int32)
    routed = _route_phase(dst, payload, cap, eff_valid, plan, role="get",
                          co=co)
    flat, mask = routing.flatten_owner_view(routed)

    def owner_gather(local, offs, m):
        vals = gather_local(local, offs, width)
        return jnp.where(m[:, None], vals, 0)

    vals = jax.vmap(owner_gather)(win.data, flat[..., 0], mask)
    replies = routing.unflatten_owner_view(vals, win.nranks, cap)
    out = routing.route_replies(routed, replies, dst, role="get_rep")
    if co is not None:
        out = routing.lead(co, out)
    return out


def _use_kernel_lane() -> bool:
    """Route the owner-side AMO apply through the Pallas `amo_apply` kernel
    (the TPU hot path) instead of the vectorized XLA appliers above. Both
    implement the same serialized contract; tests assert equivalence."""
    from .. import kernels  # local import: kernels never imports core
    return kernels.ops.use_pallas_default()


def _kernel_amo(data: Array, flat: Array, mask: Array, kind: int,
                a_col: int, b_col: Optional[int]) -> Tuple[Array, Array]:
    from ..kernels import ops as kops
    m = flat.shape[1]
    zeros = jnp.zeros((data.shape[0], m), jnp.int32)
    ops_arr = jnp.stack(
        [flat[..., 0],
         jnp.full_like(zeros, int(kind)),
         flat[..., a_col],
         flat[..., b_col] if b_col is not None else zeros], axis=-1)
    return kops.amo_apply(data, ops_arr, mask, use_pallas=True)


def rdma_fao(win: Window, dst: Array, off: Array, operand: Array,
             kind: AmoKind, valid: Optional[Array] = None,
             cap: Optional[int] = None,
             plan: Optional[routing.RoutePlan] = None,
             coalesce: bool = False) -> Tuple[Array, Window]:
    """Fetch-and-op (FAA/FOR/FAND/FXOR): TWO exchanges, serialized apply.

    coalesce=True combines duplicate (dst, off) runs sender-side
    (operand fold) and reconstructs each duplicate's fetched value from
    the representative's reply plus its exclusive operand prefix —
    bit-exact with the uncoalesced serialized apply (DESIGN.md §6)."""
    if int(kind) == int(AmoKind.FXOR):
        _notify_publish(dst, off, valid)
    operand = jnp.broadcast_to(jnp.asarray(operand, jnp.int32), off.shape)
    plan, co, eff_valid = _coalesce_for(plan, coalesce, dst, off, None,
                                        valid)
    cap = plan.cap if plan is not None else _default_cap(dst, cap)
    binop, identity = _FAO_BINOPS[int(kind)]
    if co is not None:
        operand_wire, prefix = routing.coalesce_fold(co, operand, binop,
                                                     identity)
    else:
        operand_wire = operand
    payload = jnp.stack([off.astype(jnp.int32), operand_wire], axis=-1)
    routed = _route_phase(dst, payload, cap, eff_valid, plan, role="fao",
                          co=co)
    flat, mask = routing.flatten_owner_view(routed)

    def owner_apply(local, p, m):
        return apply_fao_local(local, p[:, 0], p[:, 1], m, int(kind))

    if _use_kernel_lane():
        old_flat, new_data = _kernel_amo(win.data, flat, mask, int(kind),
                                         a_col=1, b_col=None)
    else:
        old_flat, new_data = jax.vmap(owner_apply)(win.data, flat, mask)
    replies = routing.unflatten_owner_view(old_flat[..., None], win.nranks,
                                           cap)
    old = routing.route_replies(routed, replies, dst, role="fao_rep")[..., 0]
    if co is not None:
        old = binop(routing.lead(co, old), prefix)
    return old, Window(data=new_data)


def rdma_cas(win: Window, dst: Array, off: Array, cmp: Array, new: Array,
             valid: Optional[Array] = None, cap: Optional[int] = None,
             plan: Optional[routing.RoutePlan] = None,
             coalesce: bool = False) -> Tuple[Array, Window]:
    """Compare-and-swap: TWO exchanges, serialized chained apply.

    coalesce=True ships one representative per run of IDENTICAL
    (dst, off, cmp, new) rows; duplicates short-circuit sender-side with
    the chained outcome (rep won -> they see `new`; rep lost -> they see
    the same old) — bit-exact (DESIGN.md §6)."""
    cmp = jnp.broadcast_to(jnp.asarray(cmp, jnp.int32), off.shape)
    new = jnp.broadcast_to(jnp.asarray(new, jnp.int32), off.shape)
    match = jnp.stack([cmp, new], axis=-1)
    plan, co, eff_valid = _coalesce_for(plan, coalesce, dst, off, match,
                                        valid)
    cap = plan.cap if plan is not None else _default_cap(dst, cap)
    payload = jnp.stack([off.astype(jnp.int32), cmp, new], axis=-1)
    routed = _route_phase(dst, payload, cap, eff_valid, plan, role="cas",
                          co=co)
    flat, mask = routing.flatten_owner_view(routed)

    def owner_apply(local, p, m):
        return apply_cas_local(local, p[:, 0], p[:, 1], p[:, 2], m)

    if _use_kernel_lane():
        old_flat, new_data = _kernel_amo(win.data, flat, mask,
                                         int(AmoKind.CAS), a_col=1, b_col=2)
    else:
        old_flat, new_data = jax.vmap(owner_apply)(win.data, flat, mask)
    replies = routing.unflatten_owner_view(old_flat[..., None], win.nranks,
                                           cap)
    old = routing.route_replies(routed, replies, dst, role="cas_rep")[..., 0]
    if co is not None:
        old_l = routing.lead(co, old)
        old = jnp.where(co.pos == 0, old_l,
                        jnp.where(old_l == cmp, new, old_l))
    return old, Window(data=new_data)


# ---------------------------------------------------------------------------
# Fused component phases (DESIGN.md §2): composite one-phase remote ops in
# the style of Storm's composite RTTs / Active Access compound descriptors.
# Descriptor layout [off | kind | a | b | aux0 | aux1 | vals...]. The owner
# applies the batch in SUB-PHASE order — atomics, compound puts, publish
# flips, phase-end gathers, each serialized in (src_rank, slot) order —
# i.e. exactly the order the unfused engine's separate phases would apply,
# so fusion saves exchanges without changing observable state. The XLA lane
# below composes the existing vectorized appliers per sub-phase; the Pallas
# lane (kernels/ops.fused_apply) implements the same spec.
# ---------------------------------------------------------------------------
def _scatter_rows(local: Array, base: Array, vals: Array,
                  mask: Array) -> Array:
    """Scatter V-word rows at `base`, dropped whole when out of range.
    Rows must be mutually disjoint (the caller's contract) — with no
    overlaps a plain scatter IS the serialized last-writer-wins apply."""
    L = local.shape[0]
    V = vals.shape[-1]
    ok = mask & (base >= 0) & (base <= L - V)
    row = jnp.where(ok, base, L)[:, None] + jnp.arange(V)[None, :]
    return local.at[row].set(vals, mode="drop")


def apply_cas_put_local(local: Array, off: Array, cmp: Array, new: Array,
                        put_off: Array, vals: Array, flip: Array,
                        mask: Array) -> Tuple[Array, Array]:
    """Vectorized owner apply for a CAS_PUT / CAS_PUT_PUB batch — the fused
    hot path, ONE stable sort total (the seed path pays one per sub-phase):

      1. chained CAS sub-phase in serialized order (sorted-segment scan);
      2. winners' puts as one disjoint-row scatter (dropped whole when out
         of range);
      3. publish flips folded into the flag scatter: the post-CAS value at
         each offset XOR the winners' flips (XOR order is immaterial).

    flip=0 rows are plain CAS_PUT. Returns (old, local').

    Preconditions (engine batches satisfy them by construction: new != cmp
    so at most one winner per offset, winners claim distinct slots, put
    rows are record words while CAS/flip targets are flag words): winners'
    put rows are mutually disjoint and never cover other descriptors'
    `off` words. The generic lanes (kernels/ref.fused_apply, the Pallas
    kernel) are the spec for adversarial overlaps."""
    L, V = local.shape[0], vals.shape[-1]
    m = off.shape[0]
    off_eff = jnp.where(mask, off, L)
    order = jnp.argsort(off_eff, stable=True)
    off_s, cmp_s, new_s = off_eff[order], cmp[order], new[order]
    is_first = jnp.concatenate([jnp.array([True]), off_s[1:] != off_s[:-1]])
    is_last = jnp.concatenate([off_s[1:] != off_s[:-1], jnp.array([True])])
    init_vals = local.at[off_s].get(mode="fill", fill_value=0)

    def step(carry, x):
        prev_val = carry
        first, init_v, c, nw = x
        cur = jnp.where(first, init_v, prev_val)
        nxt = jnp.where(cur == c, nw, cur)
        return nxt, (cur, nxt)

    _, (old_s, val_s) = jax.lax.scan(step, jnp.zeros((), local.dtype),
                                     (is_first, init_vals, cmp_s, new_s))
    win_s = old_s == cmp_s

    # publish flips: segmented XOR of winners' flips, folded into the final
    # flag value at each offset's last slot
    flip_contrib = jnp.where(win_s, flip[order], 0)

    def seg_xor(a, b):
        a_flag, a_val = a
        b_flag, b_val = b
        return a_flag | b_flag, jnp.where(b_flag, b_val, a_val ^ b_val)

    _, xor_incl = jax.lax.associative_scan(seg_xor, (is_first, flip_contrib))
    flag_final = val_s ^ xor_incl
    new_local = local.at[jnp.where(is_last, off_s, L)].set(flag_final,
                                                           mode="drop")

    old = jnp.zeros_like(old_s).at[order].set(old_s)
    old = jnp.where(mask, old, 0)
    win = mask & (old == cmp)
    new_local = _scatter_rows(new_local, put_off, vals, win)
    return old, new_local


def apply_fao_get_local(local: Array, off: Array, operand: Array, kind: int,
                        get_off: Array, width: int, mask: Array
                        ) -> Tuple[Array, Array, Array]:
    """Vectorized owner apply for a FAO_GET batch: serialized fetch-and-op
    sub-phase (one stable sort + segmented combine), then a phase-end
    gather of `width` words from get_off.
    Returns (old, gathered (m, width), local')."""
    L = local.shape[0]
    binop, identity = _FAO_BINOPS[int(kind)]
    ident = jnp.asarray(identity, dtype=local.dtype)
    off_eff = jnp.where(mask, off, L)
    operand_eff = jnp.where(mask, operand, ident)
    order = jnp.argsort(off_eff, stable=True)
    off_s, op_s = off_eff[order], operand_eff[order]
    init_vals = local.at[off_s].get(mode="fill", fill_value=0)
    old_s, final_s, is_last = _segmented_combine(off_s, op_s, init_vals,
                                                 binop, ident)
    new_local = local.at[jnp.where(is_last, off_s, L)].set(final_s,
                                                           mode="drop")
    old = jnp.zeros_like(old_s).at[order].set(old_s)
    rec = gather_local(new_local, get_off, width)
    return (jnp.where(mask, old, 0), jnp.where(mask[:, None], rec, 0),
            new_local)


def _fused_phase(win: Window, dst: Array, desc: Array, reply_width: int,
                 valid: Optional[Array], cap: Optional[int],
                 plan: Optional[routing.RoutePlan], role: str,
                 xla_apply,
                 co: Optional[routing.Coalescing] = None
                 ) -> Tuple[Array, Window]:
    """Route one fused-descriptor phase and apply it at the owners.

    xla_apply(data, flat, mask) -> (reply_flat, data') is the vectorized
    XLA owner lane for this (homogeneous) descriptor batch; the Pallas lane
    goes through the generic kernels/ops.fused_apply. When `co` is given,
    `valid` must already be restricted to representative rows; the raw
    reply is fanned out to every duplicate requester (per-op fixups are
    the caller's job)."""
    cap = plan.cap if plan is not None else _default_cap(dst, cap)
    routed = _route_phase(dst, desc, cap, valid, plan, role=role, co=co)
    flat, mask = routing.flatten_owner_view(routed)
    if _use_kernel_lane():
        from ..kernels import ops as kops
        reply_flat, new_data = kops.fused_apply(
            win.data, flat, mask, reply_width=reply_width, use_pallas=True)
    else:
        reply_flat, new_data = xla_apply(win.data, flat, mask)
    replies = routing.unflatten_owner_view(reply_flat, win.nranks, cap)
    out = routing.route_replies(routed, replies, dst, role=role + "_rep")
    if co is not None:
        out = routing.lead(co, out)
    return out, Window(data=new_data)


def _desc(off: Array, kind: int, a: Array, b: Array, aux0: Array,
          aux1: Array, vals: Optional[Array]) -> Array:
    cols = [off.astype(jnp.int32),
            jnp.full(off.shape, int(kind), jnp.int32),
            jnp.broadcast_to(jnp.asarray(a, jnp.int32), off.shape),
            jnp.broadcast_to(jnp.asarray(b, jnp.int32), off.shape),
            jnp.broadcast_to(jnp.asarray(aux0, jnp.int32), off.shape),
            jnp.broadcast_to(jnp.asarray(aux1, jnp.int32), off.shape)]
    head = jnp.stack(cols, axis=-1)
    if vals is None:
        return head
    return jnp.concatenate([head, vals.astype(jnp.int32)], axis=-1)


def _cas_put_xla_apply(data, flat, mask):
    V = flat.shape[-1] - 6

    def one(local, p, m):
        old, local2 = apply_cas_put_local(
            local, p[:, 0], p[:, 2], p[:, 3], p[:, 4], p[:, 6:6 + V],
            p[:, 5], m)
        return old[:, None], local2

    return jax.vmap(one)(data, flat, mask)


def rdma_cas_put(win: Window, dst: Array, off: Array, cmp: Array, new: Array,
                 put_off: Array, vals: Array,
                 valid: Optional[Array] = None, cap: Optional[int] = None,
                 plan: Optional[routing.RoutePlan] = None,
                 coalesce: bool = False) -> Tuple[Array, Window]:
    """Fused claim + record write: CAS(cmp->new) at `off`; on success the
    V-word `vals` row lands at `put_off` — ONE request phase + reply (the
    C_W insert's probes×A_CAS + W collapsed into probes×A_CAS_PUT).
    Returns (old-at-off, win').

    coalesce=True dedups runs of IDENTICAL descriptors (first-wins: one
    claim ships, duplicates short-circuit with the chained outcome)."""
    _notify_publish(dst, off, valid)
    desc = _desc(off, AmoKind.CAS_PUT, cmp, new, put_off, 0, vals)
    plan, co, eff_valid = _coalesce_for(plan, coalesce, dst, off,
                                        desc[..., 2:], valid)
    old, win2 = _fused_phase(win, dst, desc, 1, eff_valid, cap, plan,
                             role="cas_put", xla_apply=_cas_put_xla_apply,
                             co=co)
    old = old[..., 0]
    if co is not None:
        old = jnp.where(co.pos == 0, old,
                        jnp.where(old == desc[..., 2], desc[..., 3], old))
    return old, win2


def rdma_cas_put_publish(win: Window, dst: Array, off: Array, cmp: Array,
                         new: Array, put_off: Array, vals: Array,
                         flip: Array, valid: Optional[Array] = None,
                         cap: Optional[int] = None,
                         plan: Optional[routing.RoutePlan] = None,
                         coalesce: bool = False) -> Tuple[Array, Window]:
    """Fused claim + record write + publish: CAS(cmp->new) at `off`; on
    success write `vals` at `put_off` and flip mem[off] ^= `flip` — the
    C_RW insert's three logical ops (A_CAS + W + A_FAO) in TWO exchanges.
    Returns (old-at-off, win').

    coalesce=True dedups runs of IDENTICAL descriptors: one claim (and one
    publish flip) ships per run, duplicates short-circuit with the chained
    outcome sender-side (DESIGN.md §6)."""
    _notify_publish(dst, off, valid)
    desc = _desc(off, AmoKind.CAS_PUT_PUB, cmp, new, put_off, flip, vals)
    plan, co, eff_valid = _coalesce_for(plan, coalesce, dst, off,
                                        desc[..., 2:], valid)
    old, win2 = _fused_phase(win, dst, desc, 1, eff_valid, cap, plan,
                             role="cas_put_pub",
                             xla_apply=_cas_put_xla_apply, co=co)
    old = old[..., 0]
    if co is not None:
        old = jnp.where(co.pos == 0, old,
                        jnp.where(old == desc[..., 2], desc[..., 3], old))
    return old, win2


def rdma_fao_get(win: Window, dst: Array, off: Array, operand: Array,
                 kind: AmoKind, get_off: Array, width: int,
                 valid: Optional[Array] = None, cap: Optional[int] = None,
                 plan: Optional[routing.RoutePlan] = None,
                 coalesce: bool = False) -> Tuple[Array, Array, Window]:
    """Fused fetch-and-op + gather: apply FAO(`operand`, `kind`) at `off`
    and return `width` words from `get_off` in the SAME request/reply pair —
    the C_RW find's read-lock + record get (A_FAO + R, 4 exchanges) in 2.
    The gather is a phase-end snapshot (it observes every atomic in the
    batch, like the unfused engine's trailing get phase would).
    Returns (old-at-off, gathered (P, n, width), win').

    coalesce=True combines duplicate (dst, off, get_off) runs: the shipped
    descriptor carries the folded operand, duplicates reconstruct their
    fetched value from the representative's reply + their operand prefix
    and share the (phase-end) gathered record — bit-exact."""
    assert int(kind) in (int(AmoKind.FAA), int(AmoKind.FOR),
                         int(AmoKind.FAND), int(AmoKind.FXOR))
    if int(kind) == int(AmoKind.FXOR):
        _notify_publish(dst, off, valid)
    operand = jnp.broadcast_to(jnp.asarray(operand, jnp.int32), off.shape)
    get_off_b = jnp.broadcast_to(jnp.asarray(get_off, jnp.int32), off.shape)
    match = get_off_b[..., None]
    plan, co, eff_valid = _coalesce_for(plan, coalesce, dst, off, match,
                                        valid)
    binop, identity = _FAO_BINOPS[int(kind)]
    if co is not None:
        operand_wire, prefix = routing.coalesce_fold(co, operand, binop,
                                                     identity)
    else:
        operand_wire = operand
    desc = _desc(off, AmoKind.FAO_GET, operand_wire, int(kind), get_off, 0,
                 None)

    def xla_apply(data, flat, mask):
        def one(local, p, m):
            old, rec, local2 = apply_fao_get_local(
                local, p[:, 0], p[:, 2], int(kind), p[:, 4], width, m)
            return jnp.concatenate([old[:, None], rec], axis=1), local2

        return jax.vmap(one)(data, flat, mask)

    reply, win2 = _fused_phase(win, dst, desc, 1 + width, eff_valid, cap,
                               plan, role="fao_get", xla_apply=xla_apply,
                               co=co)
    old = reply[..., 0]
    if co is not None:
        old = binop(old, prefix)
    return old, reply[..., 1:], win2
