"""Hot-bucket caching tier for the distributed hash table (DESIGN.md §8).

The paper's zipfian benches concentrate find traffic on a few hot keys;
coalescing (DESIGN.md §6) collapses duplicates *within* a batch but every
batch still pays the wire round trip. This tier keeps the hot buckets
effectively local (the Storm / Active-Access move): each origin rank holds
a small cache of records it has previously fetched, validated by *version
tags* that are bumped — host-side, zero extra exchanges — whenever a write
could touch the bucket.

Coherence protocol
------------------
* `versions` is a per-(owner, slot) monotonic counter. Every cached entry
  stores the version it observed at fill time; a lookup whose stored
  version no longer matches is a *stale eviction* (counted, entry dropped).
* Writers bump versions through two channels:
  - `on_insert_keys` (the authoritative path): the structure layer calls
    it before ANY insert arm executes — AM insert-or-assign included — and
    it bumps the whole probe window [(start+j) % nslots, j < max_probes]
    of every written key (a conservative superset: the exact claimed slot
    only resolves on-device inside the probe loop).
  - `on_publish` (the precision path): eager concrete publish flips
    (`window.rdma_cas_put_publish` / the unfused FXOR publish) notify the
    cache inside `window.cache_scope`, bumping the exact flipped slot.
    Inside `jax.lax.while_loop` probe bodies the offsets are tracers, so
    this channel degrades to a no-op there — which is exactly why
    `on_insert_keys` is the authoritative channel. Double bumps are
    harmless (versions only need to move, not count).
* Tracer keys on the write path (a jitted insert) bump EVERYTHING
  (`invalidate_all`) — correct, never fast.
* Only POSITIVE entries are cached (records found READY with a matching
  key). Negative caching would require invalidating on every claim; the
  publish-based protocol only has to watch value-visibility events.

Deferred fills
--------------
Fill values come back as device arrays; materializing them at fill time
would serialize the §7 pipeline (staging must never read a device value).
Fills are therefore enqueued with a snapshot of the global `write_tick`
and drained later — immediately when not staging inside a pipeline slot,
opportunistically (only already-`is_ready()` arrays) when staging. A fill
whose snapshot tick no longer matches `write_tick` raced with a writer
and is dropped (conservative: a dropped fill is a future miss, never a
stale hit). A fill that survives the tick check saw no intervening write,
so stamping it with the CURRENT version table is exact.

Storage is per-origin and set-associative (`capacity` entries per origin
in `capacity / ways` sets, vectorized numpy — a lookup is a handful of
fancy-indexing ops, not a Python loop). Associativity matters more here
than in a hardware cache: a single persistent conflict miss makes its
batch non-all-hit forever, and a non-all-hit batch pays the FULL probe
phase loop (exchanges are per phase, not per row) — so two hot keys
sharing a direct-mapped line would erase the entire tier's win. With a
few ways, hot keys coexist; colliding cold keys round-robin-evict each
other, which is the right failure mode for the zipfian workloads this
tier exists for.

The cache object is host state, shared by reference — it is NOT part of
any jit-traced pytree. Coherence is guaranteed for writes issued through
the owning `adaptive.AdaptiveEngine` (or any caller disciplined enough to
call `on_insert_keys` before writing); one cache serves exactly ONE
table (attach a fresh cache per DHashTable).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

# Tag sentinel for an empty line. Keys are int32; the tag array is int64 so
# no valid key can collide with the sentinel.
_EMPTY_TAG = np.int64(1) << 40


def _concrete(x) -> Optional[np.ndarray]:
    """Host value of `x`, or None under jit tracing."""
    if x is None:
        return None
    try:
        return np.asarray(x)
    except Exception:  # TracerArrayConversionError and friends
        return None


def _is_ready(x) -> bool:
    fn = getattr(x, "is_ready", None)
    return True if fn is None else bool(fn())


@dataclass
class CacheLookup:
    """Host-side result of one batch lookup (all numpy)."""

    hit: np.ndarray        # (P, n) bool — fresh positive entry
    vals: np.ndarray       # (P, n, vw) int32 — zeros where miss
    keys: np.ndarray       # (P, n) int32 — the concrete batch keys
    valid: np.ndarray      # (P, n) bool — the concrete valid mask
    tick: int              # write_tick snapshot at lookup time

    @property
    def miss(self) -> np.ndarray:
        return self.valid & ~self.hit

    @property
    def all_hit(self) -> bool:
        return not bool(self.miss.any())

    @property
    def hit_rate(self) -> float:
        nv = int(self.valid.sum())
        return float(self.hit.sum() / nv) if nv else 0.0


class BucketCache:
    """Per-origin set-associative cache of hot hash-table records with
    publish-bumped version tags (see module docstring for the protocol)."""

    def __init__(self, nranks: int, nslots: int, val_words: int,
                 capacity: int = 4096, max_probes: int = 8, ways: int = 4):
        if capacity & (capacity - 1):
            raise ValueError("capacity must be a power of two")
        if ways & (ways - 1) or not 0 < ways <= capacity:
            raise ValueError("ways must be a power of two <= capacity")
        self.nranks = nranks
        self.nslots = nslots
        self.val_words = val_words
        self.rec_w = 2 + val_words
        self.capacity = capacity
        self.ways = ways
        self.sets = capacity // ways
        self.max_probes = max_probes
        self.enabled = True
        # per-(owner, slot) version counters — the invalidation substrate
        self.versions = np.zeros((nranks, nslots), np.int64)
        # global write counter: deferred-fill race detection
        self.write_tick = 0
        self.epoch = 0                       # invalidate_all generations
        # per-origin (sets, ways) store + round-robin victim clock
        self._tag = np.full((nranks, self.sets, ways), _EMPTY_TAG, np.int64)
        self._owner = np.zeros((nranks, self.sets, ways), np.int32)
        self._slot = np.zeros((nranks, self.sets, ways), np.int32)
        self._ver = np.zeros((nranks, self.sets, ways), np.int64)
        self._val = np.zeros((nranks, self.sets, ways, val_words), np.int32)
        self._clock = np.zeros((nranks, self.sets), np.int64)
        self._pending: List[Tuple] = []
        self.last_hit_rate: Optional[float] = None
        self.counters = {"lookups": 0, "hits": 0, "misses": 0, "fills": 0,
                         "stale_evicted": 0, "invalidations": 0,
                         "fill_drops": 0}

    # -- placement -----------------------------------------------------------
    def _index(self, keys: np.ndarray) -> np.ndarray:
        from .hashtable import hash_mix_np
        return (hash_mix_np(keys) % np.uint32(self.sets)).astype(np.int64)

    def _placement(self, keys: np.ndarray):
        from .hashtable import place_np
        return place_np(self.nranks, self.nslots, keys)

    # -- read path -----------------------------------------------------------
    def lookup(self, keys, valid=None,
               max_stale: int = 0) -> Optional[CacheLookup]:
        """Consult the cache for one (P, n) find batch.

        Returns None when the cache cannot be consulted (disabled, or the
        batch is abstract under jit tracing) — callers fall through to the
        normal engine. Stale entries discovered here are evicted.

        max_stale (DESIGN.md §10 graceful degradation): serve entries
        whose probe-window version lags the authoritative version by at
        most this many publishes. 0 (default) is the §8 bit-exact
        behavior — any version mismatch is a miss. Under faults a reader
        that tolerates bounded staleness keeps answering from the local
        cache while the remote owner is quarantined; entries lagging past
        the tolerance are still evicted."""
        if not self.enabled:
            return None
        k = _concrete(keys)
        if k is None:
            return None
        if valid is None:
            v = np.ones(k.shape, bool)
        else:
            v = _concrete(valid)
            if v is None:
                return None
            v = v.astype(bool)
        self.drain_fills()
        k = k.astype(np.int32)
        P, n = k.shape
        idx = self._index(k)
        pp = np.arange(P)[:, None]
        line_tag = self._tag[pp, idx]                       # (P, n, W)
        tag_hit_w = (line_tag == k.astype(np.int64)[..., None]) \
            & v[..., None]
        owner = self._owner[pp, idx]
        slot = self._slot[pp, idx]
        lag = self.versions[owner, slot] - self._ver[pp, idx]
        fresh = (lag >= 0) & (lag <= int(max_stale))
        hit_w = tag_hit_w & fresh
        stale_w = tag_hit_w & ~fresh
        if stale_w.any():
            rows, cols, wys = np.nonzero(stale_w)
            self._tag[rows, idx[rows, cols], wys] = _EMPTY_TAG
            self.counters["stale_evicted"] += int(rows.size)
        hit = hit_w.any(-1)
        way = np.argmax(hit_w, axis=-1)                     # (P, n)
        vals = np.where(hit[..., None],
                        self._val[pp, idx, way], 0).astype(np.int32)
        nhit, nvalid = int(hit.sum()), int(v.sum())
        self.counters["lookups"] += 1
        self.counters["hits"] += nhit
        self.counters["misses"] += nvalid - nhit
        self.last_hit_rate = nhit / nvalid if nvalid else 0.0
        return CacheLookup(hit=hit, vals=vals, keys=k, valid=v,
                           tick=self.write_tick)

    # -- fill path -----------------------------------------------------------
    def note_fill(self, look: CacheLookup, slot, found, vals) -> None:
        """Enqueue the device results of the miss subset for caching.

        slot/found/vals are (possibly in-flight) device arrays from the
        probe loop: (P, n) hit slot, (P, n) found mask, (P, n, vw) values.
        Tracers are ignored (nothing to cache at trace time)."""
        import jax
        if any(isinstance(a, jax.core.Tracer) for a in (slot, found, vals)):
            return
        if not look.miss.any():
            return
        self._pending.append((look.tick, look.keys, look.miss, slot, found,
                              vals))
        self.drain_fills()

    def drain_fills(self, force: Optional[bool] = None) -> None:
        """Apply pending fills whose device values are available.

        force=None auto-detects: blocking on the device values is safe
        only OUTSIDE the pipelined engine — not just outside a slot scope
        (staging), but also between submits while any pipeline still holds
        an in-flight window (`window.pipeline_inflight`). A host-side
        drain there would materialize the previous batch's not-yet-forced
        outputs and serialize against exactly the overlap the pipeline
        buys (the PR 6 depth-2 regression); those fills stay queued until
        their arrays turn ready on their own or the stream drains. Pass
        force=True to block explicitly (tests, teardown)."""
        if not self._pending:
            return
        if force is None:
            from . import window as win_mod
            force = (win_mod._CURRENT_SLOT is None
                     and not win_mod.pipeline_inflight())
        keep = []
        for rec in self._pending:
            tick, keys, miss, slot, found, vals = rec
            if tick != self.write_tick:
                # raced with a writer: the read may predate the write
                self.counters["fill_drops"] += 1
                continue
            if not (force or all(_is_ready(a) for a in (slot, found, vals))):
                keep.append(rec)
                continue
            self._apply_fill(keys, miss, np.asarray(slot), np.asarray(found),
                             np.asarray(vals))
        self._pending = keep

    def _apply_fill(self, keys, miss, slot, found, vals) -> None:
        mask = miss & found.astype(bool) & (slot >= 0)
        if not mask.any():
            return
        owner, _ = self._placement(keys)
        rows, cols = np.nonzero(mask)
        idx = self._index(keys)
        ci = idx[rows, cols]
        ow, sl = owner[rows, cols], slot[rows, cols]
        key64 = keys[rows, cols].astype(np.int64)
        fvals = vals[rows, cols]
        # dedupe (origin, key): a key's duplicate rows carry identical
        # records, and distinct per-set entries must get distinct ways
        combo = (rows.astype(np.int64) << 32) | key64
        _, first = np.unique(combo, return_index=True)
        rows, ci, ow, sl = rows[first], ci[first], ow[first], sl[first]
        key64, fvals = key64[first], fvals[first]
        # way choice: the key's existing line if present, else an empty
        # way, else the set's round-robin victim
        line_tags = self._tag[rows, ci]                     # (m, W)
        present = line_tags == key64[:, None]
        empty = line_tags == _EMPTY_TAG
        way = np.where(
            present.any(1), present.argmax(1),
            np.where(empty.any(1), empty.argmax(1),
                     self._clock[rows, ci] % self.ways)).astype(np.int64)
        # distinct keys of one batch landing in one set all saw the
        # PRE-fill line state, so they can pick the same way; rotate the
        # (rare) conflicts onto free ways with a short host loop — only
        # when a conflict actually exists
        tgt = (rows * np.int64(self.sets) + ci) * self.ways + way
        _, cnt = np.unique(tgt, return_counts=True)
        if (cnt > 1).any():
            taken: dict = {}
            for i in range(rows.size):
                used = taken.setdefault((int(rows[i]), int(ci[i])), set())
                w = int(way[i])
                if w in used:
                    pick = None
                    for d in range(1, self.ways):
                        w2 = (w + d) % self.ways
                        if w2 in used:
                            continue
                        if pick is None:
                            pick = w2
                        if empty[i, w2]:
                            pick = w2
                            break
                    if pick is not None:
                        w = pick
                used.add(w)
                way[i] = w
        # no write intervened since the read (tick check), so the current
        # version table IS the version the record was read at
        self._tag[rows, ci, way] = key64
        self._owner[rows, ci, way] = ow
        self._slot[rows, ci, way] = sl
        self._ver[rows, ci, way] = self.versions[ow, sl]
        self._val[rows, ci, way] = fvals
        np.add.at(self._clock, (rows, ci), 1)
        self.counters["fills"] += int(rows.size)
        from . import window as win_mod
        win_mod.log_cache_event("cache_fill", {"rows": int(rows.size)})

    # -- write / invalidation path -------------------------------------------
    def on_insert_keys(self, keys, valid=None,
                       max_probes: Optional[int] = None) -> None:
        """Authoritative pre-write invalidation: bump the probe-window
        versions of every key about to be written (any arm — the AM
        insert-or-assign included). Tracer batches invalidate everything."""
        self.write_tick += 1
        k = _concrete(keys)
        if k is None:
            self.invalidate_all(bump_tick=False)
            return
        v = None
        if valid is not None:
            v = _concrete(valid)
            if v is None:
                self.invalidate_all(bump_tick=False)
                return
        k = k.astype(np.int32).ravel() if v is None else \
            k.astype(np.int32)[v.astype(bool)].ravel()
        if k.size == 0:
            return
        mp = self.max_probes if max_probes is None else max_probes
        owner, start = self._placement(k)
        window_slots = (start[:, None].astype(np.int64)
                        + np.arange(mp)[None, :]) % self.nslots
        np.add.at(self.versions,
                  (np.repeat(owner, mp), window_slots.ravel()), 1)
        self.counters["invalidations"] += int(k.size)
        from . import window as win_mod
        win_mod.log_cache_event("cache_invalidate",
                                {"keys": int(k.size), "probe_window": mp})

    def on_publish(self, dst, off, valid=None) -> None:
        """Precision invalidation from an eager concrete publish flip:
        bump exactly the flipped slot (off is the flag-word offset, so
        slot = off // rec_w). Tracers no-op — `on_insert_keys` is the
        authoritative channel (see module docstring)."""
        d, o = _concrete(dst), _concrete(off)
        if d is None or o is None:
            return
        self.write_tick += 1
        if valid is not None:
            v = _concrete(valid)
            if v is None:
                self.invalidate_all(bump_tick=False)
                return
            sel = v.astype(bool)
            d, o = d[sel], o[sel]
        slots = (o.astype(np.int64) // self.rec_w) % self.nslots
        if d.size:
            np.add.at(self.versions, (d.ravel(), slots.ravel()), 1)

    def invalidate_all(self, bump_tick: bool = True) -> None:
        """Drop every entry and pending fill (conservative full flush)."""
        if bump_tick:
            self.write_tick += 1
        self.epoch += 1
        self._tag.fill(_EMPTY_TAG)
        self.counters["fill_drops"] += len(self._pending)
        self._pending.clear()
        from . import window as win_mod
        win_mod.log_cache_event("cache_invalidate", {"all": True})

    # -- introspection -------------------------------------------------------
    @property
    def hit_rate(self) -> float:
        h, m = self.counters["hits"], self.counters["misses"]
        return h / (h + m) if h + m else 0.0

    def stats(self) -> dict:
        return {**self.counters, "hit_rate": self.hit_rate,
                "epoch": self.epoch, "write_tick": self.write_tick,
                "pending_fills": len(self._pending),
                "capacity": self.capacity, "ways": self.ways,
                "entries": int((self._tag != _EMPTY_TAG).sum())}
