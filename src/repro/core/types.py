"""Core types for the PGAS data-structure layer.

Concurrency *promises* (paper §II-C, "concurrency promises"): the caller
declares which operations may run concurrently with the one being issued,
which selects the cheapest correct implementation (paper Tables II/III).

AMO opcodes: the fixed-function "NIC" operations available in RDMA style.
Anything richer must go through the RPC/active-message backend.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass

import jax
import jax.numpy as jnp


class Promise(enum.Enum):
    """Concurrency promise levels, paper notation C_RW / C_W / C_R / C_l."""

    CRW = "concurrent_read_write"  # fully atomic
    CW = "concurrent_write"        # phasal: only writes (inserts/pushes) concurrent
    CR = "concurrent_read"         # phasal: only reads (finds/pops) concurrent
    CL = "concurrent_local"        # local-only access (queue is host-local)


class Backend(enum.Enum):
    RDMA = "rdma"   # one-sided component ops (put/get/CAS/FAO phases)
    RPC = "rpc"     # aggregated active messages (one round trip + handler)
    AUTO = "auto"   # cost-model-selected


def as_backend(backend) -> "Backend":
    """Coerce a Backend or its string value ("rdma"/"rpc"/"auto")."""
    return Backend(backend) if isinstance(backend, str) else backend


class AmoKind(enum.IntEnum):
    """Fixed-function atomics. Integer codes shared with the Pallas kernel.

    Codes 0-6 are the primitive single-word AMOs (one per network phase).
    Codes 7-9 are FUSED component descriptors (DESIGN.md §2): one request
    phase carries a compound op that the owner lane applies as a single
    serialized step — the Active-Access / Storm-style composite remote op.
    """

    PUT = 0    # unconditional store, returns previous value
    GET = 1    # read, no modification
    CAS = 2    # compare(a)-and-swap(b), returns previous value
    FAA = 3    # fetch-and-add(a)
    FOR = 4    # fetch-and-or(a)
    FAND = 5   # fetch-and-and(a)
    FXOR = 6   # fetch-and-xor(a)
    # Fused descriptors [off | kind | a | b | aux0 | aux1 | vals...]:
    CAS_PUT = 7       # CAS(a->b) at off; on success put vals at aux0
    CAS_PUT_PUB = 8   # CAS_PUT, then on success mem[off] ^= aux1 (publish)
    FAO_GET = 9       # fetch-and-op(a, subkind b) at off; gather from aux0


# Hash-table slot flag states (stored in the flag word of each slot).
FLAG_EMPTY = jnp.int32(0)
FLAG_RESERVED = jnp.int32(1)
FLAG_READY = jnp.int32(2)
# Reader counting for C_RW find: readers add READ_UNIT to the flag word.
# (The paper uses fetch-and-OR on per-reader bits; a counter has identical
# cost (one A_FAO) and avoids a static reader limit.)
READ_UNIT = jnp.int32(256)
STATE_MASK = jnp.int32(255)

EMPTY_KEY = jnp.int32(-0x7FFFFFFF)  # sentinel for "no key present"


def f32_to_words(x: jax.Array) -> jax.Array:
    """Bitcast float32 payloads into int32 words for word-addressed windows."""
    return jax.lax.bitcast_convert_type(x, jnp.int32)


def words_to_f32(x: jax.Array) -> jax.Array:
    return jax.lax.bitcast_convert_type(x, jnp.float32)


@dataclass(frozen=True)
class OpStats:
    """Workload statistics fed to the cost model's backend chooser."""

    ops_per_rank: int = 1
    payload_bytes: int = 8
    expected_probes: float = 1.0     # hash-table collision factor (round trips)
    contention: float = 1.0          # expected CAS attempts for persistent CAS
    target_busy_us: float = 0.0      # interspersed compute between dispatch points
    progress_thread: bool = False    # dedicated servicing channel (paper Fig. 6 "PT")
    skew: float = 1.0                # batch owner-load skew: max owner load / mean
                                     # (1.0 = uniform; P = single hot owner).
                                     # High skew serializes RDMA atomics in one
                                     # owner's apply lane while AM aggregation
                                     # amortizes the round trip (DESIGN.md §4).
    dedup: float = 1.0               # distinct-row fraction of the batch:
                                     # distinct (owner, offset) descriptor rows
                                     # / total rows (1.0 = all distinct; 1/n =
                                     # one hot row). Coalescing (DESIGN.md §6)
                                     # ships only the distinct rows, so dedup
                                     # scales the wire/owner-apply terms of the
                                     # coalesced arms.
    pipeline_depth: int = 1          # in-flight batch windows (DESIGN.md §7):
                                     # 1 = synchronous lock-step engine, 2 =
                                     # double-buffered. Depth > 1 overlaps
                                     # batch k+1's route+send with batch k's
                                     # owner-apply+reply, so predict_arm
                                     # prices a pipelined op at
                                     # max(A, B) + min(A, B)/depth instead of
                                     # A + B (A = origin-side, B = owner-side).
    hit_rate: float = 0.0            # hot-bucket cache hit fraction
                                     # (DESIGN.md §8): fraction of a find
                                     # batch expected to be served from the
                                     # origin-local bucket cache, paying only
                                     # the host lookup. Only the cached find
                                     # arm (rdma_fused under CR) consults it;
                                     # 0.0 = no cache attached.
    loss_rate: float = 0.0           # measured per-attempt delivery-failure
                                     # probability (DESIGN.md §10): fraction
                                     # of transmissions the fault plane (or a
                                     # real lossy fabric) drops, as tracked by
                                     # AdaptiveEngine.loss_ewma. Each op pays
                                     # an expected lr/(1-lr) retransmissions
                                     # of its smallest retryable unit — a
                                     # whole AM round trip for the RPC arms
                                     # vs. one wire phase for the one-sided
                                     # arms — so loss tilts the model toward
                                     # RDMA (the paper's trade flips again
                                     # under loss). 0.0 = lossless: every
                                     # prediction is bit-identical to the
                                     # §9 model.
    nranks: int = 0                  # shard count P the batch runs at
                                     # (DESIGN.md §9): scales the per-rank
                                     # occupancy-exchange and AM reply fan-out
                                     # terms of the cost model. 0 = unknown —
                                     # the model applies no P-dependence, so
                                     # every P=8-era prediction is unchanged.
