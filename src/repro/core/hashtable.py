"""Distributed hash table (open addressing, linear probing) — paper §III-B1.

Slot layout (int32 words):   [ flag | key | val_0 .. val_{vw-1} ]

flag word: low 8 bits = state (EMPTY/RESERVED/READY); bits 8+ = reader count
(the paper uses fetch-and-OR read *bits*; an additive reader count has the
same component cost — one A_FAO — without a static reader limit).

Implementations and their best-case costs (paper Table II):

  insert C_RW (rdma):  probes×A_CAS + W + A_FAO   (claim, write, mark-ready)
  insert C_W  (rdma):  probes×A_CAS + W            (barrier supplies the fence)
  find   C_RW (rdma):  A_FAO + R + A_FAO           (read-lock, get, unlock)
  find   C_R  (rdma):  R                           (bare get of the record)
  insert/find (rpc):   one AM round trip + local probe handler

Ownership: owner = mix(key) % P; probing wraps within the owner's local
table so the RDMA and RPC backends have identical placement semantics.

RPC expressivity note (paper §II-B): the RPC insert handler does
insert-or-assign (update on key match) — free extra control flow in a
handler; the RDMA version is insert-only because CAS can only claim EMPTY
slots. This asymmetry is the paper's expressivity argument made concrete.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import am as am_mod
from . import routing
from . import window as win_mod
from .types import (FLAG_EMPTY, FLAG_READY, FLAG_RESERVED, READ_UNIT,
                    STATE_MASK, Backend, Promise, as_backend)
from .window import (Window, rdma_cas, rdma_cas_put, rdma_cas_put_publish,
                     rdma_fao, rdma_fao_get, rdma_get, rdma_put)

Array = jax.Array


def hash_mix(key: Array) -> Array:
    """Deterministic 32-bit integer mix (xorshift-multiply)."""
    k = key.astype(jnp.uint32)
    k = (k ^ (k >> 16)) * jnp.uint32(0x85EBCA6B)
    k = (k ^ (k >> 13)) * jnp.uint32(0xC2B2AE35)
    return (k ^ (k >> 16)).astype(jnp.uint32)


@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=["win"], meta_fields=["nslots", "val_words"])
@dataclass
class DHashTable:
    win: Window
    nslots: int      # local slots per rank
    val_words: int

    @property
    def nranks(self) -> int:
        return self.win.nranks

    @property
    def rec_w(self) -> int:
        return 2 + self.val_words


def make_hashtable(nranks: int, nslots: int, val_words: int) -> DHashTable:
    rec_w = 2 + val_words
    return DHashTable(win=win_mod.make_window(nranks, nslots * rec_w),
                      nslots=nslots, val_words=val_words)


def _place(ht: DHashTable, keys: Array) -> Tuple[Array, Array]:
    h = hash_mix(keys)
    owner = (h % jnp.uint32(ht.nranks)).astype(jnp.int32)
    start = ((h // jnp.uint32(ht.nranks)) % jnp.uint32(ht.nslots)).astype(
        jnp.int32)
    return owner, start


def hash_mix_np(keys):
    """Host-side (numpy) mirror of `hash_mix` — THE single numpy copy of
    the xorshift-multiply constants (benchmarks/common.py delegates here;
    bit-equality with the jnp version is pinned by tests)."""
    import numpy as np
    k = np.asarray(keys).astype(np.uint32)
    k = (k ^ (k >> 16)) * np.uint32(0x85EBCA6B)
    k = (k ^ (k >> 13)) * np.uint32(0xC2B2AE35)
    return k ^ (k >> 16)


def place_np(nranks: int, nslots: int, keys):
    """Host-side (numpy) mirror of `_place` — bit-identical owner/start.

    The pipelined front-ends (DESIGN.md §7) use this to compute the skew
    and dedup signals on the Python thread at submit time, so staging
    batch k+1 never reads a device value (which would serialize behind
    batch k's in-flight phases and defeat the overlap). Bit-equality with
    the engine placement is pinned by tests/test_pipeline.py."""
    import numpy as np
    h = hash_mix_np(keys)
    owner = (h % np.uint32(nranks)).astype(np.int32)
    start = ((h // np.uint32(nranks)) % np.uint32(nslots)).astype(np.int32)
    return owner, start


# ---------------------------------------------------------------------------
# RDMA backend
# ---------------------------------------------------------------------------
def insert_rdma(ht: DHashTable, keys: Array, vals: Array,
                promise: Promise = Promise.CRW,
                valid: Optional[Array] = None, max_probes: int = 8,
                fused: bool = True, coalesce: bool = False
                ) -> Tuple[DHashTable, Array, Array]:
    """Batched insert. keys (P, n) int32, vals (P, n, vw) int32.

    Returns (table', success (P,n), probe_count (P,n)). Distinct keys per
    batch assumed (open-addressing insert-only, see module docstring).

    fused=True (default, DESIGN.md §2): one RoutePlan per batch + fused
    claim/write(/publish) descriptors — each probe is ONE request phase and
    the trailing W / A_FAO phases disappear. fused=False keeps the unfused
    per-component phases (probes×A_CAS + W [+ A_FAO]); both paths are
    bit-exact equivalent (tests/test_datastructures.py).

    coalesce=True (DESIGN.md §6): duplicate IDENTICAL [key|val] rows in a
    batch are combined sender-side. With fused=True the whole batch uses
    one CoalescedPlan and a duplicate group claims ONE slot: the
    representative's claim satisfies every duplicate (same record lands in
    the table), so duplicates short-circuit instead of claiming sibling
    slots — wire rows and probe phases collapse toward O(distinct keys).
    Visible results (ok flags, subsequent finds) are conformant with the
    uncoalesced engine; the slot-level table state differs only in that
    duplicate side-copies are elided. With fused=False coalescing is
    phase-local (window-level) and fully bit-exact.
    """
    assert promise in (Promise.CRW, Promise.CW)
    if valid is None:
        valid = jnp.ones(keys.shape, dtype=bool)
    dst, start = _place(ht, keys)
    rec_w, nslots = ht.rec_w, ht.nslots
    claim_to = FLAG_RESERVED if promise == Promise.CRW else FLAG_READY

    if fused:
        payload = jnp.concatenate([keys[..., None], vals], axis=-1)
        if coalesce:
            plan = routing.coalesce_plan(dst, start, match=payload,
                                         valid=valid, cap=keys.shape[1],
                                         role="ht_insert")
            co = plan.co
        else:
            plan = routing.make_plan(dst, valid, cap=keys.shape[1],
                                     role="ht_insert")
            co = None
        flip = int(FLAG_RESERVED) ^ int(FLAG_READY)

        def probe_fused(carry):
            j, win, active, claimed, probes = carry
            slot = (start + j) % nslots
            off = slot * rec_w
            if promise == Promise.CRW:
                old, win = rdma_cas_put_publish(
                    win, dst, off, FLAG_EMPTY, claim_to, off + 1, payload,
                    flip, valid=active, plan=plan)
            else:
                old, win = rdma_cas_put(
                    win, dst, off, FLAG_EMPTY, claim_to, off + 1, payload,
                    valid=active, plan=plan)
            if co is not None:
                # the whole duplicate run adopts its representative's
                # outcome: one claim serves every identical [key|val] row
                old = routing.lead(co, old)
            newly = active & (old == FLAG_EMPTY)
            claimed = jnp.where(newly, slot, claimed)
            probes = probes + active.astype(jnp.int32)
            return j + 1, win, active & ~newly, claimed, probes

        # Adaptive termination: once every op has claimed, the remaining
        # probe phases are identities (all-inactive CAS batches change
        # nothing), so skipping them at runtime is bit-exact. The unfused
        # seed path keeps its fixed trip count.
        claimed0 = jnp.full(keys.shape, -1, dtype=jnp.int32)
        probes0 = jnp.zeros(keys.shape, dtype=jnp.int32)
        _, win, active, claimed, probes = jax.lax.while_loop(
            lambda c: (c[0] < max_probes) & c[2].any(), probe_fused,
            (jnp.int32(0), ht.win, valid, claimed0, probes0))
        success = valid & ~active
        return (DHashTable(win=win, nslots=nslots, val_words=ht.val_words),
                success, probes)

    def probe_phase(j, carry):
        win, active, claimed, probes = carry
        slot = (start + j) % nslots
        off = slot * rec_w
        # coalesce is phase-local here (fresh runs per probe): identical
        # CAS rows dedup on the wire, losers reconstruct bit-exactly
        old, win = rdma_cas(win, dst, off, FLAG_EMPTY, claim_to,
                            valid=active, coalesce=coalesce)
        newly = active & (old == FLAG_EMPTY)
        claimed = jnp.where(newly, slot, claimed)
        probes = probes + active.astype(jnp.int32)
        return win, active & ~newly, claimed, probes

    claimed0 = jnp.full(keys.shape, -1, dtype=jnp.int32)
    probes0 = jnp.zeros(keys.shape, dtype=jnp.int32)
    win, active, claimed, probes = jax.lax.fori_loop(
        0, max_probes, probe_phase, (ht.win, valid, claimed0, probes0))
    success = valid & ~active

    # ONE put phase writes [key | val words] for every claimed op.
    payload = jnp.concatenate([keys[..., None], vals], axis=-1)
    win = rdma_put(win, dst, claimed * rec_w + 1, payload, valid=success)

    if promise == Promise.CRW:
        # Flip RESERVED -> READY without touching reader bits: FXOR(1^2).
        # (python-level xor: staging it under jit would make the int() of
        # the module constants a tracer)
        flip = jnp.full(keys.shape, int(FLAG_RESERVED) ^ int(FLAG_READY),
                        dtype=jnp.int32)
        _, win = rdma_fao(win, dst, claimed * rec_w, flip,
                          win_mod.AmoKind.FXOR, valid=success)
    return (DHashTable(win=win, nslots=nslots, val_words=ht.val_words),
            success, probes)


def find_rdma(ht: DHashTable, keys: Array,
              promise: Promise = Promise.CR,
              valid: Optional[Array] = None, max_probes: int = 8,
              fused: bool = True, coalesce: bool = False,
              cache=None, return_slot: bool = False,
              max_stale: int = 0):
    """Batched find. Returns (table', found (P,n), vals (P,n,vw)).

    C_R : one bare get per probe (flag+key+val in a single R).
    C_RW: read-lock (FAA +unit), get, unlock (FAA -unit) per probe.

    fused=True (default): one RoutePlan per batch; for C_RW the read-lock
    and record gather fuse into one A_FAO_GET request/reply pair, cutting a
    probe from 6 exchanges to 4 (lock+get fused = 2, unlock = 2). The
    gathered flag word may predate later locks in the batch, but the C_RW
    hit test uses the lock's fetched state, so results are bit-exact with
    fused=False.

    coalesce=True (DESIGN.md §6): duplicate-key rows probe ONCE and the
    reply fans out — a zipfian find batch ships O(distinct keys) wire
    rows. Bit-exact: a duplicate group always decides (hit / miss /
    continue) identically, and for C_RW the combined read-lock carries the
    summed reader units whose per-op fetched values are reconstructed
    sender-side.

    cache (DESIGN.md §8): an optional core/cache.BucketCache consulted
    BEFORE planning — only for the fused CR find (CRW must hit the owner
    for its read locks) on concrete batches (cache.lookup returns None
    under jit tracing). Cache hits are answered origin-locally: an
    all-hit batch issues ZERO exchanges, a mixed batch plans only the
    miss subset (bit-identical occupancy, `routing.miss_subset_plan`)
    and the probe loop's fresh results are fed back via
    `cache.note_fill`. Bit-exact by the version protocol: a fresh entry
    is exactly the record the wire would return.

    return_slot=True (fused only, incompatible with `cache`): also return
    the per-row hit slot (-1 for misses) as a fourth output — lets a
    caller that manages its own BucketCache under jit (host lookup + one
    jitted miss-subset step, benchmarks/pipeline_bench.py) feed
    `cache.note_fill` without the eager integrated path."""
    assert promise in (Promise.CRW, Promise.CR)
    if return_slot:
        assert fused and cache is None, \
            "return_slot needs fused=True and an external cache"
    if valid is None:
        valid = jnp.ones(keys.shape, dtype=bool)
    dst, start = _place(ht, keys)
    rec_w, nslots, vw = ht.rec_w, ht.nslots, ht.val_words
    look = None
    if cache is not None and fused and promise == Promise.CR:
        # max_stale > 0 (DESIGN.md §10): bounded-staleness read — cached
        # records at most `max_stale` publishes behind still count as
        # hits, trading freshness for availability under quarantine.
        # The default 0 keeps the §8 bit-exact protocol.
        look = cache.lookup(keys, valid, max_stale=max_stale)
    if look is not None and look.all_hit:
        # every valid row served origin-locally: ZERO exchanges
        win_mod.log_cache_event("cache_hit", {
            "hits": int(look.hit.sum()), "misses": 0, "all_hit": True})
        return ht, jnp.asarray(look.hit), jnp.asarray(look.vals)
    eff_valid = valid
    if look is not None:
        eff_valid = valid & jnp.asarray(~look.hit)
    if fused and coalesce:
        if look is not None:
            plan = routing.miss_subset_plan(dst, start,
                                            jnp.asarray(look.hit),
                                            match=keys[..., None],
                                            valid=valid, cap=keys.shape[1],
                                            role="ht_find")
        else:
            plan = routing.coalesce_plan(dst, start, match=keys[..., None],
                                         valid=valid, cap=keys.shape[1],
                                         role="ht_find")
    elif fused:
        plan = routing.make_plan(dst, eff_valid, cap=keys.shape[1],
                                 role="ht_find")
    else:
        plan = None
    loc_coalesce = coalesce and not fused  # phase-local runs (no plan)

    def probe_body(j, win, active, found, out):
        slot = (start + j) % nslots
        off = slot * rec_w
        if promise == Promise.CRW:
            unit = jnp.full(keys.shape, int(READ_UNIT), dtype=jnp.int32)
            if fused:
                old, rec, win = rdma_fao_get(
                    win, dst, off, unit, win_mod.AmoKind.FAA, off, rec_w,
                    valid=active, plan=plan)
                state = old & STATE_MASK
            else:
                old, win = rdma_fao(win, dst, off, unit,
                                    win_mod.AmoKind.FAA, valid=active,
                                    coalesce=loc_coalesce)
                state = old & STATE_MASK
                lockable = active & (state == FLAG_READY)
                rec = rdma_get(win, dst, off, rec_w, valid=lockable,
                               coalesce=loc_coalesce)
            _, win = rdma_fao(win, dst, off, -unit, win_mod.AmoKind.FAA,
                              valid=active, plan=plan,
                              coalesce=loc_coalesce)
            flag_state = state
        else:
            rec = rdma_get(win, dst, off, rec_w, valid=active, plan=plan,
                           coalesce=loc_coalesce)
            flag_state = rec[..., 0] & STATE_MASK
        hit = active & (flag_state == FLAG_READY) & (rec[..., 1] == keys)
        miss_end = active & (flag_state == FLAG_EMPTY)
        out = jnp.where(hit[..., None], rec[..., 2:2 + vw], out)
        found = found | hit
        active = active & ~(hit | miss_end)
        return win, active, found, out

    found0 = jnp.zeros(keys.shape, dtype=bool)
    out0 = jnp.zeros(keys.shape + (vw,), dtype=jnp.int32)
    if fused:
        # Adaptive termination (see insert_rdma): an all-inactive probe is
        # an identity, so stopping when every op resolved is bit-exact.
        # With a cache in play the carry additionally tracks each hit's
        # slot (the fill needs it to stamp versions); the cache-free trace
        # is untouched.
        track = look is not None or return_slot

        def probe_fused(carry):
            if track:
                j, win, active, found, out, hslot = carry
            else:
                j, win, active, found, out = carry
            prev_found = found
            win, active, found, out = probe_body(j, win, active, found, out)
            if track:
                slot = (start + j) % nslots
                hslot = jnp.where(found & ~prev_found, slot, hslot)
                return j + 1, win, active, found, out, hslot
            return j + 1, win, active, found, out

        carry0 = (jnp.int32(0), ht.win, eff_valid, found0, out0)
        if track:
            carry0 = carry0 + (jnp.full(keys.shape, -1, jnp.int32),)
        fin = jax.lax.while_loop(
            lambda c: (c[0] < max_probes) & c[2].any(), probe_fused, carry0)
        win, found, out = fin[1], fin[3], fin[4]
        if look is not None:
            hitm = jnp.asarray(look.hit)
            found = found | hitm
            out = jnp.where(hitm[..., None], jnp.asarray(look.vals), out)
            cache.note_fill(look, fin[5], found, out)
            win_mod.log_cache_event("cache_hit", {
                "hits": int(look.hit.sum()),
                "misses": int(look.miss.sum())})
        if return_slot:
            return (DHashTable(win=win, nslots=nslots,
                               val_words=ht.val_words), found, out, fin[5])
    else:
        win, _, found, out = jax.lax.fori_loop(
            0, max_probes,
            lambda j, c: probe_body(j, *c), (ht.win, valid, found0, out0))
    return (DHashTable(win=win, nslots=nslots, val_words=ht.val_words),
            found, out)


# ---------------------------------------------------------------------------
# RPC backend (active messages, paper Fig. 2)
# ---------------------------------------------------------------------------
def _probe_local(local: Array, key: Array, nslots: int, rec_w: int,
                 start: Array, max_probes: int, want_empty: bool):
    """Shared probe loop over a local shard. Returns (slot, kind, probes)
    where kind 0=miss, 1=found key, 2=empty slot (insertable if want_empty)
    and probes is the number of slots examined before the op decided — the
    RPC-side stat comparable with the RDMA CAS-probe count."""

    def body(j, carry):
        slot, kind, probes = carry
        s = (start + j) % nslots
        rec0 = jax.lax.dynamic_slice(local, (s * rec_w,), (2,))
        state = rec0[0] & STATE_MASK
        is_hit = (state == FLAG_READY) & (rec0[1] == key)
        is_empty = state == FLAG_EMPTY
        searching = kind == 0
        take_hit = searching & is_hit
        take_empty = searching & is_empty & want_empty
        stop_empty = searching & is_empty & (not want_empty)
        kind = jnp.where(take_hit, 1, kind)
        kind = jnp.where(take_empty | stop_empty, jnp.where(take_empty, 2, 3),
                         kind)
        slot = jnp.where(take_hit | take_empty, s, slot)
        probes = probes + searching.astype(jnp.int32)
        return slot, kind, probes

    slot0 = jnp.int32(-1)
    kind0 = jnp.int32(0)
    return jax.lax.fori_loop(0, max_probes, body,
                             (slot0, kind0, jnp.int32(0)))


def build_am_handlers(ht: DHashTable, engine: am_mod.AMEngine,
                      max_probes: int = 8):
    """Register insert/find handlers. Handler state = the local slot words.

    The insert handler runs ops *sequentially* (lax.scan) — the target-side
    serial execution of AM handlers; arbitrary control flow costs no extra
    network phases.
    """
    nslots, rec_w, vw = ht.nslots, ht.rec_w, ht.val_words

    def insert_fn(local, payload, mask):
        # payload: (m, 1 + 1 + vw) = [start | key | val...]
        # reply (m, 2) = [ok | probes]
        def one(local, x):
            pay, ok = x
            start, key, val = pay[0], pay[1], pay[2:2 + vw]
            slot, kind, probes = _probe_local(local, key, nslots, rec_w,
                                              start, max_probes,
                                              want_empty=True)
            can = ok & (kind > 0) & (kind < 3)
            rec = jnp.concatenate([jnp.array([int(FLAG_READY), 0],
                                             dtype=jnp.int32), val])
            rec = rec.at[1].set(key)
            base = jnp.where(can, slot * rec_w, 0)
            cur = jax.lax.dynamic_slice(local, (base,), (rec_w,))
            new = jnp.where(can, rec, cur)
            local = jax.lax.dynamic_update_slice(local, new, (base,))
            return local, jnp.stack([can.astype(jnp.int32),
                                     jnp.where(ok, probes, 0)])

        local2, replies = jax.lax.scan(one, local, (payload, mask))
        return local2, replies

    def find_fn(local, payload, mask):
        # payload: (m, 2) = [start | key]; reply (m, 1 + vw) = [found | val]
        def one(pay):
            start, key = pay[0], pay[1]
            slot, kind, _ = _probe_local(local, key, nslots, rec_w, start,
                                         max_probes, want_empty=False)
            hit = kind == 1
            base = jnp.where(hit, slot * rec_w, 0)
            rec = jax.lax.dynamic_slice(local, (base,), (rec_w,))
            val = jnp.where(hit, rec[2:2 + vw], 0)
            return jnp.concatenate([hit.astype(jnp.int32)[None], val])

        replies = jax.vmap(one)(payload)
        replies = jnp.where(mask[:, None], replies, 0)
        return local, replies

    # Pallas-batched handler bodies (kernels/hash_probe.py): same contract,
    # table-resident-in-VMEM hot path. Selected via REPRO_USE_PALLAS=1.
    from ..kernels import ops as kops

    def insert_batched(data, flat, mask):
        ok, probes, data2 = kops.hash_insert(
            data, flat[..., 0], flat[..., 1], flat[..., 2:2 + vw], mask,
            nslots=nslots, rec_w=rec_w, max_probes=max_probes)
        return data2, jnp.stack([ok.astype(jnp.int32), probes], axis=-1)

    def find_batched(data, flat, mask):
        found, vals = kops.hash_find(
            data, flat[..., 0], flat[..., 1], mask,
            nslots=nslots, rec_w=rec_w, max_probes=max_probes)
        reply = jnp.concatenate([found.astype(jnp.int32)[..., None], vals],
                                axis=-1)
        return data, reply

    use_batched = kops.use_pallas_default()
    ins = engine.register("ht_insert", insert_fn, reply_width=2,
                          batched_fn=insert_batched if use_batched else None)
    fnd = engine.register("ht_find", find_fn, reply_width=1 + vw,
                          batched_fn=find_batched if use_batched else None)
    return ins, fnd


def insert_rpc(ht: DHashTable, engine: am_mod.AMEngine, keys: Array,
               vals: Array, valid: Optional[Array] = None,
               decision=None, coalesce: bool = False
               ) -> Tuple[DHashTable, Array, Array]:
    """Insert-or-assign via ONE AM round trip (cost: am_rt + handler).

    Returns (table', ok, probes): probes is the handler's REAL probe count
    carried in the reply word, so RDMA/RPC probe stats are comparable.
    coalesce=True dedups identical [start|key|val] request rows — safe
    because the handler is insert-or-assign (idempotent for identical
    rows), and its reply fans out to every duplicate."""
    dst, start = _place(ht, keys)
    payload = jnp.concatenate([start[..., None], keys[..., None], vals],
                              axis=-1)
    h = engine.handler("ht_insert")
    data, replies, delivered = engine.dispatch(h, ht.win.data, dst, payload,
                                               valid, decision=decision,
                                               coalesce=coalesce)
    ok = delivered & (replies[..., 0] > 0)
    probes = jnp.where(delivered, replies[..., 1], 0)
    return (DHashTable(win=Window(data=data), nslots=ht.nslots,
                       val_words=ht.val_words), ok, probes)


def find_rpc(ht: DHashTable, engine: am_mod.AMEngine, keys: Array,
             valid: Optional[Array] = None, decision=None,
             coalesce: bool = False) -> Tuple[Array, Array]:
    dst, start = _place(ht, keys)
    payload = jnp.concatenate([start[..., None], keys[..., None]], axis=-1)
    h = engine.handler("ht_find")
    _, replies, delivered = engine.dispatch(h, ht.win.data, dst, payload,
                                            valid, decision=decision,
                                            coalesce=coalesce)
    found = delivered & (replies[..., 0] > 0)
    return found, replies[..., 1:]


# ---------------------------------------------------------------------------
# Unified front-end. backend accepts Backend or its string value; the
# default is AUTO — the adaptive layer (core/adaptive.py, DESIGN.md §4)
# picks the cheapest arm per batch. Without an AMEngine the AUTO choice is
# restricted to the one-sided arms (rdma / rdma_fused).
# ---------------------------------------------------------------------------
def insert(ht, keys, vals, *, promise=Promise.CRW, backend=Backend.AUTO,
           engine=None, adaptive=None, **kw):
    """Batched distributed insert — the paper's §III-B1 op, any backend.

    Args:
      ht:      DHashTable.
      keys:    (P, n) int32, distinct per batch for the RDMA arms (RPC is
               insert-or-assign — DESIGN.md §4 conformance domain).
      vals:    (P, n, val_words) int32.
      promise: Promise.CRW (fully atomic) or CW (phasal writes).
      backend: Backend or string — "auto" (default, cost-model arm per
               batch, DESIGN.md §4), "rdma", or "rpc".
      engine:  am.AMEngine for the RPC/AM arms.
      adaptive: explicit AdaptiveEngine (default: cached per-nranks/engine).
      **kw:    valid, max_probes (any backend); stats (AUTO only — the
               chooser's OpStats); fused, coalesce (explicit "rdma" only —
               AUTO picks fusion/coalescing per batch itself).

    Returns (table', ok (P, n) bool, probes (P, n) int32). Visible results
    are bit-identical across every backend on the conformance domain
    (tests/test_conformance.py); tracer-safe — under jit the AUTO choice
    degrades to the static model decision (DESIGN.md §4)."""
    backend = as_backend(backend)
    if backend == Backend.AUTO:
        from . import adaptive as ad
        a = adaptive or ad.default_engine(ht.nranks, am_engine=engine)
        return a.ht_insert(ht, keys, vals, promise=promise, **kw)
    if backend == Backend.RPC:
        return insert_rpc(ht, engine, keys, vals, valid=kw.get("valid"),
                          coalesce=kw.get("coalesce", False))
    return insert_rdma(ht, keys, vals, promise=promise, **kw)


def find(ht, keys, *, promise=Promise.CR, backend=Backend.AUTO, engine=None,
         adaptive=None, **kw):
    """Batched distributed find. Same backend selection as `insert`.

    Args: as `insert` (promise CR = bare get per probe, CRW = read-locked).
    Returns (table', found (P, n) bool, vals (P, n, val_words) int32) —
    vals are zeros where not found. The table is returned because a C_RW
    find mutates reader counts; for CR it is unchanged. Bit-identical
    visible results across backends (tests/test_conformance.py)."""
    backend = as_backend(backend)
    if backend == Backend.AUTO:
        from . import adaptive as ad
        a = adaptive or ad.default_engine(ht.nranks, am_engine=engine)
        return a.ht_find(ht, keys, promise=promise, **kw)
    if backend == Backend.RPC:
        found, vals = find_rpc(ht, engine, keys, valid=kw.get("valid"),
                               coalesce=kw.get("coalesce", False))
        return ht, found, vals
    return find_rdma(ht, keys, promise=promise, **kw)


# ---------------------------------------------------------------------------
# Pipelined (async) front-ends: submit through a core/pipeline.Pipeline
# whose state is the DHashTable; returns a Handle instead of blocking
# (DESIGN.md §7). Bit-exact vs. the synchronous front-ends above — forcing
# immediately (depth=1, or result() right after submit) IS the sync path.
# ---------------------------------------------------------------------------
def _async_stats(ht, keys, valid, stats, depth: int):
    """Fold the host-computable batch signals (skew, dedup via `place_np`)
    and the pipeline depth into the cost-model stats WITHOUT reading any
    device value — staging must never serialize behind in-flight phases."""
    from dataclasses import replace as _rep

    import numpy as np

    from . import adaptive as ad
    from .types import OpStats
    s = stats or OpStats()
    k = ad._concrete(keys)
    if k is not None:
        # 1.0 doubles as OpStats' "unknown" sentinel: nudge a legitimately
        # computed 1.0 (perfectly uniform / all-distinct batch) off it by
        # an epsilon invisible to the scores, so the stage-time decide()
        # never recomputes the signal from a DEVICE value — which would
        # serialize staging behind the in-flight batch (DESIGN.md §7).
        if s.skew == 1.0:
            owner, _ = place_np(ht.nranks, ht.nslots, k)
            skew = ad.batch_skew(owner, ht.nranks, valid)
            s = _rep(s, skew=skew if skew != 1.0 else 1.0 + 1e-9)
        if s.dedup == 1.0:
            # nudged UP: dedup < 1 would turn coalescing on (DESIGN.md
            # §6) — every consumer clamps at 1.0, so >1 means "known
            # all-distinct"
            dd = ad.batch_dedup(k, valid)
            s = _rep(s, dedup=dd if dd != 1.0 else 1.0 + 1e-9)
    return _rep(s, pipeline_depth=max(1, int(depth)))


def insert_async(pipe, keys, vals, *, promise=Promise.CRW,
                 backend=Backend.AUTO, engine=None, adaptive=None,
                 deferred=None, **kw):
    """Submit one insert batch to a pipeline; returns a `pipeline.Handle`
    resolving to (ok, probes) — the table threads through `pipe.state`.

    Semantics (DESIGN.md §7): the batch stages immediately (eager) unless
    its arm is an active message, in which case it waits in the deferred-
    dispatch queue until the next dispatch point (`deferred` overrides;
    default: explicit backend "rpc", or an AUTO peek via
    `AdaptiveEngine.peek_arm`). Submission order is serialization order,
    so results are bit-exact vs. calling `insert` in the same order —
    including out-of-order `result()` forcing (tests/test_pipeline.py).

    AUTO batches price arms with `stats.pipeline_depth = pipe.depth`
    (the §7 overlap term) and compute skew/dedup host-side via `place_np`
    so staging never blocks on a device value. A `Pipeline(auto_depth=
    True)` additionally lets the chooser retarget the window count per
    batch (`AdaptiveEngine.auto_depth`, DESIGN.md §9)."""
    backend = as_backend(backend)
    eng = engine if engine is not None else pipe.am_engine
    st = pipe.staged_state
    if backend == Backend.AUTO:
        from . import adaptive as ad
        from .costmodel import DSOp
        a = adaptive or ad.default_engine(st.nranks, am_engine=eng)
        stats = _async_stats(st, keys, kw.get("valid"), kw.pop("stats", None),
                             pipe.depth)
        stats = a.auto_depth(pipe, DSOp.HT_INSERT, promise, stats)
        if deferred is None:
            deferred = a.peek_arm(DSOp.HT_INSERT, promise,
                                  a._ht_stats(keys, kw.get("valid"), stats)
                                  ) in ("am", "am_pt")
        kw = dict(kw, stats=stats, adaptive=a)
    elif deferred is None:
        deferred = backend == Backend.RPC

    def op(ht):
        ht2, ok, probes = insert(ht, keys, vals, promise=promise,
                                 backend=backend, engine=eng, **kw)
        return ht2, (ok, probes)

    return pipe.submit(op, deferred=deferred, label="ht_insert")


def find_async(pipe, keys, *, promise=Promise.CR, backend=Backend.AUTO,
               engine=None, adaptive=None, deferred=None, **kw):
    """Submit one find batch to a pipeline; returns a Handle resolving to
    (found, vals). Same staging/deferral semantics as `insert_async`."""
    backend = as_backend(backend)
    eng = engine if engine is not None else pipe.am_engine
    st = pipe.staged_state
    if backend == Backend.AUTO:
        from . import adaptive as ad
        from .costmodel import DSOp
        a = adaptive or ad.default_engine(st.nranks, am_engine=eng)
        stats = _async_stats(st, keys, kw.get("valid"), kw.pop("stats", None),
                             pipe.depth)
        stats = a.auto_depth(pipe, DSOp.HT_FIND, promise, stats)
        if deferred is None:
            deferred = a.peek_arm(DSOp.HT_FIND, promise,
                                  a._ht_stats(keys, kw.get("valid"), stats)
                                  ) in ("am", "am_pt")
        kw = dict(kw, stats=stats, adaptive=a)
    elif deferred is None:
        deferred = backend == Backend.RPC

    def op(ht):
        ht2, found, vals = find(ht, keys, promise=promise, backend=backend,
                                engine=eng, **kw)
        return ht2, (found, vals)

    return pipe.submit(op, deferred=deferred, label="ht_find")
