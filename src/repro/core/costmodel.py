"""The paper's analytical cost model (Tables I–III) as code, extended with
the TPU divergence terms from DESIGN.md §2 and the backend auto-chooser.

Every data-structure method cost is a sum of *component* costs. Component
costs come from one of three parameter sets:

- ``CORI_PHASE1``: the paper's measured Aries numbers (Table I) — used to
  reproduce the paper's predictions exactly;
- ``TPU_V5E_ICI``: derived ICI constants for the deployment target;
- ``calibrate(measured)``: fitted from this repo's own component
  microbenchmarks (benchmarks/components.py), used for the
  predicted-vs-measured validation (the paper's Figs. 4–5 methodology).

The model's real claim — and what we validate — is that it *orders*
implementations correctly, not that absolute microseconds match.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, replace
from typing import Dict, Optional

from .types import Backend, OpStats, Promise


@dataclass(frozen=True)
class ComponentCosts:
    """Latency (µs) of each component operation. Paper Table I notation."""

    W: float            # remote put
    R: float            # remote get
    A_cas: float        # atomic compare-and-swap
    A_fao: float        # atomic fetch-and-op
    am_rt: float        # active-message round trip (attentive target)
    handler: float      # target-side handler compute, per op (amortized)
    local: float = 0.05         # ell: local push/pop
    amo_apply: float = 0.0      # owner-lane serialized-apply term (TPU only)
    pt_overhead: float = 1.35   # progress-thread contention factor (Fig. 6 PT)
    name: str = "unnamed"


# Paper Table I (Cori Phase I, Cray Aries, 64 nodes). am_rt from Fig. 3's AM
# curve sitting between R and the persistent-CAS cluster.
CORI_PHASE1 = ComponentCosts(W=3.0, R=3.7, A_cas=3.8, A_fao=3.9,
                             am_rt=5.0, handler=0.15, name="cori-aries")

# TPU v5e ICI derivation: one exchange phase ≈ 1 µs neighbour latency; put is
# one phase, get/CAS/FAO are two dependent phases; AMOs additionally pay the
# owner-lane apply (no NIC atomics on TPU — DESIGN.md §2 divergence).
TPU_V5E_ICI = ComponentCosts(W=1.0, R=2.0, A_cas=2.3, A_fao=2.3,
                             am_rt=2.4, handler=0.10, amo_apply=0.3,
                             name="tpu-v5e-ici")


class DSOp(enum.Enum):
    HT_INSERT = "hash_insert"
    HT_FIND = "hash_find"
    Q_PUSH = "queue_push"
    Q_POP = "queue_pop"


def attentiveness_delay(c: ComponentCosts, stats: OpStats) -> float:
    """Expected extra wait for an AM to be serviced (paper Fig. 6).

    Without a progress thread the request waits on average half the target's
    interspersed compute block; with one, service is immediate but every AM
    pays the progress/compute contention factor.
    """
    if stats.progress_thread:
        return c.am_rt * (c.pt_overhead - 1.0)
    return stats.target_busy_us / 2.0


def _rpc_cost(c: ComponentCosts, stats: OpStats) -> float:
    return c.am_rt + c.handler + attentiveness_delay(c, stats)


def predict(op: DSOp, promise: Promise, backend: Backend,
            stats: Optional[OpStats] = None,
            params: ComponentCosts = CORI_PHASE1) -> float:
    """Best-case per-op latency (µs) — the paper's Tables II/III formulas."""
    s = stats or OpStats()
    c = params
    if backend == Backend.AUTO:
        raise ValueError("predict() needs a concrete backend; "
                         "use choose_backend() first")
    if backend == Backend.RPC:
        return _rpc_cost(c, s)

    probes = max(1.0, s.expected_probes)
    amo = c.amo_apply
    if op == DSOp.HT_INSERT:
        if promise == Promise.CRW:      # (a) fully atomic: CAS + W + FAO
            return probes * (c.A_cas + amo) + c.W + c.A_fao + amo
        if promise == Promise.CW:       # (b) phasal: CAS + W
            return probes * (c.A_cas + amo) + c.W
    if op == DSOp.HT_FIND:
        if promise == Promise.CRW:      # (c) FAO + R + FAO (read lock/unlock)
            return (c.A_fao + amo) + c.R + (c.A_fao + amo)
        if promise == Promise.CR:       # (d) bare get
            return c.R
    cont = max(1.0, s.contention)
    if op == DSOp.Q_PUSH:
        if promise == Promise.CRW:      # FAO + W + persistent CAS
            return (c.A_fao + amo) + c.W + cont * (c.A_cas + amo)
        if promise == Promise.CW:       # FAO + W
            return (c.A_fao + amo) + c.W
        if promise == Promise.CL:
            return c.local
    if op == DSOp.Q_POP:
        if promise == Promise.CRW:
            return (c.A_fao + amo) + c.R + cont * (c.A_cas + amo)
        if promise == Promise.CR:
            return (c.A_fao + amo) + c.R
        if promise == Promise.CL:
            return c.local
    raise ValueError(f"no formula for {op} at promise {promise}")


def predict_checksum_push(stats: Optional[OpStats] = None,
                          params: ComponentCosts = CORI_PHASE1) -> float:
    """Checksum-queue C_RW push: the ready-pointer CAS is replaced by an
    in-payload checksum word verified by the reader — FAO + W only."""
    c = params
    return (c.A_fao + c.amo_apply) + c.W


def network_phases(op: DSOp, promise: Promise, backend: Backend) -> int:
    """Dependent network phases (== chained collectives in the lowered HLO).

    This is the structural invariant the dry-run cross-checks: an RDMA C_RW
    insert must show 3 dependent op phases (5 exchanges) where the RPC one
    shows 1 (2 exchanges).
    """
    if backend == Backend.RPC:
        return 1
    table = {
        (DSOp.HT_INSERT, Promise.CRW): 3, (DSOp.HT_INSERT, Promise.CW): 2,
        (DSOp.HT_FIND, Promise.CRW): 3, (DSOp.HT_FIND, Promise.CR): 1,
        (DSOp.Q_PUSH, Promise.CRW): 3, (DSOp.Q_PUSH, Promise.CW): 2,
        (DSOp.Q_POP, Promise.CRW): 3, (DSOp.Q_POP, Promise.CR): 2,
        (DSOp.Q_PUSH, Promise.CL): 0, (DSOp.Q_POP, Promise.CL): 0,
    }
    return table[(op, promise)]


def choose_backend(op: DSOp, promise: Promise,
                   stats: Optional[OpStats] = None,
                   params: ComponentCosts = CORI_PHASE1) -> Backend:
    """The paper operationalized: pick the cheaper style for this workload."""
    s = stats or OpStats()
    rdma = predict(op, promise, Backend.RDMA, s, params)
    rpc = predict(op, promise, Backend.RPC, s, params)
    return Backend.RDMA if rdma <= rpc else Backend.RPC


def calibrate(measured: Dict[str, float],
              base: ComponentCosts = CORI_PHASE1) -> ComponentCosts:
    """Build a parameter set from measured component latencies (µs).

    Keys: any of W, R, A_cas, A_fao, am_rt, handler, local, amo_apply.
    """
    fields = {k: v for k, v in measured.items()
              if k in ComponentCosts.__dataclass_fields__}
    return replace(base, name="calibrated", **fields)


# ---------------------------------------------------------------------------
# Model-layer choosers: the same move-data-vs-move-compute decision applied
# to the training/serving stack (DESIGN.md §3).
# ---------------------------------------------------------------------------
def moe_dispatch_bytes(backend: Backend, *, tokens_per_rank: int,
                       d_model: int, expert_bytes_per_rank: int,
                       dtype_bytes: int = 2) -> int:
    """Bytes crossing the network per rank per layer for MoE dispatch.

    RPC  = ship activations to expert owners and back (2 × token bytes);
    RDMA = pull the expert weight blocks to the data owner (1 × weights).
    """
    if backend == Backend.RPC:
        return 2 * tokens_per_rank * d_model * dtype_bytes
    return expert_bytes_per_rank


def choose_moe_backend(**kw) -> Backend:
    rpc = moe_dispatch_bytes(Backend.RPC, **kw)
    rdma = moe_dispatch_bytes(Backend.RDMA, **kw)
    return Backend.RPC if rpc <= rdma else Backend.RDMA


def attention_gather_bytes(backend: Backend, *, kv_bytes_per_shard: int,
                           q_heads: int, head_dim: int, shards: int,
                           dtype_bytes: int = 2) -> int:
    """Distributed decode attention: RDMA = gather remote KV pages to the
    query owner; RPC = ship the query, compute partial attention at each KV
    shard, return (m, l, o) flash stats — bytes independent of cache length.
    """
    if backend == Backend.RDMA:
        return (shards - 1) * kv_bytes_per_shard
    stats_bytes = q_heads * (head_dim + 2) * 4  # o + (m, l) in f32
    query_bytes = q_heads * head_dim * dtype_bytes
    return (shards - 1) * (query_bytes + stats_bytes)


def choose_attention_backend(**kw) -> Backend:
    rdma = attention_gather_bytes(Backend.RDMA, **kw)
    rpc = attention_gather_bytes(Backend.RPC, **kw)
    return Backend.RDMA if rdma <= rpc else Backend.RPC
