"""The paper's analytical cost model (Tables I–III) as code, extended with
the TPU divergence terms from DESIGN.md §2 and the backend auto-chooser.

Every data-structure method cost is a sum of *component* costs. Component
costs come from one of three parameter sets:

- ``CORI_PHASE1``: the paper's measured Aries numbers (Table I) — used to
  reproduce the paper's predictions exactly;
- ``TPU_V5E_ICI``: derived ICI constants for the deployment target;
- ``calibrate(measured)``: fitted from this repo's own component
  microbenchmarks (benchmarks/components.py), used for the
  predicted-vs-measured validation (the paper's Figs. 4–5 methodology).

The model's real claim — and what we validate — is that it *orders*
implementations correctly, not that absolute microseconds match.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, replace
from typing import Dict, Optional, Tuple

from .types import Backend, OpStats, Promise


@dataclass(frozen=True)
class ComponentCosts:
    """Latency (µs) of each component operation. Paper Table I notation,
    extended with the fused component descriptors of DESIGN.md §2."""

    W: float            # remote put
    R: float            # remote get
    A_cas: float        # atomic compare-and-swap
    A_fao: float        # atomic fetch-and-op
    am_rt: float        # active-message round trip (attentive target)
    handler: float      # target-side handler compute, per op (amortized)
    local: float = 0.05         # ell: local push/pop
    amo_apply: float = 0.0      # owner-lane serialized-apply term (TPU only)
    pt_overhead: float = 1.35   # progress-thread contention factor (Fig. 6 PT)
    combine: float = 0.05       # sender-side coalescing overhead per op
                                # (duplicate-run lexsort + reply fan-out,
                                # DESIGN.md §6) — paid whether or not the
                                # batch actually contains duplicates
    cache_lookup: float = 0.15  # hot-bucket cache consult per op (DESIGN.md
                                # §8): the host-side tag+version check every
                                # cached-arm op pays, hit or miss
    pipe_depth_overhead: float = 0.0
                                # per-op penalty for each pipeline window
                                # beyond PIPELINE_STAGES (DESIGN.md §7): the
                                # engine has two stages, so depth > 2 adds
                                # queueing/host-scheduling overhead instead
                                # of overlap (the measured depth-4
                                # regression in BENCH_trajectory.json).
                                # 0.0 = pure saturation; calibrate() sets
                                # the measured slope.
    # P-dependence (DESIGN.md §9). Both default to 0.0 so every fixed-P
    # prediction (and any calibrated set that does not measure them) stays
    # bit-identical to the P-blind model; scaling_bench fits the slopes.
    exch_per_rank: float = 0.0
                                # fractional growth of each one-sided wire
                                # term per additional owner: the occupancy
                                # exchange and the request/reply all-to-alls
                                # are O(P) lanes wide, so each one-sided
                                # component costs
                                # base * (1 + exch_per_rank * (P - 1))
    fanout_per_rank: float = 0.0
                                # fractional growth of the AM round trip per
                                # additional owner: the handler reply
                                # fan-out crosses more lanes as the owner
                                # count grows, scaling am_rt by
                                # 1 + fanout_per_rank * (P - 1)
    retry_penalty: float = 0.0  # fixed per-retransmission overhead
                                # (DESIGN.md §10): timeout detection +
                                # backoff + re-submit bookkeeping charged on
                                # top of the re-sent unit's wire cost. Under
                                # OpStats.loss_rate = lr each op expects
                                # lr/(1-lr) retransmissions; the AM arms
                                # re-send a whole round trip (am_rt) while
                                # the one-sided arms re-send one phase
                                # (0.5 * W) — the asymmetry that flips the
                                # trade toward RDMA under loss. 0.0 keeps
                                # every lossless prediction bit-identical.
    # Fused component phases (None -> derived: the compound descriptor rides
    # the atomic's two exchanges, so a fused op costs its atomic; the saved
    # W / R / A_fao phases are the win). calibrate() overrides with measured
    # numbers from benchmarks/components.py.
    A_cas_put: Optional[float] = None      # claim + record write
    A_cas_put_pub: Optional[float] = None  # claim + write + publish flip
    A_fao_get: Optional[float] = None      # fetch-and-op + record gather
    name: str = "unnamed"

    def fused_cas_put(self) -> float:
        return self.A_cas if self.A_cas_put is None else self.A_cas_put

    def fused_cas_put_pub(self) -> float:
        return (self.A_cas if self.A_cas_put_pub is None
                else self.A_cas_put_pub)

    def fused_fao_get(self) -> float:
        return self.A_fao if self.A_fao_get is None else self.A_fao_get


# Paper Table I (Cori Phase I, Cray Aries, 64 nodes). am_rt from Fig. 3's AM
# curve sitting between R and the persistent-CAS cluster. Aries NICs have no
# fused descriptors; the derived defaults model what Storm-style composite
# ops would cost there.
CORI_PHASE1 = ComponentCosts(W=3.0, R=3.7, A_cas=3.8, A_fao=3.9,
                             am_rt=5.0, handler=0.15, name="cori-aries")

# TPU v5e ICI derivation: one exchange phase ≈ 1 µs neighbour latency; put is
# one phase, get/CAS/FAO are two dependent phases; AMOs additionally pay the
# owner-lane apply (no NIC atomics on TPU — DESIGN.md §2 divergence).
TPU_V5E_ICI = ComponentCosts(W=1.0, R=2.0, A_cas=2.3, A_fao=2.3,
                             am_rt=2.4, handler=0.10, amo_apply=0.3,
                             name="tpu-v5e-ici")


class DSOp(enum.Enum):
    HT_INSERT = "hash_insert"
    HT_FIND = "hash_find"
    Q_PUSH = "queue_push"
    Q_POP = "queue_pop"


# Backend *arms* the adaptive layer chooses between per batch (core/adaptive
# .py). Each maps onto (Backend, fused?, progress_thread?) below.
ARMS = ("rdma", "rdma_fused", "am", "am_pt")


def attentiveness_delay(c: ComponentCosts, stats: OpStats) -> float:
    """Expected extra wait for an AM to be serviced (paper Fig. 6).

    Without a progress thread the request waits on average half the target's
    interspersed compute block; with one, service is immediate but every AM
    pays the progress/compute contention factor.
    """
    if stats.progress_thread:
        return c.am_rt * (c.pt_overhead - 1.0)
    return stats.target_busy_us / 2.0


def _p_scaled(c: ComponentCosts, stats: OpStats) -> ComponentCosts:
    """Apply the §9 P-dependence to a parameter set: one-sided wire terms
    grow with the occupancy-exchange width, am_rt with the reply fan-out.
    Returns `c` unchanged when P is unknown (stats.nranks == 0) or both
    slopes are zero, and zeroes the slopes on the result so the scaling is
    idempotent under predict()'s internal recursion."""
    p = int(stats.nranks)
    if p <= 1 or (c.exch_per_rank == 0.0 and c.fanout_per_rank == 0.0):
        return c
    wire = 1.0 + c.exch_per_rank * (p - 1)
    fan = 1.0 + c.fanout_per_rank * (p - 1)
    return replace(
        c,
        W=c.W * wire, R=c.R * wire,
        A_cas=c.A_cas * wire, A_fao=c.A_fao * wire,
        A_cas_put=None if c.A_cas_put is None else c.A_cas_put * wire,
        A_cas_put_pub=(None if c.A_cas_put_pub is None
                       else c.A_cas_put_pub * wire),
        A_fao_get=None if c.A_fao_get is None else c.A_fao_get * wire,
        am_rt=c.am_rt * fan,
        exch_per_rank=0.0, fanout_per_rank=0.0)


def _rpc_cost(c: ComponentCosts, stats: OpStats) -> float:
    # Skew serializes handler work at the hot owner, but the AM round trip
    # itself is amortized by aggregation — only the (small) handler term
    # scales, which is why AM wins skewed batches (DESIGN.md §4).
    return (c.am_rt + c.handler * max(1.0, stats.skew)
            + attentiveness_delay(c, stats))


def predict(op: DSOp, promise: Promise, backend: Backend,
            stats: Optional[OpStats] = None,
            params: ComponentCosts = CORI_PHASE1,
            fused: bool = False, coalesce: bool = False,
            cached: bool = False) -> float:
    """Best-case per-op latency (µs) — the paper's Tables II/III formulas.

    fused=True prices the fused-descriptor engine (DESIGN.md §2): the
    hash-table insert collapses to probes fused claim/write(/publish)
    phases and the C_RW find's lock+get fuse into one A_FAO_GET pair.

    coalesce=True prices sender-side combining (DESIGN.md §6) via the
    distinct-row factor rho = stats.dedup: only rho of the batch's rows
    cross the wire and land in the owner apply lanes, so (a) the per-op
    component terms amortize over 1/rho duplicate riders and (b) the hot
    owner's serialized lane sees skew*rho of the mean load instead of
    skew. Every op additionally pays the sender-side `combine` overhead.
    rho = 1 (all-distinct traffic) degrades to the uncoalesced formula
    plus the combine overhead — which is why the chooser only coalesces
    when the observed dedup ratio is < 1.

    cached=True prices the hot-bucket cache tier (DESIGN.md §8) on the
    one-sided find: every op pays the host-side `cache_lookup`, the hit
    fraction (stats.hit_rate) pays NOTHING else — a hit issues zero
    exchanges — and only the miss fraction pays the wire formula (over
    which the coalesce discount still applies, since the miss subset
    feeds the coalesced plan). hit_rate = 0 degrades to the uncached
    formula plus the lookup overhead, which is why the chooser only
    prices the cached arm when a cache is attached and warm."""
    s = stats or OpStats()
    c = _p_scaled(params, s)
    if backend == Backend.AUTO:
        raise ValueError("predict() needs a concrete backend; "
                         "use choose_backend() first")
    if cached:
        if not (op == DSOp.HT_FIND and promise == Promise.CR
                and backend == Backend.RDMA):
            raise ValueError("cached pricing only applies to the "
                             "one-sided CR find (DESIGN.md §8)")
        hr = min(1.0, max(0.0, float(s.hit_rate)))
        base = predict(op, promise, backend, s, c, fused=fused,
                       coalesce=coalesce, cached=False)
        return c.cache_lookup + (1.0 - hr) * base
    if backend == Backend.RPC:
        if coalesce:
            rho = min(1.0, max(float(s.dedup), 1e-3))
            base = _rpc_cost(c, replace(s, skew=max(1.0, s.skew * rho)))
            return rho * base + (1.0 - rho) * c.handler + c.combine
        return _rpc_cost(c, s)

    probes = max(1.0, s.expected_probes)
    # Conflicting atomics funnel into one owner's serialized apply lane: a
    # batch with skew k makes the hot owner apply k× the mean load, so the
    # per-op owner-lane term scales with the skew (the Fig. 3
    # FAD-single-variable pathology, generalized to partial skew).
    if coalesce:
        # distinct-row factor: the hot lane only applies the distinct rows
        rho = min(1.0, max(float(s.dedup), 1e-3))
        base = predict(op, promise, backend,
                       replace(s, skew=max(1.0, s.skew * rho), dedup=1.0),
                       c, fused=fused, coalesce=False)
        return rho * base + c.combine
    amo = c.amo_apply * max(1.0, s.skew)
    if op == DSOp.HT_INSERT:
        if promise == Promise.CRW:      # (a) fully atomic: CAS + W + FAO
            if fused:                   # probes × (claim+write+publish)
                return probes * (c.fused_cas_put_pub() + amo)
            return probes * (c.A_cas + amo) + c.W + c.A_fao + amo
        if promise == Promise.CW:       # (b) phasal: CAS + W
            if fused:                   # probes × (claim+write)
                return probes * (c.fused_cas_put() + amo)
            return probes * (c.A_cas + amo) + c.W
    if op == DSOp.HT_FIND:
        if promise == Promise.CRW:      # (c) FAO + R + FAO (read lock/unlock)
            if fused:                   # lock+get fused, then unlock
                return (c.fused_fao_get() + amo) + (c.A_fao + amo)
            return (c.A_fao + amo) + c.R + (c.A_fao + amo)
        if promise == Promise.CR:       # (d) bare get
            return c.R
    cont = max(1.0, s.contention)
    if op == DSOp.Q_PUSH:
        if promise == Promise.CRW:      # FAO + W + persistent CAS
            return (c.A_fao + amo) + c.W + cont * (c.A_cas + amo)
        if promise == Promise.CW:       # FAO + W
            return (c.A_fao + amo) + c.W
        if promise == Promise.CL:
            return c.local
    if op == DSOp.Q_POP:
        if promise == Promise.CRW:
            return (c.A_fao + amo) + c.R + cont * (c.A_cas + amo)
        if promise == Promise.CR:
            return (c.A_fao + amo) + c.R
        if promise == Promise.CL:
            return c.local
    raise ValueError(f"no formula for {op} at promise {promise}")


def predict_checksum_push(stats: Optional[OpStats] = None,
                          params: ComponentCosts = CORI_PHASE1) -> float:
    """Checksum-queue C_RW push: the ready-pointer CAS is replaced by an
    in-payload checksum word verified by the reader — FAO + W only."""
    c = params
    return (c.A_fao + c.amo_apply) + c.W


def network_phases(op: DSOp, promise: Promise, backend: Backend,
                   fused: bool = False) -> int:
    """Dependent network phases (== chained collectives in the lowered HLO).

    This is the structural invariant the dry-run cross-checks: an RDMA C_RW
    insert must show 3 dependent op phases (5 exchanges) where the RPC one
    shows 1 (2 exchanges). With fused=True the fused engine's counts apply:
    the C_RW insert's claim+write+publish is ONE phase and the C_RW find is
    2 (fused lock+get, then unlock).
    """
    if backend == Backend.RPC:
        return 1
    table = {
        (DSOp.HT_INSERT, Promise.CRW): 3, (DSOp.HT_INSERT, Promise.CW): 2,
        (DSOp.HT_FIND, Promise.CRW): 3, (DSOp.HT_FIND, Promise.CR): 1,
        (DSOp.Q_PUSH, Promise.CRW): 3, (DSOp.Q_PUSH, Promise.CW): 2,
        (DSOp.Q_POP, Promise.CRW): 3, (DSOp.Q_POP, Promise.CR): 2,
        (DSOp.Q_PUSH, Promise.CL): 0, (DSOp.Q_POP, Promise.CL): 0,
    }
    fused_table = {
        (DSOp.HT_INSERT, Promise.CRW): 1, (DSOp.HT_INSERT, Promise.CW): 1,
        (DSOp.HT_FIND, Promise.CRW): 2,
    }
    if fused and (op, promise) in fused_table:
        return fused_table[(op, promise)]
    return table[(op, promise)]


# Exchanges per two-phase component op (request + reply) on the planned
# engine; the one-time plan-occupancy exchange is accounted separately.
PLAN_EXCHANGES = 1


def exchange_count(op: DSOp, promise: Promise, backend: Backend,
                   fused: bool = False, probes: int = 1) -> int:
    """All-to-all exchanges issued by `routing.exchange` per batch — what
    the roofline collective counter sees in the lowered HLO (excluding the
    one PLAN_EXCHANGES occupancy exchange when fused/planned).

    Unfused (route() per phase): a two-phase op costs 3 exchanges (request
    payload + request occupancy mask + reply) and a put costs 2. Planned:
    the occupancy mask was exchanged at plan time, so a two-phase op is 2
    (request + reply) and a put is 1 — hence C_RW find drops from 9 to 4
    per probe at the engine level, and from 6 to 4 in the paper's
    phase-pair accounting.
    """
    if backend == Backend.RPC:
        return 2 if fused else 3       # AM request (+mask) + reply
    two, put = (2, 1) if fused else (3, 2)
    # queue CRW counts assume one publish-CAS round (predict's cont=1
    # best case); both queue FAO phases (reserve + failure return) count.
    table = {
        (DSOp.HT_INSERT, Promise.CRW):
            probes * two if fused else probes * two + put + two,
        (DSOp.HT_INSERT, Promise.CW):
            probes * two if fused else probes * two + put,
        (DSOp.HT_FIND, Promise.CRW):
            probes * 2 * two if fused else probes * 3 * two,
        (DSOp.HT_FIND, Promise.CR): probes * two,
        (DSOp.Q_PUSH, Promise.CRW): two + two + put + two,
        (DSOp.Q_PUSH, Promise.CW): two + two + put,
        (DSOp.Q_POP, Promise.CRW): two + two + two + two,
        (DSOp.Q_POP, Promise.CR): two + two + two,
        (DSOp.Q_PUSH, Promise.CL): 0, (DSOp.Q_POP, Promise.CL): 0,
    }
    return table[(op, promise)]


def choose_backend(op: DSOp, promise: Promise,
                   stats: Optional[OpStats] = None,
                   params: ComponentCosts = CORI_PHASE1,
                   fused: bool = False) -> Backend:
    """The paper operationalized: pick the cheaper style for this workload.
    fused=True re-validates the choice against the fused/planned engine
    (the RDMA side gets cheaper; RPC is already one round trip)."""
    s = stats or OpStats()
    rdma = predict(op, promise, Backend.RDMA, s, params, fused=fused)
    rpc = predict(op, promise, Backend.RPC, s, params)
    return Backend.RDMA if rdma <= rpc else Backend.RPC


def arm_coalesces(op: DSOp, arm: str, dedup: float) -> bool:
    """Whether the engine actually runs `arm` with sender-side combining
    (DESIGN.md §6) for this op at this observed dedup ratio — the single
    rule shared by the pricer (predict_arm) and the executor
    (adaptive.decide), so arms are never scored with a discount the
    execution cannot realize:

    - the seed `rdma` arm never coalesces (it is the uncombined baseline);
    - queue ops never coalesce on the AM arms (a push handler is NOT
      idempotent across identical requests — each push must land) and
      the one-sided queue arms only combine their ticket FAOs;
    - everything else coalesces exactly when duplicates exist (dedup < 1).
    """
    if dedup >= 1.0 or arm == "rdma":
        return False
    if op in (DSOp.Q_PUSH, DSOp.Q_POP) and arm in ("am", "am_pt"):
        return False
    return True


def arm_caches(op: DSOp, promise: Promise, arm: str) -> bool:
    """Whether `arm` consults the hot-bucket cache (DESIGN.md §8) for this
    op — the single rule shared by the pricer (`predict_arm`) and the
    executor (adaptive.decide), mirroring `arm_coalesces`.

    Only the planned+fused one-sided find at the bare-read promise caches:
    CR is the only promise whose reply is a plain published record (CRW's
    read locks must hit the owner every time), and the seed `rdma` arm
    stays the uncombined, uncached baseline. The AM arms never cache —
    the handler round trip IS their aggregation story."""
    return (op == DSOp.HT_FIND and promise == Promise.CR
            and arm == "rdma_fused")


def _predict_arm_flat(op: DSOp, promise: Promise, arm: str, s: OpStats,
                      params: ComponentCosts) -> float:
    """Un-pipelined (lock-step) per-op latency of one arm — the sum of its
    origin- and owner-side components. `predict_arm` applies the §7 overlap
    interpolation on top of this."""
    co = arm_coalesces(op, arm, s.dedup)
    if arm == "rdma":
        base = predict(op, promise, Backend.RDMA, s, params, fused=False)
    elif arm == "rdma_fused":
        ca = s.hit_rate > 0.0 and arm_caches(op, promise, arm)
        base = predict(op, promise, Backend.RDMA, s, params, fused=True,
                       coalesce=co, cached=ca)
    elif arm == "am":
        base = predict(op, promise, Backend.RPC,
                       replace(s, progress_thread=False), params,
                       coalesce=co)
    elif arm == "am_pt":
        base = predict(op, promise, Backend.RPC,
                       replace(s, progress_thread=True), params,
                       coalesce=co)
    else:
        raise ValueError(f"unknown arm {arm!r}; expected one of {ARMS}")
    # §10 retry term: under per-attempt loss rate lr each op expects
    # lr/(1-lr) retransmissions of its smallest retryable unit — the AM
    # arms re-send a whole round trip, the one-sided arms one wire phase
    # (half a put) — plus the fixed retry_penalty bookkeeping. lr = 0
    # contributes exactly nothing, so every lossless prediction (and the
    # pinned orderings built on them) is bit-identical to the §9 model.
    lr = min(0.95, max(0.0, s.loss_rate))
    if lr > 0.0:
        retries = lr / (1.0 - lr)
        unit = params.am_rt if arm in ("am", "am_pt") else 0.5 * params.W
        base += retries * (params.retry_penalty + unit)
    return base


def overlap_split(op: DSOp, promise: Promise, arm: str,
                  stats: Optional[OpStats] = None,
                  params: ComponentCosts = CORI_PHASE1
                  ) -> Tuple[float, float]:
    """Split one arm's flat cost into (origin_us, owner_us) — the two
    pipeline stages of DESIGN.md §7.

    origin_us — route/coalesce/plan construction and the send exchange:
    the work batch *k+1* performs while batch *k* is still applying.
    owner_us — everything attributable to target-side progress: the
    serialized `amo_apply` owner lane of the one-sided arms, and the
    handler compute plus the attentiveness delay of the AM arms. This is
    the share the pipeline hides behind the next batch's origin stage.

    Computed by differencing: owner_us = flat - flat|owner-terms-zeroed,
    so the split composes correctly with the skew and dedup factors
    (which scale both sides through `predict`). origin_us + owner_us ==
    the flat prediction exactly."""
    s = replace(stats or OpStats(), pipeline_depth=1)
    total = _predict_arm_flat(op, promise, arm, s, params)
    if arm in ("am", "am_pt"):
        wire_params = replace(params, handler=0.0, pt_overhead=1.0)
        wire_stats = replace(s, target_busy_us=0.0)
    else:
        wire_params = replace(params, amo_apply=0.0)
        wire_stats = s
    origin = _predict_arm_flat(op, promise, arm, wire_stats, wire_params)
    origin = min(origin, total)
    return origin, total - origin


# The engine (core/pipeline.py) is a TWO-stage pipeline: host staging
# (route/coalesce/plan on the Python thread) and device apply. Two in-flight
# windows already achieve all the overlap the structure admits; extra depth
# only lengthens the submission queue. The measured trajectory agrees —
# per-batch medians saturate at depth 2 and REGRESS at depth 4 (~7% in
# BENCH_trajectory.json: 18.2 ms -> 19.5 ms), the regression being host
# scheduling/retirement overhead for the extra queued windows.
PIPELINE_STAGES = 2


def predict_pipelined(op: DSOp, promise: Promise, arm: str,
                      stats: Optional[OpStats] = None,
                      params: ComponentCosts = CORI_PHASE1,
                      depth: Optional[int] = None) -> float:
    """Steady-state per-batch latency of one arm at pipeline depth d
    (DESIGN.md §7):

        T(d) = max(A, B) + min(A, B) / min(d, S)
                 + max(0, d - S) * pipe_depth_overhead,   S = PIPELINE_STAGES

    with (A, B) = `overlap_split` — a two-stage pipeline keeps d windows
    in flight, so the shorter stage hides behind the longer one except for
    the un-overlapped residue. d = 1 degenerates EXACTLY to the flat sum
    A + B (the synchronous engine). The overlap term SATURATES at
    S = PIPELINE_STAGES: the engine has two stages, so no overlap beyond
    double-buffering exists to win, and each extra queued window costs the
    measured per-depth `pipe_depth_overhead` (0 by default; calibrate()
    sets the slope fitted from the depth sweep). `depth` defaults to
    stats.pipeline_depth."""
    s = stats or OpStats()
    d = max(1, int(s.pipeline_depth if depth is None else depth))
    a, b = overlap_split(op, promise, arm, s, params)
    t = max(a, b) + min(a, b) / min(d, PIPELINE_STAGES)
    return t + max(0, d - PIPELINE_STAGES) * params.pipe_depth_overhead


# Depths the auto-depth chooser prices (DESIGN.md §9) — the same ladder the
# depth-sweep bench measures. With PIPELINE_STAGES = 2 the model can only
# ever prefer 1 or 2 (depth 4 adds pipe_depth_overhead and no overlap), but
# keeping 4 in the ladder pins exactly that: the chooser must never pick it.
DEPTH_CANDIDATES = (1, 2, 4)


def choose_depth(op: DSOp, promise: Promise, arm: str,
                 stats: Optional[OpStats] = None,
                 params: ComponentCosts = CORI_PHASE1,
                 candidates: Tuple[int, ...] = DEPTH_CANDIDATES,
                 max_depth: Optional[int] = None) -> int:
    """Model-side pipeline-depth pick: argmin of `predict_pipelined` over
    the candidate ladder, tie-broken toward the SHALLOWEST depth (depth is
    never free — each extra window holds host memory and delays retirement,
    so equal predicted latency means take the smaller window count).

    An op whose owner-side share is zero (e.g. the bare CR find: no apply
    lane, no handler) predicts identical latency at every depth and stays
    at depth 1; owner-heavy ops (inserts with apply lanes, AM arms under
    poor attentiveness) flip to depth 2 as the hidden share grows. The
    online layer (`AdaptiveEngine.choose_depth`) overlays observed
    per-depth batch latency on top of this prior."""
    s = stats or OpStats()
    best_d, best_t = 1, float("inf")
    for d in sorted(set(int(x) for x in candidates)):
        if d < 1 or (max_depth is not None and d > max_depth):
            continue
        t = predict_pipelined(op, promise, arm, s, params, depth=d)
        if t < best_t - 1e-9:
            best_d, best_t = d, t
    return best_d


def predict_arm(op: DSOp, promise: Promise, arm: str,
                stats: Optional[OpStats] = None,
                params: ComponentCosts = CORI_PHASE1) -> float:
    """Per-op latency of one adaptive *arm* (see ARMS).

    `rdma` / `rdma_fused` are the seed and planned+fused one-sided engines;
    `am` / `am_pt` are aggregated active messages without / with a progress
    thread (the paper Fig. 6 "PT" curve). The AUTO chooser in
    core/adaptive.py calls this for every arm and takes the argmin.

    The observed dedup ratio (stats.dedup, the adaptive layer's third
    online signal) prices coalescing where the engine actually applies it
    (`arm_coalesces`): duplicate traffic discounts the fused/AM arms with
    the distinct-row factor — the seed `rdma` arm never coalesces and
    keeps the plain formula.

    stats.pipeline_depth > 1 (the pipelined engine, DESIGN.md §7) applies
    the overlap term via `predict_pipelined`: the arm's owner-side share
    (serialized apply lane, or handler + attentiveness for the AM arms)
    overlaps the next batch's route+send, so owner-heavy arms — notably AM
    under poor attentiveness — are discounted by exactly the latency the
    pipeline hides, which is how the chooser learns to prefer AM arms once
    overlap hides their handler latency."""
    s = stats or OpStats()
    if int(s.pipeline_depth) > 1:
        return predict_pipelined(op, promise, arm, s, params)
    return _predict_arm_flat(op, promise, arm, s, params)


def calibrate(measured: Dict[str, float],
              base: ComponentCosts = CORI_PHASE1) -> ComponentCosts:
    """Build a parameter set from measured component latencies (µs).

    Keys: any of W, R, A_cas, A_fao, am_rt, handler, local, amo_apply,
    A_cas_put, A_cas_put_pub, A_fao_get, combine, cache_lookup,
    pipe_depth_overhead, retry_penalty.
    """
    fields = {k: v for k, v in measured.items()
              if k in ComponentCosts.__dataclass_fields__}
    return replace(base, name="calibrated", **fields)


# ---------------------------------------------------------------------------
# Model-layer choosers: the same move-data-vs-move-compute decision applied
# to the training/serving stack (DESIGN.md §3).
# ---------------------------------------------------------------------------
def moe_dispatch_bytes(backend: Backend, *, tokens_per_rank: int,
                       d_model: int, expert_bytes_per_rank: int,
                       dtype_bytes: int = 2) -> int:
    """Bytes crossing the network per rank per layer for MoE dispatch.

    RPC  = ship activations to expert owners and back (2 × token bytes);
    RDMA = pull the expert weight blocks to the data owner (1 × weights).
    """
    if backend == Backend.RPC:
        return 2 * tokens_per_rank * d_model * dtype_bytes
    return expert_bytes_per_rank


def choose_moe_backend(**kw) -> Backend:
    rpc = moe_dispatch_bytes(Backend.RPC, **kw)
    rdma = moe_dispatch_bytes(Backend.RDMA, **kw)
    return Backend.RPC if rpc <= rdma else Backend.RDMA


def attention_gather_bytes(backend: Backend, *, kv_bytes_per_shard: int,
                           q_heads: int, head_dim: int, shards: int,
                           dtype_bytes: int = 2) -> int:
    """Distributed decode attention: RDMA = gather remote KV pages to the
    query owner; RPC = ship the query, compute partial attention at each KV
    shard, return (m, l, o) flash stats — bytes independent of cache length.
    """
    if backend == Backend.RDMA:
        return (shards - 1) * kv_bytes_per_shard
    stats_bytes = q_heads * (head_dim + 2) * 4  # o + (m, l) in f32
    query_bytes = q_heads * head_dim * dtype_bytes
    return (shards - 1) * (query_bytes + stats_bytes)


def choose_attention_backend(**kw) -> Backend:
    rdma = attention_gather_bytes(Backend.RDMA, **kw)
    rpc = attention_gather_bytes(Backend.RPC, **kw)
    return Backend.RDMA if rdma <= rpc else Backend.RPC
