"""Pipelined in-flight batch engine: futures-style op handles over
double-buffered exchange windows (DESIGN.md §7).

The paper's central RPC liability is *attentiveness*: remote progress only
happens when the target enters the runtime, so un-overlapped round trips
dominate. The seed engine had the same shape — one op batch ran
synchronously end-to-end, leaving the owner-apply lane idle while the next
batch's descriptors were still being routed. This module closes that gap:

    pipe = Pipeline(ht, depth=2)               # two in-flight windows
    h1 = hashtable.insert_async(pipe, k1, v1)  # batch 0: staged, in flight
    h2 = hashtable.find_async(pipe, k2)        # batch 1 routes while batch
    ok, probes = h1.result()                   #   0's owner lane applies
    ht = pipe.flush()                          # force everything, get state

`submit` stages a batch — the routing/coalescing/plan construction and the
send exchange are *dispatched* immediately — and returns a `Handle`
without waiting for the owner-apply and reply exchange to complete.
`Handle.result()` forces completion. `depth` counts exchange windows,
INCLUDING the one being staged: with `depth >= 2` the engine keeps
windows in flight across submits, so batch *k+1*'s route+send (and the
caller's interspersed compute) overlaps batch *k*'s apply+reply;
`depth=1` is the single-window lock-step engine — every submit completes
its own batch before returning, bit-exactly the synchronous path.

How the overlap is realized in this emulation: each batch is a chain of
JAX computations dispatched asynchronously — the Python thread returns as
soon as the work is enqueued, and batch *k+1*'s staging (the adaptive
decision, `routing.make_plan_np`'s host-side argsort, descriptor
construction, jit-cache dispatch) runs while the device executes batch
*k*. State threads through the pipeline functionally: batch *k+1* is
staged against batch *k*'s not-yet-materialized output window — the
dependency resolves on the device, never on the host. The two (at depth 2)
live windows are physically distinct device buffers: functional updates
ARE the double buffering.

Deferred (AM) batches and attentiveness: ops whose chosen arm is an active
message are submitted with `deferred=True`. They wait in the
`AMEngine` dispatch queue and drain at the next *dispatch point* — the
next eager submit, a `result()`, or a `flush()` (`AMEngine.
drain_dispatch_queue`, DESIGN.md §7). Their service latency is therefore
exactly the time to the next overlap window, which makes the paper's
attentiveness a tunable, measurable quantity: `benchmarks/
pipeline_bench.py` sweeps the inter-submit `busy_wait` knob against it.

Ordering contract: submission order IS serialization order. Deferred
batches are drained before any later eager batch stages, so the state
each batch observes is identical to the synchronous engine's — the
conformance suite (tests/test_pipeline.py) pins async == sync == oracle
on randomized interleaved submit streams with out-of-order `result()`
forcing.
"""
from __future__ import annotations

import collections
from typing import Any, Callable, List, Optional, Tuple

import jax

from . import faults as flt
from . import window as win_mod

# An op stages one batch against the current structure state and returns
# (state', outputs). Outputs are what Handle.result() yields.
OpFn = Callable[[Any], Tuple[Any, Any]]


class Handle:
    """Future for one submitted op batch (DESIGN.md §7).

    A Handle is created by `Pipeline.submit` and resolves to the batch's
    outputs — e.g. `(ok, probes)` for a hash-table insert. Handles may be
    forced in any order; forcing never changes values (results are
    deterministic — the conformance suite pins out-of-order forcing).
    """

    __slots__ = ("seq", "label", "deferred", "_pipe", "_op", "_outputs",
                 "_staged", "_forced", "_error")

    def __init__(self, pipe: "Pipeline", seq: int, label: Optional[str],
                 deferred: bool):
        self.seq = seq
        self.label = label
        self.deferred = deferred
        self._pipe = pipe
        self._op: Optional[OpFn] = None
        self._outputs: Any = None
        self._staged = False
        self._forced = False
        self._error: Optional[BaseException] = None

    def done(self) -> bool:
        """True when the batch's outputs are materialized on the device.

        Never blocks: a deferred batch still waiting for a dispatch point
        reports False, as does a staged batch whose device work is in
        flight (falls back to True-once-staged where the runtime lacks
        `is_ready`)."""
        if self._forced or self._error is not None:
            return True
        if not self._staged:
            return False
        try:
            return all(x.is_ready() for x in jax.tree_util.tree_leaves(
                self._outputs) if hasattr(x, "is_ready"))
        except Exception:
            return True

    def result(self, timeout: Optional[int] = None) -> Any:
        """Force completion and return the batch's outputs.

        Blocks until the device work is done; drains the deferred-dispatch
        queue first if this batch (or an earlier one) is still waiting for
        a dispatch point. Idempotent — repeated calls return the same
        values.

        timeout (DESIGN.md §10): under an active `faults.FaultPlan`, the
        maximum number of simulated dispatch rounds to wait for a stalled
        deferred-AM queue before raising `faults.RemoteTimeout` (default:
        the plan's `RetryPolicy.deadline`) — a permanently dead owner
        raises immediately instead of spinning. Without a plan the engine
        cannot stall, so the value is accepted but unused. A timed-out
        Handle stays failed: repeated `result()` re-raises the same
        RemoteTimeout even if the owner later wakes (the classic
        ambiguity of a timed-out RPC — the op may or may not have run;
        here it is guaranteed dropped, see `Pipeline.close`)."""
        self._pipe._force(self, timeout=timeout)
        return self._outputs


class Pipeline:
    """In-flight op-batch manager over a functionally threaded state.

    state:     the structure being operated on (e.g. a `DHashTable` or
               `DQueue` — any value the submitted ops thread through).
    depth:     exchange windows, including the one being staged.
               1 = synchronous lock-step (each submit completes its own
               batch before returning — bit-exact with the direct engine
               calls); 2 = double-buffered (the default): one window
               stages/sends while the previous one applies/replies, so
               at most one batch is left in flight when submit returns.
    am_engine: optional `am.AMEngine`. Deferred (AM-arm) submissions queue
               on it and drain at dispatch points; without one the
               pipeline keeps its own FIFO with the same semantics.
    auto_depth: make the window count a CHOOSER decision (DESIGN.md §9):
               the async front-ends ask their AdaptiveEngine's
               `choose_depth` before each submit and retarget the window
               count via `set_depth`. The constructor `depth` becomes the
               CAP — the chooser may shrink the window but never exceeds
               the caller's budget.

    `Pipeline.state` is the latest *staged* state — its device values may
    still be in flight; `flush()` forces everything and returns it.
    """

    def __init__(self, state: Any, depth: int = 2, am_engine=None,
                 auto_depth: bool = False):
        if depth < 1:
            raise ValueError(f"pipeline depth must be >= 1, got {depth}")
        self._state = state
        self.depth = depth
        self.am_engine = am_engine
        self.auto_depth = auto_depth
        self.max_depth = depth
        self._inflight: collections.deque = collections.deque()
        self._own_queue: collections.deque = collections.deque()
        self._seq = 0
        self._closed = False

    # -- context manager (DESIGN.md §10: teardown never strands batches) ----
    def __enter__(self) -> "Pipeline":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is None:
            # clean exit: a full dispatch point — deferred batches drain,
            # every handle forces; failures (RemoteTimeout on a stalled
            # queue) propagate to the caller
            self.flush()
        else:
            # exception path: best-effort teardown that never masks the
            # in-flight exception
            self.close()
        return False

    def close(self) -> None:
        """Best-effort teardown: drain the deferred queue so no dispatch
        thunk is stranded, force every stageable Handle, and fail the
        rest with `faults.RemoteTimeout`. Errors are swallowed (this is
        the exception path of the context manager); queued thunks of this
        pipeline become no-ops, so a later engine drain by another user
        cannot resurrect a batch the caller was told had failed."""
        try:
            self._drain_deferred()
        except Exception:
            pass
        for h in list(self._inflight):
            if h._staged:
                try:
                    self._force(h)
                except Exception:
                    pass
            else:
                h._error = flt.RemoteTimeout(
                    f"pipeline closed with batch seq={h.seq} "
                    f"({h.label or 'op'}) never serviced")
                try:
                    self._inflight.remove(h)
                except ValueError:
                    pass
        self._closed = True
        self._own_queue.clear()
        self._note_inflight()

    def set_depth(self, depth: int) -> None:
        """Retarget the in-flight window count (the §9 auto-depth hook).

        Clamped to [1, max_depth]. Shrinking forces the oldest batches
        immediately so the at-most-`depth - 1`-in-flight invariant holds
        before the next submit; growing just admits more windows. Safe to
        call between any two submits — ordering is untouched."""
        d = max(1, min(int(depth), self.max_depth))
        self.depth = d
        while len(self._inflight) > d - 1:
            self._force(self._inflight[0])

    def _note_inflight(self) -> None:
        win_mod.note_pipeline_inflight(self, bool(self._inflight))

    # -- introspection ------------------------------------------------------
    @property
    def staged_state(self) -> Any:
        """The raw staged state, WITHOUT draining deferred batches.

        For metadata reads at submit time (e.g. a `DHashTable`'s static
        `nranks`/`nslots`, which never change across the pipeline) — the
        async front-ends use this so peeking never forces a dispatch
        point. Use `state` for a value reflecting every submission."""
        return self._state

    @property
    def state(self) -> Any:
        """Latest staged state (drains any pending deferred batches so the
        value reflects every submission; device work may still be in
        flight — this property never blocks on it)."""
        self._drain_deferred()
        return self._state

    @property
    def in_flight(self) -> int:
        """Unforced batches currently tracked (staged + deferred)."""
        return len(self._inflight)

    @property
    def pending_deferred(self) -> int:
        """Deferred batches still waiting for a dispatch point."""
        if self.am_engine is not None:
            return self.am_engine.pending_dispatches
        return len(self._own_queue)

    # -- submission ---------------------------------------------------------
    def submit(self, op: OpFn, deferred: bool = False,
               label: Optional[str] = None) -> Handle:
        """Stage one op batch; returns its Handle immediately.

        op: callable `state -> (state', outputs)`. Eager ops run now (their
        device work is dispatched asynchronously — the host does not wait);
        `deferred=True` queues the op for the next dispatch point (the AM
        attentiveness model — see the module docstring). Before returning,
        the oldest batches are forced until at most `depth - 1` remain in
        flight: depth=1 therefore completes the submitted batch itself
        (the lock-step engine), depth=2 leaves exactly this batch in
        flight while the caller stages the next one."""
        h = Handle(self, self._seq, label, deferred)
        self._seq += 1
        if not deferred:
            self._drain_deferred()
            if self.pending_deferred:
                # an inattentive owner (§10 queue stall) still holds
                # earlier deferred batches: this submission must queue
                # behind them — submission order IS serialization order,
                # with or without faults
                deferred = h.deferred = True
        if deferred:
            h._op = op
            self._enqueue(h)
        else:
            self._run(h, op)
        self._inflight.append(h)
        self._note_inflight()
        while len(self._inflight) > self.depth - 1:
            self._force(self._inflight[0])
        return h

    def flush(self) -> Any:
        """Force every in-flight batch (a dispatch point) and return the
        fully materialized state."""
        self._drain_deferred()
        while self._inflight:
            self._force(self._inflight[0])
        jax.block_until_ready(jax.tree_util.tree_leaves(self._state))
        return self._state

    # -- internals ----------------------------------------------------------
    def _enqueue(self, h: Handle) -> None:
        def thunk():
            if self._closed or h._error is not None:
                return  # failed/closed batches are guaranteed dropped
            self._run(h, h._op)

        if self.am_engine is not None:
            self.am_engine.queue_dispatch(thunk)
        else:
            self._own_queue.append(thunk)

    def _run(self, h: Handle, op: OpFn) -> None:
        """Stage one batch: run the op against the current state inside the
        batch's slot scope (per-slot phase logs, DESIGN.md §7)."""
        with win_mod.slot_scope(h.seq % self.depth, h.seq):
            state, outputs = op(self._state)
        self._state = state
        h._outputs = outputs
        h._staged = True

    def _drain_deferred(self) -> None:
        """Enter a dispatch point: run every queued deferred batch FIFO.

        Deferred batches are always a suffix of the submission order (an
        eager submit drains them first), so draining preserves the
        synchronous engine's serialization."""
        if self.am_engine is not None:
            self.am_engine.drain_dispatch_queue()
        else:
            while self._own_queue:
                self._own_queue.popleft()()

    def _force(self, h: Handle, timeout: Optional[int] = None) -> None:
        if h._error is not None:
            raise h._error
        if h._forced:
            return
        if not h._staged:
            self._drain_deferred()
        if not h._staged:
            # DESIGN.md §10: the deferred queue refused to drain — an
            # inattentive owner. Keep offering service opportunities
            # (each drain attempt advances the plane's round clock) up to
            # `timeout` simulated rounds, then fail typed instead of
            # hanging; a permanently dead owner fails without spinning.
            plane = flt.active_plane()
            if plane is not None:
                rounds = int(timeout if timeout is not None
                             else plane.retry.deadline)
                for _ in range(rounds):
                    if plane.queue_dead():
                        break
                    self._drain_deferred()
                    if h._staged:
                        break
                if not h._staged:
                    why = ("permanently dead" if plane.queue_dead()
                           else f"stalled past {rounds} rounds")
                    err = flt.RemoteTimeout(
                        f"batch seq={h.seq} ({h.label or 'op'}) not "
                        f"serviced: deferred-AM queue {why}")
                    h._error = err
                    try:
                        self._inflight.remove(h)
                    except ValueError:
                        pass
                    self._note_inflight()
                    raise err
        assert h._staged, "deferred batch did not stage at dispatch point"
        jax.block_until_ready(jax.tree_util.tree_leaves(h._outputs))
        h._forced = True
        try:
            self._inflight.remove(h)
        except ValueError:
            pass
        self._note_inflight()


def submit_many(pipe: Pipeline, ops: List[OpFn]) -> List[Handle]:
    """Convenience: submit a list of ops in order, returning their handles."""
    return [pipe.submit(op) for op in ops]
