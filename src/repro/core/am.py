"""Active-message (RPC) engine: aggregated request routing + local handlers.

The TPU-native realization of GASNet-EX style active messages (DESIGN.md §2):

- `dispatch` = ONE request exchange + arbitrary shard-local handler + ONE
  reply exchange. The number of network phases is *independent of the
  handler's control flow* — the paper's central RPC property.
- Handlers obey the paper's AM restrictions by construction: they are pure
  shard-local JAX functions, so they cannot send further messages or touch
  the network.
- Attentiveness: an owner services requests only when its SPMD program
  reaches a dispatch point. The latency penalty of infrequent dispatch
  points is modeled in `costmodel.attentiveness_delay` and measured by the
  Fig. 6 benchmark; the engine itself is oblivious (as is GASNet's API).
"""
from __future__ import annotations

import collections
from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from . import faults as flt
from . import routing

Array = jax.Array

# handler(state_row, payload (m, W) int32, mask (m,)) -> (state_row', reply (m, RW) int32)
HandlerFn = Callable[[Any, Array, Array], Tuple[Any, Array]]


@dataclass(frozen=True)
class Handler:
    """A registered active-message handler (paper Fig. 2's insert_handler).

    `batched_fn`, when provided, processes all owners' request grids at once
    — signature (state (P,...), payload (P,m,W), mask (P,m)) -> (state',
    replies (P,m,RW)) — and is the hook through which Pallas handler
    kernels (kernels/hash_probe.py) replace the vmapped per-row path.
    """

    name: str
    fn: HandlerFn
    reply_width: int  # int32 words returned per op (0 => no-reply AM)
    batched_fn: Optional[Callable[[Any, Array, Array],
                                  Tuple[Any, Array]]] = None


# Explicit bound on the dispatch diagnostic ring (see AMEngine.dispatch_log).
DISPATCH_LOG_MAX = 1024


class AMEngine:
    """Handler registry + dispatch. One engine per distributed structure."""

    def __init__(self, nranks: int, dispatch_log_max: int = DISPATCH_LOG_MAX):
        self.nranks = nranks
        self._handlers: dict[str, Handler] = {}
        # (handler name, Decision, info) per dispatch issued by the adaptive
        # layer — benchmarks read this to log which arm serviced a batch.
        # Bounded ring: library callers never drain it; `drain_dispatch_log`
        # returns-and-clears for callers that do.
        self.dispatch_log: collections.deque = collections.deque(
            maxlen=dispatch_log_max)
        # Deferred-dispatch queue (DESIGN.md §7): AM batches submitted
        # through the pipeline engine wait here until the next *dispatch
        # point* — the paper's attentiveness, made an explicit queue. The
        # pipeline drains it whenever it enters the engine (an eager
        # submit, a Handle.result(), a flush), so AM service latency is
        # exactly the time to the next overlap window.
        self._pending: collections.deque = collections.deque()
        # dispatch points entered (drains, including empty ones): together
        # with the inter-submit busy_wait knob this makes attentiveness a
        # measurable quantity (benchmarks/pipeline_bench.py).
        self.dispatch_points = 0

    def drain_dispatch_log(self):
        """Return and clear the (handler, decision, info) dispatch log."""
        out = list(self.dispatch_log)
        self.dispatch_log.clear()
        return out

    # -- deferred dispatch (pipeline integration, DESIGN.md §7) ------------
    @property
    def pending_dispatches(self) -> int:
        """Queued dispatch thunks awaiting the next dispatch point."""
        return len(self._pending)

    def queue_dispatch(self, thunk) -> None:
        """Enqueue a zero-arg dispatch thunk for the next dispatch point.

        Thunks run FIFO at `drain_dispatch_queue`; the engine stays
        oblivious to what they do (they typically call `dispatch` and stash
        the replies — see core/pipeline.py). Queueing models the paper's
        attentiveness liability: remote progress happens only when the
        target enters the runtime."""
        self._pending.append(thunk)

    def drain_dispatch_queue(self) -> int:
        """Enter a dispatch point: service every queued dispatch, FIFO.

        Returns the number of dispatches serviced. Counted in
        `dispatch_points` whether or not anything was pending (an attentive
        target polls on every entry).

        Under an active FaultPlan (DESIGN.md §10) each call is one AM
        service opportunity: the plane's round clock ticks, and while the
        plan stalls the queue (`stall_rounds` / `stall_forever` — the
        paper's inattentive owner taken to its limit) the queue does NOT
        drain and no dispatch point is counted (the owner never entered
        the runtime)."""
        plane = flt.active_plane()
        if plane is not None:
            stalled = plane.queue_stalled()
            plane.tick()
            if stalled:
                plane.stall_hits += 1
                return 0
        self.dispatch_points += 1
        count = len(self._pending)
        while self._pending:
            self._pending.popleft()()
        return count

    def register(self, name: str, fn: HandlerFn, reply_width: int,
                 batched_fn=None) -> Handler:
        if name in self._handlers:
            raise ValueError(f"handler {name!r} already registered")
        h = Handler(name=name, fn=fn, reply_width=reply_width,
                    batched_fn=batched_fn)
        self._handlers[name] = h
        return h

    def handler(self, name: str) -> Handler:
        return self._handlers[name]

    def dispatch(self, handler: Handler, state: Any, dst: Array,
                 payload: Array, valid: Optional[Array] = None,
                 cap: Optional[int] = None,
                 plan: Optional[routing.RoutePlan] = None,
                 decision: Optional[Any] = None,
                 coalesce: bool = False
                 ) -> Tuple[Any, Array, Array]:
        """Issue one aggregated AM phase for a batch of requests.

        state:   pytree whose leaves have leading axis P (owner rows)
        dst:     (P, n) target ranks
        payload: (P, n, W) int32 request words
        plan:    optional precomputed RoutePlan (routing.make_plan) — callers
                 issuing repeated dispatches to fixed destinations reuse one
                 plan per batch and skip the per-dispatch routing sort
        decision: optional adaptive.Decision that chose this dispatch —
                 recorded in `self.dispatch_log` for benchmark attribution
        coalesce: dedup IDENTICAL request rows to the same destination
                 sender-side — the handler sees one combined row per
                 duplicate run and its reply fans out to every duplicate
                 requester (DESIGN.md §6). Only valid for handlers that are
                 idempotent across identical requests (the hash-table
                 insert-or-assign and find handlers are; a queue push is
                 NOT — each identical push must land separately).
        returns (state', replies (P, n, RW), delivered (P, n)).

        Exactly two network phases regardless of handler complexity; for
        reply_width == 0 a single phase (the origin-side completion counter
        is derivable locally from `delivered`, matching the paper's
        counter-increment reply elision).
        """
        plane = flt.active_plane()
        if plane is not None:
            # DESIGN.md §10, applied pre-coalescing at op-row granularity:
            # rows to a dead/stalled owner are masked undelivered (and
            # recorded for the adaptive layer's one-sided failover); live
            # rows go through wire-loss retransmit + dedup simulation.
            valid = plane.inject_am(dst, valid)
            plane.tick()
        co = None
        eff_valid = valid
        if coalesce:
            co = routing.coalesce(dst, payload[..., 0], match=payload,
                                  valid=valid)
            eff_valid = co.rep if valid is None else (valid & co.rep)
        if decision is not None:
            info = None
            if co is not None:
                from . import window as win_mod
                info = win_mod._coalesce_info(co)
            self.dispatch_log.append((handler.name, decision, info))
        if plan is not None:
            cap = plan.cap
            routed = routing.route_with_plan(plan, payload,
                                             active=eff_valid,
                                             role="am_req")
        else:
            cap = dst.shape[1] if cap is None else cap
            routed = routing.route(dst, payload, cap, eff_valid,
                                   role="am_req")
        flat, mask = routing.flatten_owner_view(routed)

        if handler.batched_fn is not None:
            state2, reply_flat = handler.batched_fn(state, flat, mask)
        else:
            state2, reply_flat = jax.vmap(handler.fn)(state, flat, mask)
        delivered = routed.op_ok
        if co is not None:
            # reply fan-out: duplicates are delivered iff their
            # representative was
            delivered = routing.lead(co, delivered)
            if valid is not None:
                delivered = delivered & valid
        if handler.reply_width == 0:
            replies = jnp.zeros(dst.shape + (0,), dtype=jnp.int32)
            return state2, replies, delivered
        replies_o = routing.unflatten_owner_view(reply_flat, self.nranks, cap)
        replies = routing.route_replies(routed, replies_o, dst, role="am_rep")
        if co is not None:
            replies = routing.lead(co, replies)
        return state2, replies, delivered

    def dispatch_local(self, handler: Handler, state: Any, payload: Array,
                       valid: Optional[Array] = None
                       ) -> Tuple[Any, Array]:
        """Run the handler against the caller's own shard (C_l level):
        zero network phases, used by hosted structures when origin == owner
        and by tests."""
        if valid is None:
            valid = jnp.ones(payload.shape[:-1], dtype=bool)
        return jax.vmap(handler.fn)(state, payload, valid)
