"""Deterministic fault injection + exactly-once delivery (DESIGN.md §10).

The paper's caveat about RPC-style ops is that they "can suffer from lack
of attentiveness from the remote side"; until now the engine modelled
that only as a benign, tunable drain delay (§7). This module makes
failure a first-class, *deterministically injectable* axis of the
simulated P-shard engine:

  FaultPlan     a seeded per-(phase, origin, row, attempt) fault
                schedule — dropped rows, duplicated (ack-lost) rows,
                delayed rows, and slow/dead owners (AM service that
                stops for k rounds or forever). Any chaos run is exactly
                reproducible from its seed.
  RetryPolicy   the origin-side retry budget: capped exponential
                backoff, bounded attempts, a deadline in simulated
                dispatch rounds.
  DedupIndex    the receiver half of exactly-once delivery: per
                (owner <- origin) channel sequence numbers, a watermark
                of the highest contiguously-admitted seq plus an
                out-of-order set, so replayed rows apply exactly once.
  RemoteTimeout the typed failure `Handle.result(timeout=)` raises
                instead of hanging on a dead owner.

Delivery model (the §10 invariant): faults and retries play out INSIDE
one exchange phase, like NIC link-level retransmission — the engine's
(src_rank, slot) serialization order is fixed by the routing plan, not
by delivery order, so once every surviving row has been applied exactly
once the phase's visible result is bit-identical to the fault-free
phase. At-least-once (origins retransmit unacked rows) composed with
at-most-once (owners dedup by (origin, seq)) = exactly-once; the
conformance suite pins oracle equality across every arm under every
schedule (tests/test_faults.py).

Fault scoping: wire faults (drop/dup/delay) hit every arm — RDMA NICs
lose packets too. Owner faults (dead_owners, queue stall) hit only the
AM lane: a dead host CPU stops servicing handlers while its NIC keeps
answering one-sided ops — exactly the asymmetry the paper's Fig. 6
measures, and the reason the chooser quarantines an inattentive owner
by re-routing its traffic to the rdma arms (core/adaptive.py).

Tracing: shapes are static under jit, so the plane computes a concrete
numpy keep-mask and folds it into a traced `valid`; fault sampling and
stats record at trace time (the same documented idiom as the phase
log). Inside `lax.while_loop` probe bodies the phase is traced once, so
one fault draw covers every executed probe round of that phase.
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass
from typing import Dict, Optional, Set, Tuple

import numpy as np

__all__ = ["RemoteTimeout", "RetryPolicy", "DedupIndex", "FaultPlan",
           "fault_scope", "active_plane"]


class RemoteTimeout(TimeoutError):
    """A remote owner failed to service a request before its deadline."""


@dataclass(frozen=True)
class RetryPolicy:
    """Origin-side retry budget.

    max_attempts bounds wire retransmits per row inside one phase (at
    the default 16, a row survives drop_rate=0.5 with probability
    1 - 2^-16 — exhaustion is a seed-deterministic, measure-zero event
    for the rates the tests and bench use); base_delay/max_delay shape
    the capped exponential backoff charged to the plane's clock (and
    surfaced in owner stats); deadline bounds how many simulated
    dispatch rounds `Handle.result()` waits on a stalled deferred-AM
    queue before raising RemoteTimeout.
    """
    max_attempts: int = 16
    base_delay: float = 1.0
    max_delay: float = 64.0
    deadline: int = 64

    def delay(self, attempt: int) -> float:
        """Backoff charged before retransmit #attempt (1-based)."""
        return float(min(self.base_delay * (2.0 ** max(0, attempt - 1)),
                         self.max_delay))


# ---------------------------------------------------------------------------
# Deterministic fault stream: splitmix-style hash of
# (seed, phase, origin, row, attempt, salt) -> uniform [0, 1).
# ---------------------------------------------------------------------------
_K = tuple(np.uint64(k) for k in (
    0x9E3779B97F4A7C15, 0xBF58476D1CE4E5B9, 0x94D049BB133111EB,
    0xD6E8FEB86659FD93, 0xFF51AFD7ED558CCD, 0xC2B2AE3D27D4EB4F))
_SALT_DROP, _SALT_ACK, _SALT_DELAY = 1, 2, 3


def _uniform(seed: int, salt: int, phase: int, attempt: int,
             P: int, n: int) -> np.ndarray:
    """(P, n) uniforms, a pure function of every argument."""
    with np.errstate(over="ignore"):
        o = (np.arange(P, dtype=np.uint64) + np.uint64(1))[:, None]
        r = (np.arange(n, dtype=np.uint64) + np.uint64(1))[None, :]
        h = (np.uint64(seed & 0xFFFFFFFFFFFFFFFF) * _K[0]
             ^ np.uint64(phase) * _K[1]
             ^ np.uint64(attempt + 1) * _K[2]
             ^ np.uint64(salt) * _K[3])
        h = h ^ (o * _K[4]) ^ (r * _K[5])
        h = (h ^ (h >> np.uint64(30))) * _K[1]
        h = (h ^ (h >> np.uint64(27))) * _K[2]
        h = h ^ (h >> np.uint64(31))
    return (h >> np.uint64(11)).astype(np.float64) / float(1 << 53)


def _concrete(x) -> Optional[np.ndarray]:
    """Host array, or None for a jit tracer (adaptive._concrete idiom)."""
    if x is None:
        return None
    try:
        return np.asarray(x)
    except Exception:
        return None


# ---------------------------------------------------------------------------
# Receiver-side exactly-once filter
# ---------------------------------------------------------------------------
class DedupIndex:
    """Per-channel sequence numbers + watermark dedup.

    Origins stamp every request row with a monotonically increasing seq
    on its (owner <- origin) channel (`assign`); owners admit each tag
    at most once (`admit`): seq <= watermark, or present in the
    out-of-order set, is a duplicate. The watermark advances over
    contiguous runs so the set only holds genuinely reordered tags.

    The tags are reliability-sublayer metadata carried out of band of
    the payload words — owners stay fixed-function appliers and the
    wire layouts of DESIGN.md §2 are unchanged (§10).
    """

    def __init__(self, nranks: int):
        self.nranks = nranks
        self.next_seq = np.zeros((nranks, nranks), dtype=np.int64)
        self.watermark = np.full((nranks, nranks), -1, dtype=np.int64)
        self.out_of_order: Dict[Tuple[int, int], Set[int]] = {}
        self.admitted = 0
        self.dup_filtered = 0

    def grow(self, nranks: int) -> None:
        """Widen the channel matrices to `nranks` ranks, preserving all
        existing seq/watermark state (e.g. after an elastic rehash to a
        larger table: new ranks open fresh channels at seq 0)."""
        if nranks <= self.nranks:
            return
        ns = np.zeros((nranks, nranks), dtype=np.int64)
        ns[:self.nranks, :self.nranks] = self.next_seq
        wm = np.full((nranks, nranks), -1, dtype=np.int64)
        wm[:self.nranks, :self.nranks] = self.watermark
        self.next_seq, self.watermark = ns, wm
        self.nranks = nranks

    def assign(self, dst: np.ndarray, active: np.ndarray) -> np.ndarray:
        """Stamp each active row with its channel's next seq.

        Returns (P, n) int64 seqs (-1 on inactive rows)."""
        P, n = active.shape
        seqs = np.full((P, n), -1, dtype=np.int64)
        for o in range(P):
            for c in np.nonzero(active[o])[0]:
                w = int(dst[o, c])
                if not 0 <= w < self.nranks:
                    continue  # out-of-range dst: routing drops it anyway
                seqs[o, c] = self.next_seq[w, o]
                self.next_seq[w, o] += 1
        return seqs

    def admit(self, owner: int, origin: int, seq: int) -> bool:
        """Admit one (origin, seq) tag at `owner`; False = duplicate."""
        if seq <= self.watermark[owner, origin]:
            self.dup_filtered += 1
            return False
        oo = self.out_of_order.setdefault((owner, origin), set())
        if seq in oo:
            self.dup_filtered += 1
            return False
        oo.add(seq)
        w = int(self.watermark[owner, origin])
        while w + 1 in oo:
            w += 1
            oo.discard(w)
        self.watermark[owner, origin] = w
        self.admitted += 1
        return True


# ---------------------------------------------------------------------------
# The fault plane
# ---------------------------------------------------------------------------
class FaultPlan:
    """Seeded fault schedule + the plane's runtime state.

    Config:
      seed          master seed: every fault is a pure function of
                    (seed, phase, origin, row, attempt, salt).
      drop_rate     P(request row lost on the wire) per attempt.
      dup_rate      P(ack lost) per delivered attempt — the row was
                    applied but the origin retransmits it, and the
                    owner's DedupIndex filters the redelivery: the
                    classic at-least-once duplicate.
      delay_rate /  fraction of rows delayed, and for how many attempts
      delay_rounds  (delivery carried to a later retransmit round).
      dead_owners   {rank: wake_round or None}: AM service at `rank`
                    stops until the plane's round clock reaches
                    wake_round (None = forever). One-sided phases are
                    NOT affected — the NIC lane stays live.
      stall_rounds/ the deferred-AM dispatch queue refuses to drain for
      stall_forever its first stall_rounds service opportunities, or
                    forever (`Pipeline._force` then raises
                    RemoteTimeout instead of hanging).
      retry         RetryPolicy for origin retransmits.

    The round clock advances once per AM service opportunity (every
    `AMEngine.dispatch` and every `drain_dispatch_queue` call), so
    "stalls for k rounds" means "misses its next k chances to serve".
    """

    def __init__(self, nranks: int, seed: int = 0, drop_rate: float = 0.0,
                 dup_rate: float = 0.0, delay_rate: float = 0.0,
                 delay_rounds: int = 0,
                 dead_owners: Optional[Dict[int, Optional[int]]] = None,
                 stall_rounds: int = 0, stall_forever: bool = False,
                 retry: RetryPolicy = RetryPolicy()):
        self.nranks = int(nranks)
        self.seed = int(seed)
        self.drop_rate = float(drop_rate)
        self.dup_rate = float(dup_rate)
        self.delay_rate = float(delay_rate)
        self.delay_rounds = int(delay_rounds)
        self.dead_owners = dict(dead_owners or {})
        self.stall_rounds = int(stall_rounds)
        self.stall_forever = bool(stall_forever)
        self.retry = retry
        self.reset()

    # -- state ------------------------------------------------------------
    def reset(self) -> None:
        self.phase_idx = 0
        self.round = 0
        self.dedup = DedupIndex(self.nranks)
        self.owner_rows = np.zeros(self.nranks, dtype=np.int64)
        self.owner_retries = np.zeros(self.nranks, dtype=np.int64)
        self.owner_unserviced = np.zeros(self.nranks, dtype=np.int64)
        self.backoff_total = 0.0
        self.dropped = 0
        self.exhausted = 0
        self.stall_hits = 0
        self._last_unserviced: Optional[np.ndarray] = None

    def _accommodate(self, dst_np: np.ndarray) -> None:
        """Widen per-rank state when a phase addresses more ranks than
        the plan was built for (an elastic rehash target has its own,
        larger symmetric window; the plane keeps injecting there)."""
        hi = int(dst_np.shape[0])
        if dst_np.size:
            hi = max(hi, int(dst_np.max()) + 1)
        if hi <= self.nranks:
            return
        pad = hi - self.nranks
        self.owner_rows = np.pad(self.owner_rows, (0, pad))
        self.owner_retries = np.pad(self.owner_retries, (0, pad))
        self.owner_unserviced = np.pad(self.owner_unserviced, (0, pad))
        self.dedup.grow(hi)
        self.nranks = hi

    @property
    def _wire_faults(self) -> bool:
        return bool(self.drop_rate or self.dup_rate
                    or (self.delay_rate and self.delay_rounds))

    def owner_stalled(self, rank: int) -> bool:
        """Is `rank`'s AM service down at the current round?"""
        if rank not in self.dead_owners:
            return False
        wake = self.dead_owners[rank]
        return wake is None or self.round < wake

    def queue_stalled(self) -> bool:
        return self.stall_forever or self.round < self.stall_rounds

    def queue_dead(self) -> bool:
        return self.stall_forever

    def tick(self) -> None:
        """One AM service opportunity passes."""
        self.round += 1

    def wait_for_service(self) -> bool:
        """Advance one round; True if the deferred queue may now drain,
        False if it is permanently stalled (no point waiting)."""
        if self.stall_forever:
            return False
        self.tick()
        return not self.queue_stalled()

    # -- the attempt-loop simulation ---------------------------------------
    def _simulate(self, phase: int, dst: np.ndarray,
                  active: np.ndarray) -> np.ndarray:
        """Play one phase's delivery to completion: per attempt, drop
        rows (wire loss / delay), admit arrivals through the dedup
        filter, then lose acks (dup_rate) so origins retransmit already
        applied rows. Returns `applied` — rows the owner admitted
        exactly once. A row pending at max_attempts that was applied but
        never acked still counts applied (the origin's give-up does not
        un-apply it); a never-applied exhausted row is masked out and
        counted in `exhausted`."""
        P, n = active.shape
        pol = self.retry
        seqs = self.dedup.assign(dst, active)
        clip = np.clip(dst, 0, self.nranks - 1)
        delayed_for = np.zeros((P, n), dtype=np.int64)
        if self.delay_rate and self.delay_rounds:
            u = _uniform(self.seed, _SALT_DELAY, phase, 0, P, n)
            delayed_for = np.where(u < self.delay_rate,
                                   self.delay_rounds, 0)
        applied = np.zeros((P, n), dtype=bool)
        pending = active.copy()
        for a in range(pol.max_attempts):
            if not pending.any():
                break
            if a > 0:
                self.backoff_total += pol.delay(a) * int(pending.sum())
                np.add.at(self.owner_retries, clip[pending], 1)
            u_drop = _uniform(self.seed, _SALT_DROP, phase, a, P, n)
            lost = (u_drop < self.drop_rate) | (a < delayed_for)
            arrive = pending & ~lost
            self.dropped += int((pending & lost).sum())
            # owner applies each arrival at most once, in deterministic
            # (origin, col) order — serialization itself is the routing
            # plan's, so this order only affects dedup bookkeeping
            for o, c in np.argwhere(arrive):
                if self.dedup.admit(int(dst[o, c]), int(o),
                                    int(seqs[o, c])):
                    applied[o, c] = True
            u_ack = _uniform(self.seed, _SALT_ACK, phase, a, P, n)
            pending = pending & ~(arrive & (u_ack >= self.dup_rate))
        self.exhausted += int((pending & ~applied).sum())
        return applied

    # -- engine hooks -------------------------------------------------------
    def inject_phase(self, role: str, dst, valid):
        """Window-lane hook (one-sided phases): fold wire faults into
        the phase's effective valid mask. Returns `valid` unchanged
        (same object) when every row survives — the no-fault fast path
        perturbs nothing, not even a `valid=None` plan reuse."""
        phase = self.phase_idx
        self.phase_idx += 1
        if not self._wire_faults:
            return valid
        dst_np = _concrete(dst)
        if dst_np is None or dst_np.ndim != 2:
            return valid  # symbolic dst: never happens in the engine
        self._accommodate(dst_np)
        P, n = dst_np.shape
        valid_np = _concrete(valid)
        active = (np.ones((P, n), dtype=bool) if valid_np is None
                  else valid_np.astype(bool))
        np.add.at(self.owner_rows,
                  np.clip(dst_np, 0, self.nranks - 1)[active], 1)
        applied = self._simulate(phase, dst_np, active)
        keep = applied | ~active
        if keep.all():
            return valid
        import jax.numpy as jnp
        keep_j = jnp.asarray(keep)
        return keep_j if valid is None else valid & keep_j

    def inject_am(self, dst, valid):
        """AM-lane hook, applied pre-coalescing at op-row granularity:
        rows addressed to a stalled/dead owner are recorded unserviced
        and masked (retransmits cannot help a CPU that is not polling —
        callers re-route them, see AdaptiveEngine); the rest go through
        the same wire retransmit+dedup simulation as one-sided phases."""
        phase = self.phase_idx
        self.phase_idx += 1
        dst_np = _concrete(dst)
        if dst_np is None or dst_np.ndim != 2:
            return valid
        self._accommodate(dst_np)
        P, n = dst_np.shape
        valid_np = _concrete(valid)
        active = (np.ones((P, n), dtype=bool) if valid_np is None
                  else valid_np.astype(bool))
        clip = np.clip(dst_np, 0, self.nranks - 1)
        dead = np.zeros(self.nranks, dtype=bool)
        for r in self.dead_owners:
            dead[r] = self.owner_stalled(r)
        unserviced = active & dead[clip] & (dst_np == clip)
        np.add.at(self.owner_rows, clip[active], 1)
        np.add.at(self.owner_unserviced, clip[unserviced], 1)
        live = active & ~unserviced
        applied = (self._simulate(phase, dst_np, live)
                   if self._wire_faults else live)
        self._last_unserviced = unserviced if unserviced.any() else None
        keep = applied | ~active
        if keep.all():
            return valid
        import jax.numpy as jnp
        keep_j = jnp.asarray(keep)
        return keep_j if valid is None else valid & keep_j

    # -- consumers ----------------------------------------------------------
    def take_unserviced(self) -> Optional[np.ndarray]:
        """(P, n) bool mask of the last AM dispatch's rows that hit a
        dead/stalled owner (None if none) — consumed by the adaptive
        layer to fail those rows over to the one-sided lane."""
        u = self._last_unserviced
        self._last_unserviced = None
        return u

    def take_owner_stats(self) -> Dict[int, Dict[str, int]]:
        """Per-owner fault pressure accumulated since the last take:
        {rank: {"rows", "retries", "unserviced"}} — the feed for the
        chooser's health EWMA (sixth online signal). Resets on read."""
        out: Dict[int, Dict[str, int]] = {}
        for r in range(self.nranks):
            rows = int(self.owner_rows[r])
            ret = int(self.owner_retries[r])
            uns = int(self.owner_unserviced[r])
            if rows or ret or uns:
                out[r] = {"rows": rows, "retries": ret, "unserviced": uns}
        self.owner_rows[:] = 0
        self.owner_retries[:] = 0
        self.owner_unserviced[:] = 0
        return out

    def stats(self) -> Dict[str, float]:
        """Cumulative plane counters (not reset by take_owner_stats)."""
        return {"phases": self.phase_idx, "round": self.round,
                "dropped": self.dropped,
                "dup_filtered": self.dedup.dup_filtered,
                "admitted": self.dedup.admitted,
                "exhausted": self.exhausted,
                "stall_hits": self.stall_hits,
                "backoff_total": self.backoff_total}


# ---------------------------------------------------------------------------
# Scope plumbing (the window.decision_scope idiom)
# ---------------------------------------------------------------------------
_CURRENT_PLAN: Optional[FaultPlan] = None


@contextlib.contextmanager
def fault_scope(plan: Optional[FaultPlan]):
    """Activate `plan` for the dynamic extent: window phases, AM
    dispatch/drain, and pipeline forcing all consult `active_plane()`."""
    global _CURRENT_PLAN
    prev = _CURRENT_PLAN
    _CURRENT_PLAN = plan
    try:
        yield plan
    finally:
        _CURRENT_PLAN = prev


def active_plane() -> Optional[FaultPlan]:
    """The FaultPlan in scope, or None (the fault-free engine)."""
    return _CURRENT_PLAN
