"""Distributed hosted queue (ring buffer) — paper §III-B2, Table III, Fig. 4.

A ``DQueue`` lives on a single *host* rank but is visible to (and
manipulable by) every rank — the paper's "hosted data structure". It is a
ring buffer with four control words followed by the data region:

    word 0: tail          (reserve frontier for pushes, advanced by FAA)
    word 1: tail_ready    (publish frontier: data below this is readable)
    word 2: head          (reserve frontier for pops)
    word 3: head_ready    (release frontier: space below this is reusable)

Implementations and their best-case costs (paper Table III):

  push C_RW (rdma):      A_FAO + W + A_CAS-P   (reserve, write, publish)
                         The publish step is a *persistent* CAS: it may only
                         advance tail_ready to its own end offset once every
                         earlier reservation has published — the inherent
                         serialization the paper identifies as the reason
                         C_RW push under-performs its model prediction.
  push C_W  (rdma):      A_FAO + W             (barrier supplies the fence)
  push checksum C_RW:    A_FAO + W             (ready-CAS replaced by an
                         in-payload checksum word verified by the reader)
  pop  C_RW (rdma):      A_FAO + R + A_CAS-P
  pop  C_R  (rdma):      A_FAO + R
  push/pop C_L:          local vector ops, zero network phases
  push/pop (rpc):        one AM round trip + local handler

Batched SPMD semantics: each rank contributes up to ``n`` ops per step; the
RDMA backend issues the component phases for all ranks' batches together
(each component = one routed exchange phase, DESIGN.md §2).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import am as am_mod
from . import routing
from . import window as win_mod
from .types import AmoKind, Backend, Promise, as_backend
from .window import Window, rdma_cas, rdma_fao, rdma_get, rdma_put

Array = jax.Array

TAIL, TAIL_READY, HEAD, HEAD_READY = 0, 1, 2, 3
CTRL_WORDS = 4


@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=["win"],
                   meta_fields=["host", "capacity", "val_words", "checksum"])
@dataclass
class DQueue:
    """Hosted ring buffer. Slot i of the data region starts at word
    CTRL_WORDS + (i % capacity) * slot_w."""

    win: Window
    host: int
    capacity: int      # slots
    val_words: int     # payload words per slot
    checksum: bool = False  # slots carry a trailing checksum word

    @property
    def nranks(self) -> int:
        return self.win.nranks

    @property
    def slot_w(self) -> int:
        return self.val_words + (1 if self.checksum else 0)


def make_queue(nranks: int, host: int, capacity: int, val_words: int,
               checksum: bool = False) -> DQueue:
    slot_w = val_words + (1 if checksum else 0)
    win = win_mod.make_window(nranks, CTRL_WORDS + capacity * slot_w)
    return DQueue(win=win, host=host, capacity=capacity,
                  val_words=val_words, checksum=checksum)


def _csum(vals: Array) -> Array:
    """Checksum over the payload words of one slot: mixed XOR-rotate, nonzero
    by construction (0 marks an unwritten slot)."""
    def body(c, v):
        c = (c ^ v) * jnp.int32(0x01000193)
        return c, None
    seed = jnp.asarray(0x811C9DC5, dtype=jnp.uint32).astype(jnp.int32)
    c, _ = jax.lax.scan(body, seed, vals)
    return jnp.where(c == 0, jnp.int32(1), c)


def _host_dst(q: DQueue, shape) -> Array:
    return jnp.full(shape, q.host, dtype=jnp.int32)


# ---------------------------------------------------------------------------
# RDMA backend — push
# ---------------------------------------------------------------------------
def push_rdma(q: DQueue, vals: Array, promise: Promise = Promise.CRW,
              valid: Optional[Array] = None, max_cas_rounds: int = 8,
              planned: bool = True, coalesce: bool = False
              ) -> Tuple[DQueue, Array]:
    """Batched push of vals (P, n, vw) onto the hosted ring buffer.

    Returns (queue', pushed (P, n) bool). Ops that would overflow the ring
    (reservation >= head_ready + capacity) are aborted by *returning* their
    reservation... which plain FAA cannot do — so, faithfully to BCL, the
    caller must size the ring; overflow slots wrap and are flagged failed.

    planned=True (default): every component phase of the push — reserve
    FAO, failure-return FAO, payload W, and the max_cas_rounds publish
    CASes — reuses ONE RoutePlan (the host destination never changes), so
    the whole op costs one routing sort instead of `max_cas_rounds + 3`.

    coalesce=True (DESIGN.md §6): the reserve and failure-return FAO
    phases combine each origin's n ticket increments into ONE wire row per
    origin (every push targets the same (host, TAIL) word — the extreme
    duplicate case); per-op tickets are reconstructed sender-side from the
    base ticket + each op's prefix, bit-exactly. The payload write and the
    publish CAS rounds target distinct words and are left alone.
    """
    assert promise in (Promise.CRW, Promise.CW)
    if valid is None:
        valid = jnp.ones(vals.shape[:-1], dtype=bool)
    P, n, vw = vals.shape
    assert vw == q.val_words
    dst = _host_dst(q, (P, n))
    use_csum = q.checksum and promise == Promise.CRW
    slot_w = q.slot_w
    plan = (routing.make_plan(dst, valid, cap=n, role="q_push")
            if planned else None)

    # Phase 1 — A_FAO: reserve space by advancing `tail`.
    one = jnp.ones((P, n), dtype=jnp.int32)
    off_tail = jnp.zeros((P, n), dtype=jnp.int32) + TAIL
    ticket, win = rdma_fao(q.win, dst, off_tail, one, AmoKind.FAA,
                           valid=valid, plan=plan, coalesce=coalesce)

    # Ring-capacity check against head_ready (read is free at the host in
    # BCL's implementation via a cached local bound; we read our own cached
    # copy — conservative: a full ring fails the push).
    head_ready = win.data[q.host, HEAD_READY]
    ok = valid & (ticket - head_ready < q.capacity)
    # Failed reservations return their tickets (they are exactly the top
    # of the reserved range, so a bulk decrement restores tail to the
    # last successful ticket + 1). One extra A_FAO on the failure path.
    neg = jnp.where(valid & ~ok, -1, 0)
    _, win = rdma_fao(win, dst, off_tail, neg, AmoKind.FAA,
                      valid=valid & ~ok, plan=plan, coalesce=coalesce)

    # Phase 2 — W: write the payload into the reserved slot.
    slot = ticket % q.capacity
    base = CTRL_WORDS + slot * slot_w
    if use_csum:
        csums = jax.vmap(jax.vmap(_csum))(vals)
        payload = jnp.concatenate([vals, csums[..., None]], axis=-1)
    elif q.checksum:
        # checksum layout but phasal promise: write a zero checksum word
        payload = jnp.concatenate([vals, jnp.zeros((P, n, 1), jnp.int32)],
                                  axis=-1)
    else:
        payload = vals
    win = rdma_put(win, dst, base, payload, valid=ok, plan=plan)

    if promise == Promise.CRW and not use_csum:
        # Phase 3 — persistent CAS: advance tail_ready ticket -> ticket+1.
        # Each op may only publish once every earlier ticket has published:
        # the inherent serialization of Fig. 4's C_RW push.
        off_tr = jnp.zeros((P, n), dtype=jnp.int32) + TAIL_READY
        pending = ok

        def round_(i, carry):
            win, pending = carry
            old, win = rdma_cas(win, dst, off_tr, ticket, ticket + 1,
                                valid=pending, plan=plan)
            done = pending & (old == ticket)
            return win, pending & ~done

        win, pending = jax.lax.fori_loop(0, max_cas_rounds, round_,
                                         (win, pending))
        ok = ok & ~pending  # unpublished pushes report failure
    return (DQueue(win=win, host=q.host, capacity=q.capacity,
                   val_words=q.val_words, checksum=q.checksum), ok)


# ---------------------------------------------------------------------------
# RDMA backend — pop
# ---------------------------------------------------------------------------
def pop_rdma(q: DQueue, n: int, promise: Promise = Promise.CR,
             valid: Optional[Array] = None, max_cas_rounds: int = 8,
             planned: bool = True, coalesce: bool = False
             ) -> Tuple[DQueue, Array, Array]:
    """Batched pop of up to n values per rank. Returns (q', got (P,n), vals).

    C_R : A_FAO (reserve head) + R (read slot). A barrier separates pops
          from pushes, so tail_ready == tail and no release CAS is needed.
    C_RW: A_FAO + R + persistent CAS advancing head_ready (release), and the
          reservation is validated against tail_ready.

    planned=True: one RoutePlan shared by every phase (see push_rdma).
    coalesce=True combines the head-reservation (and failure-return) FAOs
    into one wire row per origin, tickets reconstructed sender-side
    (bit-exact; see push_rdma).
    """
    assert promise in (Promise.CRW, Promise.CR)
    P = q.nranks
    if valid is None:
        valid = jnp.ones((P, n), dtype=bool)
    dst = _host_dst(q, (P, n))
    slot_w = q.slot_w
    plan = (routing.make_plan(dst, valid, cap=n, role="q_pop")
            if planned else None)

    one = jnp.ones((P, n), dtype=jnp.int32)
    off_head = jnp.zeros((P, n), dtype=jnp.int32) + HEAD
    ticket, win = rdma_fao(q.win, dst, off_head, one, AmoKind.FAA,
                           valid=valid, plan=plan, coalesce=coalesce)

    # Bound check: may only read below the publish frontier. Checksum
    # queues read optimistically below `tail` and validate the in-payload
    # checksum instead (that is the point of the design: no publish CAS).
    use_ready = promise == Promise.CRW and not q.checksum
    frontier = win.data[q.host, TAIL_READY if use_ready else TAIL]
    got = valid & (ticket < frontier)
    # Return failed reservations (top of the range) so unread elements are
    # not skipped by later pops.
    neg = jnp.where(valid & ~got, -1, 0)
    _, win = rdma_fao(win, dst, off_head, neg, AmoKind.FAA,
                      valid=valid & ~got, plan=plan, coalesce=coalesce)

    slot = ticket % q.capacity
    base = CTRL_WORDS + slot * slot_w
    rec = rdma_get(win, dst, base, slot_w, valid=got, plan=plan)
    vals = rec[..., :q.val_words]

    if q.checksum and promise == Promise.CRW:
        # Verify the in-payload checksum instead of trusting tail_ready.
        want = jax.vmap(jax.vmap(_csum))(vals)
        got = got & (rec[..., -1] == want)

    if promise == Promise.CRW:
        off_hr = jnp.zeros((P, n), dtype=jnp.int32) + HEAD_READY
        pending = got

        def round_(i, carry):
            win, pending = carry
            old, win = rdma_cas(win, dst, off_hr, ticket, ticket + 1,
                                valid=pending, plan=plan)
            done = pending & (old == ticket)
            return win, pending & ~done

        win, _ = jax.lax.fori_loop(0, max_cas_rounds, round_,
                                   (win, pending))
    # Failed pops report zeros, not routing garbage: the reply words of
    # undelivered ops are garbage by contract in the unplanned engine, and
    # the adaptive layer swaps backends per batch — visible results must be
    # bit-identical across every backend (tests/test_conformance.py).
    vals = jnp.where(got[..., None], vals, 0)
    return (DQueue(win=win, host=q.host, capacity=q.capacity,
                   val_words=q.val_words, checksum=q.checksum), got, vals)


# ---------------------------------------------------------------------------
# C_L: local push/pop — the host manipulates its own ring, no network.
# ---------------------------------------------------------------------------
def push_local(q: DQueue, vals: Array, valid: Optional[Array] = None
               ) -> Tuple[DQueue, Array]:
    """Host-local batched push: vals (n, vw) appended at tail. Zero phases."""
    n, vw = vals.shape
    if valid is None:
        valid = jnp.ones((n,), dtype=bool)
    data = q.win.data
    local = data[q.host]
    tail = local[TAIL]
    head_ready = local[HEAD_READY]
    rank = jnp.cumsum(valid.astype(jnp.int32)) - 1
    ticket = tail + rank
    ok = valid & (ticket - head_ready < q.capacity)
    slot = ticket % q.capacity
    base = CTRL_WORDS + slot * q.slot_w
    cols = base[:, None] + jnp.arange(vw)[None, :]
    safe_cols = jnp.where(ok[:, None], cols, q.win.local_size)
    local = local.at[safe_cols].set(vals, mode="drop")
    if q.checksum:
        csums = jax.vmap(_csum)(vals)
        local = local.at[jnp.where(ok, base + vw, q.win.local_size)].set(
            csums, mode="drop")
    new_tail = tail + jnp.sum(ok)
    local = local.at[TAIL].set(new_tail).at[TAIL_READY].set(new_tail)
    data = data.at[q.host].set(local)
    return (DQueue(win=Window(data=data), host=q.host, capacity=q.capacity,
                   val_words=q.val_words, checksum=q.checksum), ok)


def pop_local(q: DQueue, n: int) -> Tuple[DQueue, Array, Array]:
    """Host-local batched pop of up to n values. Zero network phases."""
    data = q.win.data
    local = data[q.host]
    head, tail_ready = local[HEAD], local[TAIL_READY]
    ticket = head + jnp.arange(n, dtype=jnp.int32)
    got = ticket < tail_ready
    slot = ticket % q.capacity
    base = CTRL_WORDS + slot * q.slot_w
    cols = base[:, None] + jnp.arange(q.val_words)[None, :]
    vals = local.at[cols].get(mode="fill", fill_value=0)
    vals = jnp.where(got[:, None], vals, 0)
    new_head = head + jnp.sum(got)
    local = local.at[HEAD].set(new_head).at[HEAD_READY].set(new_head)
    data = data.at[q.host].set(local)
    return (DQueue(win=Window(data=data), host=q.host, capacity=q.capacity,
                   val_words=q.val_words, checksum=q.checksum), got, vals)


# ---------------------------------------------------------------------------
# RPC backend (paper Fig. 2 applied to the queue)
# ---------------------------------------------------------------------------
def build_am_handlers(q: DQueue, engine: am_mod.AMEngine):
    """push/pop handlers running sequentially at the host — arbitrary control
    flow (bounds checks, wraparound, publish) in ONE round trip."""
    vw, slot_w, cap = q.val_words, q.slot_w, q.capacity

    def push_fn(local, payload, mask):
        # payload: (m, vw)
        def one(local, x):
            vals, ok = x
            tail = local[TAIL]
            head_ready = local[HEAD_READY]
            can = ok & (tail - head_ready < cap)
            base = CTRL_WORDS + (tail % cap) * slot_w
            cur = jax.lax.dynamic_slice(local, (jnp.where(can, base, 0),),
                                        (vw,))
            new = jnp.where(can, vals, cur)
            local = jax.lax.dynamic_update_slice(
                local, new, (jnp.where(can, base, 0),))
            if q.checksum:
                c = jnp.where(can, _csum(vals),
                              local[jnp.where(can, base + vw, 0)])
                local = local.at[jnp.where(can, base + vw, 0)].set(c)
            adv = can.astype(jnp.int32)
            local = local.at[TAIL].add(adv).at[TAIL_READY].add(adv)
            return local, adv[None]

        local2, replies = jax.lax.scan(one, local, (payload, mask))
        return local2, replies

    def pop_fn(local, payload, mask):
        # payload ignored; reply (m, 1 + vw) = [got | vals]
        def one(local, ok):
            head, tail_ready = local[HEAD], local[TAIL_READY]
            can = ok & (head < tail_ready)
            base = CTRL_WORDS + (head % cap) * slot_w
            rec = jax.lax.dynamic_slice(local, (jnp.where(can, base, 0),),
                                        (vw,))
            rec = jnp.where(can, rec, 0)
            adv = can.astype(jnp.int32)
            local = local.at[HEAD].add(adv).at[HEAD_READY].add(adv)
            return local, jnp.concatenate([adv[None], rec])

        local2, replies = jax.lax.scan(one, local, mask)
        return local2, replies

    # Vectorized batched handler bodies: the sequential scan semantics are
    # reproducible with prefix ranks (a failed op never consumes a ticket,
    # and capacity failures are a contiguous suffix of the valid ops), so
    # the owner can service its whole request grid in one vector step —
    # the emulation analogue of a cheap GASNet handler.
    def push_batched(data, payload, mask):
        def one(local, vals, ok):
            m = ok.shape[0]
            tail, head_ready = local[TAIL], local[HEAD_READY]
            rank = jnp.cumsum(ok.astype(jnp.int32)) - 1
            ticket = tail + rank
            can = ok & (ticket - head_ready < cap)
            base = CTRL_WORDS + (ticket % cap) * slot_w
            cols = base[:, None] + jnp.arange(vw)[None, :]
            safe = jnp.where(can[:, None], cols, local.shape[0])
            local = local.at[safe].set(vals[:, :vw], mode="drop")
            if q.checksum:
                cs = jax.vmap(_csum)(vals[:, :vw])
                local = local.at[jnp.where(can, base + vw,
                                           local.shape[0])].set(
                    cs, mode="drop")
            adv = jnp.sum(can)
            local = local.at[TAIL].add(adv).at[TAIL_READY].add(adv)
            return local, can.astype(jnp.int32)[:, None]

        return jax.vmap(one)(data, payload, mask)

    def pop_batched(data, payload, mask):
        def one(local, _, ok):
            head, tail_ready = local[HEAD], local[TAIL_READY]
            rank = jnp.cumsum(ok.astype(jnp.int32)) - 1
            ticket = head + rank
            can = ok & (ticket < tail_ready)
            base = CTRL_WORDS + (ticket % cap) * slot_w
            cols = base[:, None] + jnp.arange(vw)[None, :]
            rec = local.at[cols].get(mode="fill", fill_value=0)
            rec = jnp.where(can[:, None], rec, 0)
            adv = jnp.sum(can)
            local = local.at[HEAD].add(adv).at[HEAD_READY].add(adv)
            return local, jnp.concatenate(
                [can.astype(jnp.int32)[:, None], rec], axis=-1)

        return jax.vmap(one)(data, payload, mask)

    push_h = engine.register("q_push", push_fn, reply_width=1,
                             batched_fn=push_batched)
    pop_h = engine.register("q_pop", pop_fn, reply_width=1 + vw,
                            batched_fn=pop_batched)
    return push_h, pop_h


def push_rpc(q: DQueue, engine: am_mod.AMEngine, vals: Array,
             valid: Optional[Array] = None,
             decision=None) -> Tuple[DQueue, Array]:
    """Push via ONE AM round trip."""
    P, n, _ = vals.shape
    dst = _host_dst(q, (P, n))
    h = engine.handler("q_push")
    data, replies, delivered = engine.dispatch(h, q.win.data, dst, vals,
                                               valid, decision=decision)
    ok = delivered & (replies[..., 0] > 0)
    return (DQueue(win=Window(data=data), host=q.host, capacity=q.capacity,
                   val_words=q.val_words, checksum=q.checksum), ok)


def pop_rpc(q: DQueue, engine: am_mod.AMEngine, n: int,
            valid: Optional[Array] = None,
            decision=None) -> Tuple[DQueue, Array, Array]:
    P = q.nranks
    dst = _host_dst(q, (P, n))
    payload = jnp.zeros((P, n, 1), dtype=jnp.int32)
    h = engine.handler("q_pop")
    data, replies, delivered = engine.dispatch(h, q.win.data, dst, payload,
                                               valid, decision=decision)
    got = delivered & (replies[..., 0] > 0)
    vals = jnp.where(got[..., None], replies[..., 1:], 0)
    return (DQueue(win=Window(data=data), host=q.host, capacity=q.capacity,
                   val_words=q.val_words, checksum=q.checksum), got, vals)


# ---------------------------------------------------------------------------
# Unified front-end. backend accepts Backend or its string value; default
# AUTO routes through the adaptive layer (core/adaptive.py, DESIGN.md §4).
# C_L short-circuits before any backend decision (zero network phases).
# ---------------------------------------------------------------------------
def push(q, vals, *, promise=Promise.CRW, backend=Backend.AUTO, engine=None,
         adaptive=None, **kw):
    """Batched push onto the hosted ring buffer — paper §III-B2, any backend.

    Args:
      q:       DQueue.
      vals:    (P, n, val_words) int32 — up to n pushes per rank per step.
      promise: CRW (reserve+write+publish), CW (barrier-fenced), or CL
               (host-local, zero network phases — short-circuits before any
               backend decision; vals is (n, val_words) there).
      backend: "auto" (default, DESIGN.md §4) / "rdma" / "rpc".
      engine:  am.AMEngine for the RPC/AM arms.
      adaptive: explicit AdaptiveEngine (default: cached).
      **kw:    valid, max_cas_rounds (any backend); stats (AUTO only);
               planned, coalesce (explicit "rdma" only — AUTO picks the
               planned/coalesced engine per batch itself).

    Returns (queue', pushed (P, n) bool). Bit-identical visible results
    across backends (tests/test_conformance.py); tracer-safe (the hosted
    queue's skew is `nranks` by construction, so AUTO needs no host read)."""
    if promise == Promise.CL:
        return push_local(q, vals, **kw)
    backend = as_backend(backend)
    if backend == Backend.AUTO:
        from . import adaptive as ad
        a = adaptive or ad.default_engine(q.nranks, am_engine=engine)
        return a.q_push(q, vals, promise=promise, **kw)
    if backend == Backend.RPC:
        return push_rpc(q, engine, vals, valid=kw.get("valid"))
    return push_rdma(q, vals, promise=promise, **kw)


def pop(q, n, *, promise=Promise.CR, backend=Backend.AUTO, engine=None,
        adaptive=None, **kw):
    """Batched pop of up to n values per rank. Backends as in `push`.

    Returns (queue', got (P, n) bool, vals (P, n, val_words) int32) — vals
    are zeros where got is False (the cross-backend contract pinned by
    tests/test_conformance.py)."""
    if promise == Promise.CL:
        return pop_local(q, n)
    backend = as_backend(backend)
    if backend == Backend.AUTO:
        from . import adaptive as ad
        a = adaptive or ad.default_engine(q.nranks, am_engine=engine)
        return a.q_pop(q, n, promise=promise, **kw)
    if backend == Backend.RPC:
        return pop_rpc(q, engine, n, valid=kw.get("valid"))
    return pop_rdma(q, n, promise=promise, **kw)


# ---------------------------------------------------------------------------
# Pipelined (async) front-ends (DESIGN.md §7): submit through a
# core/pipeline.Pipeline whose state is the DQueue. Bit-exact vs. the
# synchronous front-ends — submission order is serialization order.
# ---------------------------------------------------------------------------
def _q_async_stats(stats, depth: int):
    from dataclasses import replace as _rep

    from .types import OpStats
    return _rep(stats or OpStats(), pipeline_depth=max(1, int(depth)))


def push_async(pipe, vals, *, promise=Promise.CRW, backend=Backend.AUTO,
               engine=None, adaptive=None, deferred=None, **kw):
    """Submit one push batch to a pipeline; returns a Handle resolving to
    `pushed` — the queue threads through `pipe.state`.

    AM-arm batches go through the deferred-dispatch queue and stage at the
    next dispatch point (`deferred` overrides — see
    `hashtable.insert_async` for the §7 semantics); AUTO batches price
    arms with `stats.pipeline_depth = pipe.depth`. CL pushes are always
    eager (they are local compute — there is nothing to overlap)."""
    backend = as_backend(backend)
    eng = engine if engine is not None else pipe.am_engine
    q0 = pipe.staged_state
    if promise != Promise.CL and backend == Backend.AUTO:
        from . import adaptive as ad
        from .costmodel import DSOp
        a = adaptive or ad.default_engine(q0.nranks, am_engine=eng)
        stats = _q_async_stats(kw.pop("stats", None), pipe.depth)
        stats = a.auto_depth(pipe, DSOp.Q_PUSH, promise,
                             a._host_stats(stats))
        if deferred is None:
            deferred = a.peek_arm(DSOp.Q_PUSH, promise,
                                  a._host_stats(stats)) in ("am", "am_pt")
        kw = dict(kw, stats=stats, adaptive=a)
    elif deferred is None:
        deferred = promise != Promise.CL and backend == Backend.RPC

    def op(q):
        q2, ok = push(q, vals, promise=promise, backend=backend, engine=eng,
                      **kw)
        return q2, ok

    return pipe.submit(op, deferred=deferred, label="q_push")


def pop_async(pipe, n, *, promise=Promise.CR, backend=Backend.AUTO,
              engine=None, adaptive=None, deferred=None, **kw):
    """Submit one pop batch to a pipeline; returns a Handle resolving to
    (got, vals). Same staging/deferral semantics as `push_async`."""
    backend = as_backend(backend)
    eng = engine if engine is not None else pipe.am_engine
    q0 = pipe.staged_state
    if promise != Promise.CL and backend == Backend.AUTO:
        from . import adaptive as ad
        from .costmodel import DSOp
        a = adaptive or ad.default_engine(q0.nranks, am_engine=eng)
        stats = _q_async_stats(kw.pop("stats", None), pipe.depth)
        stats = a.auto_depth(pipe, DSOp.Q_POP, promise,
                             a._host_stats(stats))
        if deferred is None:
            deferred = a.peek_arm(DSOp.Q_POP, promise,
                                  a._host_stats(stats)) in ("am", "am_pt")
        kw = dict(kw, stats=stats, adaptive=a)
    elif deferred is None:
        deferred = promise != Promise.CL and backend == Backend.RPC

    def op(q):
        q2, got, vals = pop(q, n, promise=promise, backend=backend,
                            engine=eng, **kw)
        return q2, (got, vals)

    return pipe.submit(op, deferred=deferred, label="q_pop")
