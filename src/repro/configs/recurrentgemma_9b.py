"""recurrentgemma-9b [hybrid RG-LRU + local attn 1:2] — arXiv:2402.19427.
38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000, window 2048.
Layer pattern: (rec, rec, lattn) x 6 + (rec,) = 19-layer group x 2 = 38
layers with a 26:12 recurrent:attention split (the paper's ~2:1).
Sub-quadratic -> runs long_500k."""
from .base import ArchConfig, ShapeSpec, std_shapes, RGLRU, LATTN, MLP

_GROUP = (((RGLRU, MLP), (RGLRU, MLP), (LATTN, MLP)) * 6
          + ((RGLRU, MLP),))

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    d_ff=12288, vocab=256000,
    pattern=_GROUP, local_window=2048, rnn_width=4096,
    optimizer="adamw",
    shapes=std_shapes(long=True, train_accum=8),
)
