"""Architecture registry + input_specs(): ShapeDtypeStruct stand-ins for
every model input, per (arch × shape) cell — the dry-run's only data source
(weak-type-correct, shardable, zero allocation).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from . import (arctic_480b, deepseek_coder_33b, deepseek_moe_16b,
               granite_3_8b, internlm2_20b, llava_next_34b,
               recurrentgemma_9b, smollm_135m, whisper_base, xlstm_1_3b)
from .base import ArchConfig, ShapeSpec

ARCHS: Dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG for m in (
        granite_3_8b, internlm2_20b, smollm_135m, deepseek_coder_33b,
        whisper_base, deepseek_moe_16b, arctic_480b, recurrentgemma_9b,
        xlstm_1_3b, llava_next_34b)
}


def get(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs():
    return sorted(ARCHS)


def get_shape(cfg: ArchConfig, shape_name: str) -> ShapeSpec:
    for s in cfg.shapes:
        if s.name == shape_name:
            return s
    raise KeyError(f"{cfg.name} has no shape {shape_name!r} "
                   f"(skip list: {cfg.skip_shapes})")


def runnable_cells():
    """All (arch, shape) pairs that are defined and not rule-skipped."""
    out = []
    for name in list_archs():
        cfg = ARCHS[name]
        for s in cfg.shapes:
            if s.name not in cfg.skip_shapes:
                out.append((name, s.name))
    return out


def skipped_cells():
    out = []
    for name in list_archs():
        cfg = ARCHS[name]
        for s in cfg.skip_shapes:
            out.append((name, s))
    return out


def input_specs(cfg: ArchConfig, shape: ShapeSpec,
                dtype=None) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract inputs for the step function of this cell.

    train:   tokens (accum, mb, S) [+ frames / patch_embeds stubs]
    prefill: tokens (B, S) [+ stubs]
    decode:  tokens (B,)  (the decode state comes from decode_state_specs)
    """
    dt = dtype or cfg.compute_dtype
    B, S = shape.global_batch, shape.seq_len
    f32 = jnp.float32

    def tok(*s):
        return jax.ShapeDtypeStruct(s, jnp.int32)

    if shape.kind == "train":
        A = shape.grad_accum
        assert B % A == 0, (cfg.name, shape)
        mb = B // A
        specs = {"tokens": tok(A, mb, S)}
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct((A, mb, S, cfg.d_model),
                                                   dt)
        if cfg.family == "vlm":
            st = S - cfg.n_patch_tokens
            assert st > 0
            specs["tokens"] = tok(A, mb, st)
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (A, mb, cfg.n_patch_tokens, cfg.d_model), dt)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": tok(B, S)}
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)
        if cfg.family == "vlm":
            st = S - cfg.n_patch_tokens
            specs["tokens"] = tok(B, st)
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_patch_tokens, cfg.d_model), dt)
        return specs
    if shape.kind == "decode":
        return {"tokens": tok(B)}
    raise ValueError(shape.kind)


def decode_state_specs(cfg: ArchConfig, shape: ShapeSpec):
    """Abstract decode state (KV caches / recurrent states) via eval_shape —
    no allocation."""
    from ..models import lm

    def mk():
        return lm.init_decode_state(cfg, shape.global_batch, shape.seq_len)

    return jax.eval_shape(mk)


def params_specs(cfg: ArchConfig):
    """Abstract parameters via eval_shape — no allocation."""
    from ..models import lm

    return jax.eval_shape(lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))
