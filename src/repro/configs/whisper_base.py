"""whisper-base [audio enc-dec] — arXiv:2212.04356; unverified tier.
6L d_model=512 8H (kv=8) d_ff=2048 vocab=51865; conv frontend is a STUB:
input_specs() provides precomputed frame embeddings (B, S, D).
Shapes: seq_len applies to both encoder frames and decoder tokens
(documented deviation: whisper's native ctx is 1500/448)."""
from .base import ArchConfig, std_shapes

CONFIG = ArchConfig(
    name="whisper-base", family="encdec",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab=51865, n_enc_layers=6,
    optimizer="adamw",
    shapes=std_shapes(train_accum=2),
    skip_shapes=("long_500k",),
)
