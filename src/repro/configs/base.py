"""Architecture + shape schema shared by models/, configs/, and launch/.

Every assigned architecture is an `ArchConfig`; every assigned input shape
is a `ShapeSpec`. `reduced()` produces the family-preserving small config
used by the per-arch CPU smoke tests; the full config is only ever
lowered/compiled via ShapeDtypeStructs (launch/dryrun.py).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

import jax.numpy as jnp

# Block kinds understood by models/lm.py.
ATTN, LATTN, MLP, MOE, RGLRU, MLSTM, SLSTM = (
    "attn", "lattn", "mlp", "moe", "rglru", "mlstm", "slstm")


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # "train" | "prefill" | "decode"
    grad_accum: int = 1       # microbatch count (train only)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str               # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    dense_residual: bool = False    # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    # --- hybrid / recurrent ---
    pattern: Tuple[Tuple[str, ...], ...] = ()   # repeating group of layers,
                                                # each layer = tuple of blocks
    local_window: int = 2048
    rnn_width: int = 0
    # --- enc-dec (whisper) ---
    n_enc_layers: int = 0
    # --- vlm (llava) ---
    n_patch_tokens: int = 0
    # --- common ---
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # --- paper-technique backends (rdma | rpc | auto) ---
    moe_backend: str = "auto"
    embed_backend: str = "rpc"
    decode_backend: str = "auto"
    # --- training ---
    optimizer: str = "adamw"        # adamw | adafactor (low-mem, big archs)
    remat: bool = True
    # --- shapes assigned to this arch ---
    shapes: Tuple[ShapeSpec, ...] = ()
    skip_shapes: Tuple[str, ...] = ()   # rule-skipped cells (documented)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Embedding-table rows padded to a multiple of 256 so the vocab
        axis shards evenly (and MXU-aligns); padded logits are masked to
        -inf in the loss/argmax paths."""
        return -(-self.vocab // 256) * 256

    @property
    def compute_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def layer_pattern(self) -> Tuple[Tuple[str, ...], ...]:
        """Per-layer block tuples for one repeating group."""
        if self.pattern:
            return self.pattern
        mixer_ffn = (ATTN, MOE if self.n_experts else MLP)
        return (mixer_ffn,)

    @property
    def group_size(self) -> int:
        return len(self.layer_pattern())

    @property
    def n_groups(self) -> int:
        assert self.n_layers % self.group_size == 0, \
            f"{self.name}: {self.n_layers} layers not divisible by " \
            f"group of {self.group_size}"
        return self.n_layers // self.group_size

    def params_count(self) -> int:
        """Analytical parameter count (embedding tied with logits)."""
        D, F, hd = self.d_model, self.d_ff, self.hd
        H, Hkv = self.n_heads, self.n_kv_heads
        per_layer = {}
        per_layer[ATTN] = D * H * hd + 2 * D * Hkv * hd + H * hd * D + D
        per_layer[LATTN] = per_layer[ATTN]
        per_layer[MLP] = 3 * D * F + D
        per_layer[MOE] = (D * self.n_experts
                          + 3 * self.n_experts * D * self.moe_d_ff
                          + 3 * D * self.moe_d_ff * self.n_shared_experts
                          + (3 * D * F if self.dense_residual else 0) + D)
        R = self.rnn_width or D
        per_layer[RGLRU] = 3 * D * R + R * D + D
        per_layer[MLSTM] = 4 * D * D + 3 * D + D
        per_layer[SLSTM] = 4 * D * R + 4 * R * R + R * D + D
        total = self.vocab * D
        for g in range(self.n_groups):
            for layer in self.layer_pattern():
                for block in layer:
                    total += per_layer[block]
        if self.n_enc_layers:
            # encoder layers + decoder cross-attention
            total += self.n_enc_layers * (per_layer[ATTN] + per_layer[MLP])
            total += self.n_layers * per_layer[ATTN]
        return total

    def active_params_count(self) -> int:
        """Active parameters per token (MoE: only top_k experts count)."""
        if not self.n_experts:
            return self.params_count()
        dense_like = replace(
            self, n_experts=self.top_k,
            pattern=(), dense_residual=self.dense_residual)
        # count with top_k routed experts instead of all
        D = self.d_model
        full = self.params_count()
        routed_all = 3 * self.n_experts * D * self.moe_d_ff
        routed_active = 3 * self.top_k * D * self.moe_d_ff
        return full - self.n_layers * (routed_all - routed_active)

    def reduced(self) -> "ArchConfig":
        """Family-preserving tiny config for CPU smoke tests."""
        groups = max(1, min(2, self.n_groups))
        kv = min(self.n_kv_heads, 2)
        heads = max(kv * max(1, min(self.n_heads // self.n_kv_heads, 2)), kv)
        return replace(
            self,
            n_layers=groups * self.group_size,
            d_model=64,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            moe_d_ff=32 if self.n_experts else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            local_window=32,
            rnn_width=64 if (self.rnn_width or self.family in
                             ("hybrid", "ssm")) else 0,
            n_enc_layers=min(self.n_enc_layers, 2),
            n_patch_tokens=min(self.n_patch_tokens, 8),
            dtype="float32",
            shapes=(ShapeSpec("smoke", seq_len=16, global_batch=2,
                              kind="train"),),
        )


def std_shapes(*, decode: bool = True, long: bool = False,
               train_accum: int = 16) -> Tuple[ShapeSpec, ...]:
    """The assigned LM shape set. `long` only for sub-quadratic archs."""
    shapes = [
        ShapeSpec("train_4k", 4096, 256, "train", grad_accum=train_accum),
        ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    ]
    if decode:
        shapes.append(ShapeSpec("decode_32k", 32768, 128, "decode"))
    if long:
        shapes.append(ShapeSpec("long_500k", 524288, 1, "decode"))
    return tuple(shapes)
