"""llava-next-34b [VLM, anyres tiling] — hf:llava-hf/llava-v1.6-*; unverified.
60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000. The vision
frontend is a STUB: input_specs() provides 2880 precomputed anyres patch
embeddings (5 tiles x 576) prepended to the text sequence; the anyres
tile table is modeled as a DHashTable lookup in examples/."""
from .base import ArchConfig, std_shapes

CONFIG = ArchConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab=64000, n_patch_tokens=2880,
    optimizer="adafactor",
    shapes=std_shapes(train_accum=16),
    skip_shapes=("long_500k",),
)
