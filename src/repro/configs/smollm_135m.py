"""smollm-135m [dense GQA, llama-arch small] — hf:HuggingFaceTB/SmolLM-135M.
30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152.
Also the end-to-end *real training* example arch (examples/train_lm.py)."""
from .base import ArchConfig, std_shapes

CONFIG = ArchConfig(
    name="smollm-135m", family="dense",
    n_layers=30, d_model=576, n_heads=9, n_kv_heads=3,
    d_ff=1536, vocab=49152,
    optimizer="adamw",
    shapes=std_shapes(train_accum=2),
    skip_shapes=("long_500k",),
)
