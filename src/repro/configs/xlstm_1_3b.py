"""xlstm-1.3b [sLSTM + mLSTM] — arXiv:2405.04517; unverified tier.
48L d_model=2048 4H d_ff=0 vocab=50304. Block ratio mLSTM:sLSTM = 7:1
(the paper's xLSTM[7:1]); group of 8 layers x 6 groups.
Attention-free -> KV-cache data structures inapplicable (DESIGN.md
§Arch-applicability); runs long_500k."""
from .base import ArchConfig, std_shapes, MLSTM, SLSTM

_GROUP = tuple((MLSTM,) for _ in range(7)) + ((SLSTM,),)

CONFIG = ArchConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    pattern=_GROUP, rnn_width=2048,
    optimizer="adamw",
    shapes=std_shapes(long=True, train_accum=4),
)
