from .base import ArchConfig, ShapeSpec
