"""deepseek-moe-16b [fine-grained MoE] — arXiv:2401.06066; hf tier.
28L d_model=2048 16H (kv=16) vocab=102400; 2 shared + 64 routed experts,
top-6, expert d_ff=1408. PRIMARY showcase of the paper's technique:
expert dispatch selects between the RPC (token all_to_all) and RDMA
(expert-weight gather) backends via the cost model."""
from .base import ArchConfig, std_shapes

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=102400,
    n_experts=64, top_k=6, moe_d_ff=1408, n_shared_experts=2,
    capacity_factor=1.25,
    moe_backend="auto",
    optimizer="adamw",
    shapes=std_shapes(train_accum=8),
    skip_shapes=("long_500k",),
)
