"""deepseek-coder-33b [dense GQA, llama-arch] — arXiv:2401.14196; hf tier.
62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256."""
from .base import ArchConfig, std_shapes

CONFIG = ArchConfig(
    name="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=19200, vocab=32256,
    optimizer="adafactor",
    shapes=std_shapes(train_accum=16),
    skip_shapes=("long_500k",),
)
