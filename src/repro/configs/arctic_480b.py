"""arctic-480b [MoE + dense residual] — hf:Snowflake/snowflake-arctic-base.
35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000; 128 experts top-2
routed in parallel with a dense residual FFN. Largest collective load in
the assigned pool; optimizer=adafactor (f32 Adam moments would not fit a
single v5e pod for 0.5T params)."""
from .base import ArchConfig, std_shapes

CONFIG = ArchConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab=32000,
    n_experts=128, top_k=2, moe_d_ff=4864, dense_residual=True,
    capacity_factor=1.25,
    moe_backend="auto",
    optimizer="adafactor",
    shapes=std_shapes(train_accum=8),
    skip_shapes=("long_500k",),
)
