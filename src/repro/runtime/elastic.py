"""Elastic re-scale: move a training state between meshes of different
size/shape.

Because checkpoints store full (unsharded) arrays and shardings are
derived from logical rules (models/sharding.py), re-scaling is just
re-placement: build the new mesh, resolve the same logical specs against
it, device_put. Uneven divisions are legal under jit (XLA pads), so a
16x16 -> 8x16 shrink after evicting a host row needs no model changes.

The PGAS data structures re-scale by *re-insertion*: hash-table placement
depends on nranks, so `rehash_table` drains the old table (C_R phase) and
reinserts into a fresh one on the new rank count — the standard BCL
resize story, executed with the same batched phases.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..core import hashtable as ht_mod
from ..core.types import Promise


def reshard_tree(tree: Any, shardings: Any) -> Any:
    flat_t, treedef = jax.tree.flatten(tree)
    flat_s = jax.tree.leaves(shardings)
    assert len(flat_t) == len(flat_s)
    return treedef.unflatten(
        [jax.device_put(x, s) if s is not None else jax.device_put(x)
         for x, s in zip(flat_t, flat_s)])


def rehash_table(old: ht_mod.DHashTable, new_nranks: int,
                 max_probes: int = 16) -> ht_mod.DHashTable:
    """Drain + reinsert under the new rank count (batched phases)."""
    P, L = old.win.data.shape
    rec_w, vw = old.rec_w, old.val_words
    recs = old.win.data.reshape(P, old.nslots, rec_w)
    flags = recs[..., 0] & 255
    live = flags == 2
    keys = recs[..., 1]
    vals = recs[..., 2:]
    new = ht_mod.make_hashtable(new_nranks, old.nslots * P // new_nranks
                                + max_probes, vw)
    # Reinsert per old-rank batches; ranks beyond new_nranks fold onto
    # the new table via ownership hashing inside insert.
    nslots = old.nslots
    k2 = keys.reshape(new_nranks, -1)
    v2 = vals.reshape(new_nranks, -1, vw)
    m2 = live.reshape(new_nranks, -1)
    new, ok, _ = ht_mod.insert_rdma(new, k2, v2, promise=Promise.CW,
                                    valid=m2, max_probes=max_probes)
    return new
