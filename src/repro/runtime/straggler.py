"""Straggler detection + mitigation policy.

At pod scale the dominant failure modes are (a) dead hosts and (b) slow
hosts (thermal throttling, network degradation). The monitor ingests
per-step per-host heartbeat durations and drives a policy:

  healthy   -> keep
  slow      -> if persistent (>= `patience` consecutive flags at
               > `threshold` x median), schedule replace-and-remesh
  dead      -> (missed `dead_after` heartbeats) immediate remesh

Remesh = restore the latest checkpoint on the surviving host set
(runtime/elastic.py + checkpoint.restore_sharded) with the deterministic
pipeline replaying from the checkpointed step — the integration test
exercises the full kill -> shrink -> restore -> bit-exact-replay path.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class HostState:
    last_step: int = -1
    slow_streak: int = 0
    durations: collections.deque = field(
        default_factory=lambda: collections.deque(maxlen=16))


class StragglerMonitor:
    def __init__(self, n_hosts: int, threshold: float = 2.0,
                 patience: int = 3, dead_after: int = 5):
        self.hosts: Dict[int, HostState] = {h: HostState()
                                            for h in range(n_hosts)}
        self.threshold = threshold
        self.patience = patience
        self.dead_after = dead_after
        self.current_step = 0

    def heartbeat(self, host: int, step: int, duration_s: float):
        st = self.hosts[host]
        st.last_step = max(st.last_step, step)
        st.durations.append(duration_s)
        self.current_step = max(self.current_step, step)

    def _median_duration(self) -> float:
        vals = sorted(st.durations[-1] for st in self.hosts.values()
                      if st.durations)
        if not vals:
            return 0.0
        return vals[len(vals) // 2]

    def classify(self) -> Dict[int, str]:
        med = self._median_duration()
        out = {}
        for h, st in self.hosts.items():
            if self.current_step - st.last_step >= self.dead_after:
                out[h] = "dead"
                continue
            if st.durations and med > 0 and \
                    st.durations[-1] > self.threshold * med:
                st.slow_streak += 1
            else:
                st.slow_streak = 0
            out[h] = ("replace" if st.slow_streak >= self.patience
                      else ("slow" if st.slow_streak > 0 else "healthy"))
        return out

    def plan(self) -> Optional[dict]:
        """Remesh plan if any host is dead/replace-worthy, else None."""
        cls = self.classify()
        evict = [h for h, c in cls.items() if c in ("dead", "replace")]
        if not evict:
            return None
        survivors = [h for h in self.hosts if h not in evict]
        return {"evict": evict, "survivors": survivors,
                "action": "restore_latest_checkpoint_and_remesh"}
