from .checkpoint import (AsyncCheckpointer, load_checkpoint,
                         restore_sharded, save_checkpoint)
from .straggler import StragglerMonitor
from .elastic import reshard_tree
