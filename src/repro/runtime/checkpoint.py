"""Fault-tolerant checkpointing: atomic manifests, async write-behind,
elastic (mesh-size-independent) restore.

Layout:
  <dir>/step_<N>.tmp/...   (written)
  <dir>/step_<N>/          (atomic rename on completion)
    manifest.json          {step, leaf paths, shapes, dtypes, treedef}
    leaf_<i>.npy           one file per pytree leaf

Restart protocol: `latest_step` scans for the highest *complete* step
(rename is the commit point: a crash mid-write leaves only a .tmp that is
ignored and garbage-collected). Restore is mesh-independent — leaves are
full (unsharded) arrays re-device_put under the new mesh's shardings
(`restore_sharded`), which is what elastic re-scale uses.

The async writer is the write-behind queue from DESIGN.md §3: the train
loop snapshots to host (device_get — the only sync point) and hands the
write to a daemon thread, so step N+1's compute overlaps step N's I/O.
"""
from __future__ import annotations

import json
import os
import pathlib
import queue as pyqueue
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef, str(treedef)


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    d = pathlib.Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    tmp = d / f"step_{step}.tmp"
    final = d / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    leaves, treedef = jax.tree.flatten(tree)
    manifest = {"step": step, "n_leaves": len(leaves),
                "treedef": str(treedef),
                "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(tmp / f"leaf_{i}.npy", arr)
        manifest["leaves"].append({"index": i, "shape": list(arr.shape),
                                   "dtype": str(arr.dtype)})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)          # commit point
    return str(final)


def latest_step(directory: str) -> Optional[int]:
    d = pathlib.Path(directory)
    if not d.exists():
        return None
    steps = []
    for p in d.iterdir():
        if p.is_dir() and p.name.startswith("step_") and \
                not p.name.endswith(".tmp") and \
                (p / "manifest.json").exists():
            steps.append(int(p.name.split("_")[1]))
    return max(steps) if steps else None


def load_checkpoint(directory: str, step: int, template: Any) -> Any:
    """Load into the structure of `template` (leaf order must match)."""
    d = pathlib.Path(directory) / f"step_{step}"
    manifest = json.loads((d / "manifest.json").read_text())
    leaves, treedef = jax.tree.flatten(template)
    assert manifest["n_leaves"] == len(leaves), \
        f"checkpoint has {manifest['n_leaves']} leaves, template " \
        f"{len(leaves)}"
    loaded = [np.load(d / f"leaf_{i}.npy") for i in range(len(leaves))]
    return treedef.unflatten(loaded)


def restore_sharded(directory: str, step: int, template: Any,
                    shardings: Any) -> Any:
    """Elastic restore: load full arrays and place them under the target
    mesh's shardings (any mesh size)."""
    host_tree = load_checkpoint(directory, step, template)
    flat_h, treedef = jax.tree.flatten(host_tree)
    flat_s = jax.tree.leaves(shardings)
    if len(flat_s) == len(flat_h):
        placed = [jax.device_put(h, s) for h, s in zip(flat_h, flat_s)]
    else:
        placed = [jax.device_put(h) for h in flat_h]
    return treedef.unflatten(placed)


def gc_checkpoints(directory: str, keep: int = 3):
    d = pathlib.Path(directory)
    if not d.exists():
        return
    steps = sorted([int(p.name.split("_")[1]) for p in d.iterdir()
                    if p.is_dir() and p.name.startswith("step_")
                    and not p.name.endswith(".tmp")])
    for s in steps[:-keep]:
        shutil.rmtree(d / f"step_{s}", ignore_errors=True)
    for p in d.iterdir():
        if p.name.endswith(".tmp"):
            shutil.rmtree(p, ignore_errors=True)


class AsyncCheckpointer:
    """Write-behind checkpointing: snapshot on the caller thread (cheap),
    serialize + fsync on a daemon thread. At most `depth` outstanding
    writes; `wait()` drains (call before exit / before restore)."""

    def __init__(self, directory: str, keep: int = 3, depth: int = 1):
        self.directory = directory
        self.keep = keep
        self._q: pyqueue.Queue = pyqueue.Queue(maxsize=depth)
        self._errors: list = []
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            step, host_tree = item
            try:
                save_checkpoint(self.directory, step, host_tree)
                gc_checkpoints(self.directory, self.keep)
            except Exception as e:  # surfaced on wait()
                self._errors.append(e)
            finally:
                self._q.task_done()

    def submit(self, step: int, tree: Any):
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)
        self._q.put((step, host_tree))

    def wait(self):
        self._q.join()
        if self._errors:
            raise self._errors[0]

    def close(self):
        self._q.put(None)
        self._q.join()
