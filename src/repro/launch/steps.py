"""Step builders: the jit-able train_step / prefill_step / serve_step for
any (arch × shape), plus the NamedSharding trees the dry-run and trainers
pass as in_shardings/out_shardings.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, ShapeSpec
from ..models import lm
from ..models import sharding as shd
from ..optim import make_optimizer, warmup_cosine

Array = jax.Array


# ---------------------------------------------------------------------------
# Sharding trees
# ---------------------------------------------------------------------------
def _to_shardings(spec_tree):
    """Logical-name-tuple tree -> NamedSharding tree (needs mesh ctx)."""
    return jax.tree.map(
        lambda names: shd.named_sharding(*names),
        spec_tree, is_leaf=lambda x: isinstance(x, tuple) and
        all(n is None or isinstance(n, str) for n in x))


def sanitize_shardings(shardings, shapes):
    """jit in_shardings require every sharded dim to divide evenly. For
    leaves where a rule doesn't divide (e.g. batch=1 at long_500k, 4 mLSTM
    heads on a 16-wide axis), drop trailing mesh axes of that dim's spec
    until it divides — per-leaf, per-dim."""
    mesh = shd.current_mesh()
    if mesh is None:
        return shardings
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    flat_sh, treedef = jax.tree.flatten(
        shardings, is_leaf=lambda x: isinstance(x, NamedSharding))
    flat_shape = jax.tree.leaves(shapes)
    out = []
    for sh, spec in zip(flat_sh, flat_shape):
        if sh is None:
            out.append(sh)
            continue
        dims = spec.shape
        parts = list(sh.spec) + [None] * (len(dims) - len(sh.spec))
        new_parts = []
        for dim, part in zip(dims, parts):
            if part is None:
                new_parts.append(None)
                continue
            axes = list(part) if isinstance(part, tuple) else [part]
            while axes:
                prod = 1
                for a in axes:
                    prod *= sizes[a]
                if dim % prod == 0:
                    break
                axes.pop()
            new_parts.append(tuple(axes) if len(axes) > 1 else
                             (axes[0] if axes else None))
        out.append(NamedSharding(mesh, P(*new_parts)))
    return treedef.unflatten(out)


def param_shardings(cfg: ArchConfig):
    from ..configs import registry
    return sanitize_shardings(_to_shardings(lm.param_specs(cfg)),
                              registry.params_specs(cfg))


def batch_shardings(cfg: ArchConfig, shape: ShapeSpec):
    if shape.kind == "train":
        specs = {"tokens": (None, "batch", None)}
        if cfg.family == "encdec":
            specs["frames"] = (None, "batch", None, None)
        if cfg.family == "vlm":
            specs["patch_embeds"] = (None, "batch", None, None)
    elif shape.kind == "prefill":
        specs = {"tokens": ("batch", None)}
        if cfg.family == "encdec":
            specs["frames"] = ("batch", None, None)
        if cfg.family == "vlm":
            specs["patch_embeds"] = ("batch", None, None)
    else:
        specs = {"tokens": ("batch",)}
    return _to_shardings(specs)


def decode_state_shardings(cfg: ArchConfig):
    return _to_shardings(lm.decode_state_logical_specs(cfg))


def opt_state_shardings(cfg: ArchConfig, opt_state_shape):
    """Optimizer slots follow their parameter's sharding: full-shape slots
    (Adam m/v) reuse it directly; Adafactor's factored vr (shape[:-1]) and
    vc (shape[:-2]+shape[-1:]) inherit the matching sub-spec. Anything
    unmatched (counts) is replicated."""
    logical = jax.tree.leaves(
        lm.param_specs(cfg),
        is_leaf=lambda x: isinstance(x, tuple) and
        all(n is None or isinstance(n, str) for n in x))
    shapes = [p.shape for p in jax.tree.leaves(
        jax.eval_shape(lambda: lm.init_params(cfg, jax.random.PRNGKey(0))))]
    table = {}
    for names, shp in zip(logical, shapes):
        table.setdefault(shp, names)
        if len(shp) >= 1:
            table.setdefault(tuple(shp[:-1]), tuple(names[:-1]))
        if len(shp) >= 2:
            table.setdefault(tuple(shp[:-2]) + (shp[-1],),
                             tuple(names[:-2]) + (names[-1],))

    def one(leaf):
        return shd.named_sharding(*table.get(leaf.shape, ()))

    return jax.tree.map(one, opt_state_shape)


# ---------------------------------------------------------------------------
# Train step (with in-step gradient accumulation over microbatches)
# ---------------------------------------------------------------------------
def make_train_step(cfg: ArchConfig, *, lr: float = 3e-4,
                    warmup: int = 100, total_steps: int = 10000):
    init_fn, update_fn = make_optimizer(
        cfg.optimizer, warmup_cosine(lr, warmup, total_steps))

    def train_step(params, opt_state, batch, step):
        """batch leaves have leading (accum, microbatch, ...)."""
        accum = batch["tokens"].shape[0]

        def micro(carry, mb):
            gsum, lsum = carry
            loss, grads = jax.value_and_grad(lm.loss_fn)(params, cfg, mb)
            gsum = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), gsum, grads)
            return (gsum, lsum + loss), None

        gzero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, lsum), _ = jax.lax.scan(micro, (gzero, 0.0), batch)
        grads = jax.tree.map(lambda g: g / accum, gsum)
        new_params, new_opt, gnorm = update_fn(grads, opt_state, params,
                                               step)
        metrics = {"loss": lsum / accum, "grad_norm": gnorm}
        return new_params, new_opt, metrics

    return init_fn, train_step


def make_prefill_step(cfg: ArchConfig):
    def prefill_step(params, batch):
        x = lm._forward(params, cfg, batch["tokens"], extra=batch)
        logits = lm.logits_fn(params, cfg, x[:, -1:])
        return logits[:, 0]
    return prefill_step


def make_serve_step(cfg: ArchConfig):
    def serve_step(params, state, batch):
        """One decode step for the whole request batch; greedy next token."""
        logits, state = lm.decode_step(params, cfg, state, batch["tokens"])
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, state
    return serve_step


# ---------------------------------------------------------------------------
# Convenience: fully-jitted cell (used by dryrun + trainers)
# ---------------------------------------------------------------------------
def jitted_cell(cfg: ArchConfig, shape: ShapeSpec, *, donate: bool = True):
    """Build (fn, in_shardings, out_shardings, arg_specs) for the cell's
    step under the *current* mesh context."""
    from ..configs import registry

    specs = registry.input_specs(cfg, shape)
    bsh = sanitize_shardings(batch_shardings(cfg, shape), specs)
    psh = param_shardings(cfg)
    if shape.kind == "train":
        init_fn, step = make_train_step(cfg)
        opt_shape = jax.eval_shape(
            init_fn, jax.eval_shape(
                lambda: lm.init_params(cfg, jax.random.PRNGKey(0))))
        osh = sanitize_shardings(opt_state_shardings(cfg, opt_shape),
                                 opt_shape)
        scalar = shd.named_sharding()
        fn = jax.jit(step,
                     in_shardings=(psh, osh, bsh, scalar),
                     out_shardings=(psh, osh,
                                    {"loss": scalar, "grad_norm": scalar}),
                     donate_argnums=(0, 1) if donate else ())
        args = (registry.params_specs(cfg), opt_shape, specs,
                jax.ShapeDtypeStruct((), jnp.int32))
        return fn, args
    if shape.kind == "prefill":
        step = make_prefill_step(cfg)
        out_sh = shd.named_sharding("batch", "vocab")
        fn = jax.jit(step, in_shardings=(psh, bsh), out_shardings=out_sh)
        args = (registry.params_specs(cfg), specs)
        return fn, args
    if shape.kind == "decode":
        step = make_serve_step(cfg)
        st = registry.decode_state_specs(cfg, shape)
        ssh = sanitize_shardings(decode_state_shardings(cfg), st)
        tok_sh = sanitize_shardings(shd.named_sharding("batch"),
                                    specs["tokens"])
        fn = jax.jit(step, in_shardings=(psh, ssh, bsh),
                     out_shardings=(tok_sh, ssh),
                     donate_argnums=(1,) if donate else ())
        args = (registry.params_specs(cfg), st, specs)
        return fn, args
    raise ValueError(shape.kind)
