"""End-to-end training driver: data pipeline -> train_step -> async
checkpointing -> straggler monitor -> (simulated) elastic restart.

Real runs on this CPU container use --reduced (family-preserving small
config) or smollm-135m with a small batch; the full configs are exercised
via launch/dryrun.py. The loop structure is the production one:
deterministic data keyed by (seed, step, host), write-behind checkpoints,
heartbeats after every step, restart-from-latest on relaunch.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --reduced --steps 50 --batch 8 --seq 64 --ckpt /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from ..configs import registry
from ..configs.base import ShapeSpec
from ..data import SyntheticLM
from ..models import lm
from ..models import sharding as shd
from ..runtime import AsyncCheckpointer, StragglerMonitor
from ..runtime import checkpoint as ckpt_mod
from . import mesh as mesh_mod
from . import steps


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--total-steps", type=int, default=None,
                    help="schedule horizon (fixed across restarts)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data", default="data,model",
                    help="mesh axes sizes, e.g. 1,1")
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    cfg = registry.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    shape = ShapeSpec("cli", seq_len=args.seq, global_batch=args.batch,
                      kind="train", grad_accum=args.accum)
    mesh = mesh_mod.make_host_mesh()
    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq, seed=args.seed)
    monitor = StragglerMonitor(n_hosts=1)
    ckpt = AsyncCheckpointer(args.ckpt) if args.ckpt else None

    with shd.mesh_context(mesh):
        total = args.total_steps or args.steps
        init_fn, train_step = steps.make_train_step(
            cfg, lr=args.lr, warmup=min(20, total // 4 + 1),
            total_steps=total)
        params = lm.init_params(cfg, jax.random.PRNGKey(args.seed))
        opt_state = init_fn(params)
        start = 0
        if args.ckpt:
            latest = ckpt_mod.latest_step(args.ckpt)
            if latest is not None:
                print(f"[train] restoring step {latest} from {args.ckpt}")
                params, opt_state = ckpt_mod.load_checkpoint(
                    args.ckpt, latest, (params, opt_state))
                params = jax.tree.map(jnp.asarray, params)
                opt_state = jax.tree.map(jnp.asarray, opt_state)
                start = latest
        jit_step = jax.jit(train_step, donate_argnums=(0, 1))

        losses = []
        for step in range(start, args.steps):
            t0 = time.time()
            batch = data.train_batch(cfg, shape, step)
            params, opt_state, metrics = jit_step(
                params, opt_state, batch, jnp.int32(step))
            loss = float(metrics["loss"])
            losses.append(loss)
            monitor.heartbeat(0, step, time.time() - t0)
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.submit(step + 1, (params, opt_state))
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"[train] step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"dt {time.time()-t0:.2f}s", flush=True)
            plan = monitor.plan()
            if plan:
                print(f"[train] straggler plan: {plan}")
        if ckpt:
            ckpt.submit(args.steps, (params, opt_state))
            ckpt.close()
        print(f"[train] done. loss {losses[0]:.3f} -> {losses[-1]:.3f}")
        return losses


if __name__ == "__main__":
    main()
