"""Trip-count-aware statistics over optimized (SPMD-partitioned) HLO text.

XLA's `compiled.cost_analysis()` counts every while-loop body ONCE
(verified in tests/test_roofline.py), which under-counts scanned layer
groups, gradient-accumulation loops and flash kv-chunk loops by orders of
magnitude. This module re-derives per-device totals by walking the
computation graph and multiplying loop bodies by their
`known_trip_count` backend_config (emitted by XLA for lax.scan loops).

Extracted metrics (all per device — shapes in a partitioned module are
local):
  flops            2·M·N·K over every dot, trip-weighted
  collective bytes ring-model ICI bytes per collective kind, trip-weighted
  hbm bytes        proxy: 2 × Σ op output bytes (fusion internals hidden,
                   like VMEM-resident temporaries on TPU), trip-weighted
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {"pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2,
               "u16": 2, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4,
               "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
               "token": 0, "f8e4m3fn": 1, "f8e5m2": 1}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+[a-z0-9]*|pred|token)\[([0-9,]*)\]")
_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
# TYPE then opname: tuple types may contain /*index=N*/ comments; the
# non-greedy tuple branch stops at the first `) opname(` boundary.
_TYPE_OP_RE = re.compile(
    r"^((?:\(.*?\)|[a-z]+[0-9]*[a-z0-9]*\[[^\]]*\](?:\{[^}]*\})?))\s+"
    r"([a-z][a-z0-9\-]*)\(")
_CALLEE_RE = re.compile(
    r"(?:calls=|to_apply=|condition=|body=|called_computations=\{|"
    r"branch_computations=\{)%?([\w.\-]+)")
_ALL_CALLEES_RE = re.compile(
    r"(?:calls=%?([\w.\-]+)|to_apply=%?([\w.\-]+)|condition=%?([\w.\-]+)"
    r"|body=%?([\w.\-]+)|called_computations=\{([^}]*)\}"
    r"|branch_computations=\{([^}]*)\})")
_TRIP_RE = re.compile(r'known_trip_count"?\s*[=:]\s*\{\s*"?n"?\s*[=:]\s*"?(\d+)')
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_DIMS_RE = {
    "lhs_contracting": re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}"),
}

_SKIP_OUTPUT_OPS = {"parameter", "get-tuple-element", "tuple", "bitcast",
                    "constant", "iota", "copy", "copy-start", "copy-done",
                    "after-all", "partition-id", "replica-id", "reshape",
                    "transpose", "broadcast", "convert"}

# Opnames tallied (trip-weighted) into Stats.op_counts. "sort" backs the
# phase-count regression: a planned batch lowers to exactly ONE argsort
# (routing.make_plan) and plan-reusing phases to none.
COUNTED_OPS = ("sort",)


def shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES.get(dt, 4)
    return total


def shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Stats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll: Dict[str, Dict[str, float]] = field(default_factory=dict)
    # trip-weighted occurrence counts of caller-selected opnames (e.g.
    # "sort" — the phase-count regression pins the planner's one-argsort
    # claim with it, tests/test_phase_counts.py)
    op_counts: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "Stats", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        for k, v in other.coll.items():
            slot = self.coll.setdefault(k, {"count": 0.0, "bytes": 0.0})
            slot["count"] += v["count"] * mult
            slot["bytes"] += v["bytes"] * mult
        for k, v in other.op_counts.items():
            self.op_counts[k] = self.op_counts.get(k, 0.0) + v * mult


@dataclass
class _Comp:
    name: str
    ops: List[dict] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)


def _split_computations(text: str) -> Dict[str, _Comp]:
    comps: Dict[str, _Comp] = {}
    cur: Optional[_Comp] = None
    for line in text.splitlines():
        stripped = line.rstrip()
        if cur is None:
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?.*\{\s*$",
                         stripped)
            if m and "=" not in stripped.split("(")[0]:
                cur = _Comp(name=m.group(1))
                # parameters declared in the header get shapes from body
                continue
        else:
            if stripped.strip() == "}":
                comps[cur.name] = cur
                cur = None
                continue
            am = _ASSIGN_RE.match(stripped)
            if am:
                name, rest = am.groups()
                tm = _TYPE_OP_RE.match(rest)
                if tm:
                    type_str, opname = tm.groups()
                    cur.shapes[name] = type_str
                    cur.ops.append({"name": name, "type": type_str,
                                    "op": opname, "line": stripped})
    return comps


def _group_size(line: str, world: int) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return world


def _collective_bytes(kind: str, out_b: float, n: int) -> float:
    if n <= 1:
        return 0.0
    if kind == "all-gather":
        return out_b * (n - 1) / n
    if kind == "reduce-scatter":
        return out_b * (n - 1)
    if kind == "all-reduce":
        return 2 * out_b * (n - 1) / n
    if kind == "all-to-all":
        return out_b * (n - 1) / n
    return out_b  # collective-permute


def _operands(line: str) -> List[str]:
    """Operand names of the op call on this line."""
    m = re.search(r"\s[a-z][a-z0-9\-]*\((.*)$", line)
    if not m:
        return []
    body = m.group(1)
    out, depth, cur = [], 0, []
    for ch in body:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            if depth == 0:
                break
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    out.append("".join(cur))
    names = []
    for tok in out:
        mm = re.search(r"%([\w.\-]+)", tok)
        names.append(mm.group(1) if mm else None)
    return names


class HloStats:
    def __init__(self, hlo_text: str, world: int):
        self.world = world
        self.comps = _split_computations(hlo_text)
        self._memo: Dict[str, Stats] = {}
        entry = None
        for line in hlo_text.splitlines():
            if line.startswith("ENTRY"):
                m = re.match(r"ENTRY\s+%?([\w.\-]+)", line)
                if m:
                    entry = m.group(1)
        self.entry = entry or max(
            self.comps, key=lambda c: len(self.comps[c].ops))
        self.total = self._stats_of(self.entry)

    def _stats_of(self, name: str) -> Stats:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = Stats()  # cycle guard
        comp = self.comps.get(name)
        st = Stats()
        if comp is None:
            self._memo[name] = st
            return st
        for op in comp.ops:
            opname, line, type_str = op["op"], op["line"], op["type"]
            base = opname.removesuffix("-start").removesuffix("-done")
            out_b = shape_bytes(type_str)
            if opname not in _SKIP_OUTPUT_OPS and not opname.endswith(
                    "-done"):
                st.hbm_bytes += 2 * out_b
            if opname in COUNTED_OPS:
                st.op_counts[opname] = st.op_counts.get(opname, 0.0) + 1
            if base in COLLECTIVES and not opname.endswith("-done"):
                n = _group_size(line, self.world)
                moved = _collective_bytes(base, out_b, n)
                slot = st.coll.setdefault(base, {"count": 0, "bytes": 0.0})
                slot["count"] += 1
                slot["bytes"] += moved
                st.coll_bytes += moved
            if opname == "dot":
                ops_names = _operands(line)
                lhs_dims = shape_dims(comp.shapes.get(ops_names[0], ""))
                mC = _DIMS_RE["lhs_contracting"].search(line)
                k = 1
                if mC and lhs_dims:
                    for idx in mC.group(1).split(","):
                        if idx:
                            k *= lhs_dims[int(idx)]
                out_elems = 1
                for d in shape_dims(type_str):
                    out_elems *= d
                st.flops += 2.0 * out_elems * k
            if opname == "while":
                trips = 1
                mT = _TRIP_RE.search(line)
                if mT:
                    trips = int(mT.group(1))
                callees = _callees(line)
                for c in callees:
                    st.add(self._stats_of(c), mult=trips)
            elif opname in ("fusion", "call", "conditional", "custom-call",
                            "reduce", "sort", "scatter", "map",
                            "reduce-window", "select-and-scatter"):
                for c in _callees(line):
                    st.add(self._stats_of(c), mult=1.0)
        self._memo[name] = st
        return st

    def summary(self) -> dict:
        return {
            "flops": self.total.flops,
            "hbm_bytes": self.total.hbm_bytes,
            "collective_bytes": self.total.coll_bytes,
            "collectives": self.total.coll,
            "op_counts": self.total.op_counts,
        }


def _callees(line: str) -> List[str]:
    out = []
    for m in _ALL_CALLEES_RE.finditer(line):
        for g in m.groups():
            if g:
                for part in g.split(","):
                    part = part.strip().lstrip("%")
                    if part:
                        out.append(part)
    return out


def analyze(hlo_text: str, world: int) -> dict:
    return HloStats(hlo_text, world).summary()
