"""Serving driver: batched greedy decoding with the distributed KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
      --reduced --batch 4 --prompt-len 12 --gen-len 20
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import registry
from ..models import lm
from ..models import sharding as shd
from . import mesh as mesh_mod
from . import steps


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen-len", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = registry.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = mesh_mod.make_host_mesh()
    max_len = args.prompt_len + args.gen_len + 1
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len))

    with shd.mesh_context(mesh):
        params = lm.init_params(cfg, jax.random.PRNGKey(args.seed))
        state = lm.init_decode_state(cfg, args.batch, max_len)
        if cfg.family == "encdec":
            state["enc"] = jnp.asarray(
                rng.normal(0, 1, state["enc"].shape), state["enc"].dtype)
        serve_step = jax.jit(steps.make_serve_step(cfg),
                             donate_argnums=(1,))
        # prompt ingestion (token-by-token prefill through the decode path)
        tok = jnp.asarray(prompts[:, 0], jnp.int32)
        outs = [np.asarray(tok)]
        t0 = time.time()
        for t in range(1, max_len):
            nxt, state = serve_step(params, state, {"tokens": tok})
            if t < args.prompt_len:
                tok = jnp.asarray(prompts[:, t], jnp.int32)  # teacher force
            else:
                tok = nxt
                outs.append(np.asarray(tok))
        dt = time.time() - t0
        gen = np.stack(outs[1:], axis=1)
        print(f"[serve] {args.batch} seqs x {args.gen_len} tokens in "
              f"{dt:.2f}s ({args.batch*args.gen_len/dt:.1f} tok/s)")
        for b in range(min(args.batch, 2)):
            print(f"[serve] seq{b}: prompt={prompts[b].tolist()} "
                  f"gen={gen[b].tolist()}")
        return gen


if __name__ == "__main__":
    main()
