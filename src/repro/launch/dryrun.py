import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes and extract the roofline inputs.

The two lines above MUST stay the first statements in this module (before
any jax-importing import): jax locks the device count at first init, and
this module — and ONLY this module — needs 512 host placeholder devices to
build the 2x16x16 multi-pod mesh.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m \
      --shape train_4k --mesh pod
  PYTHONPATH=src python -m repro.launch.dryrun --all --out artifacts/dryrun
"""
import argparse
import json
import pathlib
import re
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import registry
from ..configs.base import ArchConfig
from ..models import lm
from ..models import sharding as shd
from . import hlo_stats
from . import mesh as mesh_mod
from . import steps

DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
               "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
               "f64": 8, "c64": 8, "c128": 16}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+|pred)\[([0-9,]*)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES.get(dt, 4)
    return total


def _group_size(line: str, world: int) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return world


def parse_collectives(hlo_text: str, world: int):
    """Per-device ICI byte accounting from the SPMD-partitioned HLO.

    Shapes in the partitioned module are per-device (local). Bytes moved
    per device, ring algorithms:
      all-gather        out_local × (n-1)/n   (received)
      reduce-scatter    out_local × (n-1)    (sent, = in×(n-1)/n)
      all-reduce        2 × out_local × (n-1)/n
      all-to-all        out_local × (n-1)/n
      collective-permute out_local
    """
    per_op = {k: {"count": 0, "bytes": 0.0, "out_bytes": 0} for k in
              COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[^\]]*\]"
                     r"(?:\{[^}]*\})?)\s+([a-z\-]+)", stripped)
        if not m:
            continue
        opname = m.group(2)
        base = opname.removesuffix("-start").removesuffix("-done")
        if base not in COLLECTIVES or opname.endswith("-done"):
            continue
        out_b = _shape_bytes(m.group(1))
        n = max(_group_size(stripped, world), 1)
        if base == "all-gather":
            moved = out_b * (n - 1) / n
        elif base == "reduce-scatter":
            moved = out_b * (n - 1)
        elif base == "all-reduce":
            moved = 2 * out_b * (n - 1) / n
        elif base == "all-to-all":
            moved = out_b * (n - 1) / n
        else:  # collective-permute
            moved = out_b
        per_op[base]["count"] += 1
        per_op[base]["bytes"] += moved
        per_op[base]["out_bytes"] += out_b
    total = sum(v["bytes"] for v in per_op.values())
    return per_op, total


def dryrun_cell(arch: str, shape_name: str, mesh_kind: str,
                save_hlo: str | None = None) -> dict:
    cfg = registry.get(arch)
    shape = registry.get_shape(cfg, shape_name)
    mesh = mesh_mod.make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    world = mesh.size
    t0 = time.time()
    with shd.mesh_context(mesh):
        fn, args = steps.jitted_cell(cfg, shape)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    cost = compiled.cost_analysis() or {}
    # jax 0.4.x returns a one-element list of dicts; >=0.5 a plain dict.
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not implement it
        mem_info = {"error": str(e)}
    hlo = compiled.as_text()
    per_op, coll_bytes = parse_collectives(hlo, world)
    trip_aware = hlo_stats.analyze(hlo, world)
    if save_hlo:
        pathlib.Path(save_hlo).write_text(hlo)
    # Analytic per-device parameter bytes (from shardings).
    psh = None
    with shd.mesh_context(mesh):
        psh = steps.param_shardings(cfg)
    pspecs = registry.params_specs(cfg)
    pbytes = 0
    for sh, p in zip(jax.tree.leaves(psh), jax.tree.leaves(pspecs)):
        shard_shape = sh.shard_shape(p.shape)
        n = 1
        for d in shard_shape:
            n *= d
        pbytes += n * p.dtype.itemsize
    return {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "world": world,
        "kind": shape.kind,
        "flops_per_device": cost.get("flops"),
        "bytes_per_device": cost.get("bytes accessed"),
        "cost_analysis": {k: v for k, v in cost.items()
                          if isinstance(v, (int, float))},
        "memory_analysis": mem_info,
        "param_bytes_per_device": pbytes,
        "collectives_body_once": per_op,
        "collective_bytes_body_once": coll_bytes,
        # trip-count-aware per-device totals (launch/hlo_stats.py)
        "hlo_flops_per_device": trip_aware["flops"],
        "hlo_hbm_bytes_per_device": trip_aware["hbm_bytes"],
        "collective_bytes_per_device": trip_aware["collective_bytes"],
        "collectives": trip_aware["collectives"],
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "ok": True,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--meshes", default="pod,multipod")
    args = ap.parse_args()

    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    cells = []
    if args.all:
        for arch, shape in registry.runnable_cells():
            for mk in args.meshes.split(","):
                cells.append((arch, shape, mk))
        # smallest models first so progress accrues early
        cells.sort(key=lambda c: registry.get(c[0]).params_count())
        (outdir / "skipped.json").write_text(
            json.dumps([{"arch": a, "shape": s,
                         "reason": "full attention at 524k (O(L^2)); "
                                   "sub-quadratic archs only"}
                        for a, s in registry.skipped_cells()], indent=1))
    else:
        cells.append((args.arch, args.shape, args.mesh))

    n_ok = n_fail = n_skip = 0
    for arch, shape, mk in cells:
        tag = f"{arch}__{shape}__{mk}"
        path = outdir / f"{tag}.json"
        if path.exists() and args.all:
            prev = json.loads(path.read_text())
            if prev.get("ok"):
                n_skip += 1
                continue
        print(f"[dryrun] {tag} ...", flush=True)
        try:
            rec = dryrun_cell(arch, shape, mk, save_hlo=args.save_hlo)
            n_ok += 1
        except Exception as e:
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape, "mesh": mk, "ok": False,
                   "error": f"{type(e).__name__}: {e}"}
            n_fail += 1
        path.write_text(json.dumps(rec, indent=1))
        status = "OK" if rec["ok"] else "FAIL"
        extra = ""
        if rec["ok"]:
            extra = (f" flops/dev={rec['hlo_flops_per_device']:.3g}"
                     f" coll_bytes/dev={rec['collective_bytes_per_device']:.3g}"
                     f" compile={rec['compile_s']}s")
        print(f"[dryrun] {tag}: {status}{extra}", flush=True)
    print(f"[dryrun] done ok={n_ok} fail={n_fail} skip={n_skip}")


if __name__ == "__main__":
    main()
