"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before any jax
device initialization).

Production target: TPU v5e, 16x16 = 256 chips per pod; 2 pods = 512 chips
multi-pod. The "pod" axis carries only (hierarchically reduced) gradient
traffic; "data" is batch/FSDP; "model" is TP/EP/SP/vocab.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

# TPU v5e hardware constants (per chip) — used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW_PER_LINK = 50e9          # bytes/s/link (~4 links usable per chip)
ICI_LINKS = 4


def _axis_types_kwargs(n_axes: int) -> dict:
    """jax >= 0.5 wants explicit axis_types; jax 0.4.x (this image ships
    0.4.37) has no jax.sharding.AxisType and every axis is Auto by
    default — pass nothing there."""
    if hasattr(jax.sharding, "AxisType"):
        return {"axis_types": (jax.sharding.AxisType.Auto,) * n_axes}
    return {}


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types_kwargs(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(n // data, 1))
    return jax.make_mesh(
        (data, model), ("data", "model"), **_axis_types_kwargs(2))
