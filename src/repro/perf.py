"""Perf-iteration flags (EXPERIMENTS.md §Perf).

Each flag gates one beyond-baseline optimization; the paper-faithful
baseline is REPRO_PERF="causal_skip=0,mlstm_chunked=0,moe_wstat=0,
rnn_local=0". Defaults are the optimized configuration (production).

  causal_skip    flash attention processes q in chunks and skips kv
                 blocks above the causal frontier (~1.8x attention FLOPs)
  mlstm_chunked  two-level remat scan for recurrent cells: per-step scan
                 residuals become per-chunk (memory term / ~chunk)
  moe_wstat      weight-stationary MoE: ship tokens over BOTH mesh axes
                 (all-gather tokens over data + psum partial FFN) instead
                 of all-gathering FSDP expert-weight shards
  rnn_local      pin recurrent-cell scans to data-parallel-only sharding
                 (kills per-timestep collectives inside the scan)
"""
from __future__ import annotations

import os

_DEFAULTS = {
    "causal_skip": True,
    "mlstm_chunked": True,
    "moe_wstat": True,
    "rnn_local": True,
    "decode_wstat": True,
    "decode_unroll": True,
}


def _parse():
    out = dict(_DEFAULTS)
    env = os.environ.get("REPRO_PERF", "")
    for tok in env.split(","):
        tok = tok.strip()
        if not tok:
            continue
        if "=" in tok:
            k, v = tok.split("=", 1)
            out[k.strip()] = v.strip() not in ("0", "false", "off")
        else:
            out[tok] = True
    return out


_FLAGS = _parse()


def flag(name: str) -> bool:
    return _FLAGS[name]


def reload():
    global _FLAGS
    _FLAGS = _parse()
    return _FLAGS
