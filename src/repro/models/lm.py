"""The model zoo: one composable block stack covering all 10 assigned
architectures (dense GQA, fine-grained/residual MoE, RG-LRU hybrid, xLSTM,
enc-dec, VLM backbone).

Everything is pure JAX (scan-over-layer-groups, remat per group); the
paper's technique enters at three irregular-access points, each with a
selectable rdma|rpc|auto backend (DESIGN.md §3):

  * embedding / logits   (vocab-sharded table: gather rows vs owner-compute)
  * MoE dispatch         (ship tokens via all_to_all vs pull expert weights)
  * distributed decode   (seq-sharded KV + stats combine vs KV gather)

Attention uses a chunked ("lax-flash") softmax so 32k prefill never
materializes S×S logits; the Pallas kernels in ../kernels are the TPU hot
paths of the same math (validated against the identical ref oracles).
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import (ATTN, LATTN, MLP, MOE, MLSTM, RGLRU, SLSTM,
                            ArchConfig)
from .. import perf
from ..core import costmodel
from ..core.types import Backend
from ..kernels import ops as kops
from . import sharding as shd

Array = jax.Array
CROSS = "cross"
EATTN = "eattn"   # encoder (non-causal) attention


# ===========================================================================
# Primitives
# ===========================================================================
def rms_norm(x: Array, scale: Array, eps: float) -> Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * (1.0 + scale)).astype(dt)


def rope(x: Array, positions: Array, theta: float) -> Array:
    """x (..., S, H, hd); positions (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           axis=-1).astype(x.dtype)


def _chunk_kv(x: Array, bk: int, nk: int) -> Array:
    """(B, Skv, Hkv, hd) -> (nk, B, bk, Hkv, hd) zero-padded."""
    B, Skv, Hkv, hd = x.shape
    pad = nk * bk - Skv
    xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return xp.reshape(B, nk, bk, Hkv, hd).transpose(1, 0, 2, 3, 4)


def _chunk_mask(j, bk, S, Skv, causal, window, kv_len):
    """Validity mask (B-or-1, S, bk) for kv chunk j."""
    kpos = j * bk + jnp.arange(bk)
    qpos = (jnp.arange(S) + (Skv - S))[:, None]  # queries end-aligned
    ok = jnp.broadcast_to((kpos < Skv)[None, None, :], (1, S, bk))
    if causal:
        ok = ok & (kpos[None, None, :] <= qpos[None])
    if window > 0:
        ok = ok & (kpos[None, None, :] > qpos[None] - window)
    if kv_len is not None:
        ok = ok & (kpos[None, None, :] < kv_len[:, None, None])
    return ok


def _flash_fwd(q, k, v, causal, window, kv_len, block_k):
    """Running-softmax scan over kv chunks. q (B,S,H,hd) k/v (B,Skv,Hkv,hd).
    Returns (out (B,S,H,hd), m (B,S,Hkv,g), l (B,S,Hkv,g))."""
    B, S, H, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    qg = q.reshape(B, S, Hkv, g, hd).astype(jnp.float32)
    scale = hd ** -0.5
    bk = min(block_k, Skv)
    nk = -(-Skv // bk)
    kc, vc = _chunk_kv(k, bk, nk), _chunk_kv(v, bk, nk)

    def step(carry, xs):
        acc, m, l = carry
        kb, vb, j = xs
        s = jnp.einsum("bsked,bckd->bscke",
                       qg, kb.astype(jnp.float32)) * scale
        ok = _chunk_mask(j, bk, S, Skv, causal, window, kv_len)
        s = jnp.where(ok[..., None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=2))
        msafe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.where(jnp.isfinite(s),
                      jnp.exp(s - msafe[:, :, None]), 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - msafe), 0.0)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bscke,bckd->bsked", p, vb.astype(jnp.float32))
        l = l * alpha + jnp.sum(p, axis=2)
        return (acc, m_new, l), None

    acc0 = jnp.zeros((B, S, Hkv, g, hd), jnp.float32)
    m0 = jnp.full((B, S, Hkv, g), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, S, Hkv, g), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0),
                                  (kc, vc, jnp.arange(nk)))
    out = (acc / jnp.maximum(l, 1e-30)[..., None]).reshape(B, S, H, hd)
    return out.astype(q.dtype), m, l


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_train(q, k, v, causal: bool, window: int, block_k: int):
    """Memory-optimal attention for train/prefill: the backward recomputes
    per-chunk probabilities from the saved softmax stats (m, l) — O(S·d)
    residuals instead of the O(S²) the autodiff-of-scan would store. This
    is the XLA-level twin of kernels/flash_attention.py."""
    out, _, _ = _flash_fwd(q, k, v, causal, window, None, block_k)
    return out


def _flash_train_fwd(q, k, v, causal, window, block_k):
    out, m, l = _flash_fwd(q, k, v, causal, window, None, block_k)
    return out, (q, k, v, out, m, l)


def _flash_train_bwd(causal, window, block_k, res, do):
    q, k, v, out, m, l = res
    B, S, H, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    scale = hd ** -0.5
    bk = min(block_k, Skv)
    nk = -(-Skv // bk)
    kc, vc = _chunk_kv(k, bk, nk), _chunk_kv(v, bk, nk)
    qg = q.reshape(B, S, Hkv, g, hd).astype(jnp.float32)
    dog = do.reshape(B, S, Hkv, g, hd).astype(jnp.float32)
    og = out.reshape(B, S, Hkv, g, hd).astype(jnp.float32)
    msafe = jnp.where(jnp.isfinite(m), m, 0.0)
    linv = 1.0 / jnp.maximum(l, 1e-30)
    delta = jnp.sum(dog * og, axis=-1)                 # (B,S,Hkv,g)

    def step(dq, xs):
        kb, vb, j = xs
        s = jnp.einsum("bsked,bckd->bscke",
                       qg, kb.astype(jnp.float32)) * scale
        ok = _chunk_mask(j, bk, S, Skv, causal, window, None)
        p = jnp.where(ok[..., None, None],
                      jnp.exp(s - msafe[:, :, None]) * linv[:, :, None],
                      0.0)                              # normalized probs
        dv = jnp.einsum("bscke,bsked->bckd", p, dog)
        dp = jnp.einsum("bsked,bckd->bscke", dog, vb.astype(jnp.float32))
        ds = p * (dp - delta[:, :, None]) * scale
        dq = dq + jnp.einsum("bscke,bckd->bsked", ds, kb.astype(jnp.float32))
        dk = jnp.einsum("bscke,bsked->bckd", ds, qg)
        return dq, (dk, dv)

    dq0 = jnp.zeros((B, S, Hkv, g, hd), jnp.float32)
    dq, (dkc, dvc) = jax.lax.scan(step, dq0, (kc, vc, jnp.arange(nk)))
    dk = dkc.transpose(1, 0, 2, 3, 4).reshape(B, nk * bk, Hkv, hd)[:, :Skv]
    dv = dvc.transpose(1, 0, 2, 3, 4).reshape(B, nk * bk, Hkv, hd)[:, :Skv]
    return (dq.reshape(B, S, H, hd).astype(q.dtype), dk.astype(k.dtype),
            dv.astype(v.dtype))


flash_train.defvjp(_flash_train_fwd, _flash_train_bwd)


def chunked_flash(q: Array, k: Array, v: Array, *, causal: bool,
                  window: int = 0, kv_len: Optional[Array] = None,
                  block_k: int = 1024) -> Array:
    """Attention front-end. Differentiable path (train/prefill) uses the
    flash custom_vjp; decode paths (kv_len masking, never differentiated)
    use the raw scan.

    §Perf `causal_skip`: process q in N chunks, each attending only up to
    its causal frontier (plus the window's lower bound for local
    attention) — skipped kv blocks cost zero FLOPs instead of being
    computed-then-masked. Positions stay aligned because the inner kernel
    end-aligns queries to the kv slice.
    """
    if kv_len is not None:
        out, _, _ = _flash_fwd(q, k, v, causal, window, kv_len, block_k)
        return out
    S, Skv = q.shape[1], k.shape[1]
    if (not perf.flag("causal_skip") or not causal or S != Skv
            or S <= 2 * block_k):
        return flash_train(q, k, v, causal, window, block_k)
    n_chunks = min(8, S // block_k)
    bq = -(-S // n_chunks)
    outs = []
    for i in range(n_chunks):
        qlo, qhi = i * bq, min(S, (i + 1) * bq)
        klo = 0 if window <= 0 else max(0, qlo - window + 1)
        outs.append(flash_train(q[:, qlo:qhi], k[:, klo:qhi],
                                v[:, klo:qhi], causal, window, block_k))
    return jnp.concatenate(outs, axis=1)


# ===========================================================================
# Parameter initialization (per block kind; all arrays get a leading
# n_groups axis via init_stack)
# ===========================================================================
def _dense(key, shape, dtype, scale=None):
    fan_in = shape[-2] if len(shape) > 1 else shape[0]
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def init_block(cfg: ArchConfig, kind: str, key) -> Dict[str, Array]:
    D, F, hd = cfg.d_model, cfg.d_ff, cfg.hd
    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    R = cfg.rnn_width or D
    dt = cfg.compute_dtype
    ks = jax.random.split(key, 12)
    if kind in (ATTN, LATTN, EATTN, CROSS):
        return {
            "norm": jnp.zeros((D,), dt),
            "wq": _dense(ks[0], (D, H * hd), dt),
            "wk": _dense(ks[1], (D, Hkv * hd), dt),
            "wv": _dense(ks[2], (D, Hkv * hd), dt),
            "wo": _dense(ks[3], (H * hd, D), dt),
        }
    if kind == MLP:
        return {
            "norm": jnp.zeros((D,), dt),
            "w1": _dense(ks[0], (D, F), dt),
            "w3": _dense(ks[1], (D, F), dt),
            "w2": _dense(ks[2], (F, D), dt),
        }
    if kind == MOE:
        E, Fe = cfg.n_experts, cfg.moe_d_ff
        p = {
            "norm": jnp.zeros((D,), dt),
            "router": _dense(ks[0], (D, E), jnp.float32),
            "we1": _dense(ks[1], (E, D, Fe), dt),
            "we3": _dense(ks[2], (E, D, Fe), dt),
            "we2": _dense(ks[3], (E, Fe, D), dt),
        }
        if cfg.n_shared_experts:
            Fs = cfg.n_shared_experts * Fe
            p.update(ws1=_dense(ks[4], (D, Fs), dt),
                     ws3=_dense(ks[5], (D, Fs), dt),
                     ws2=_dense(ks[6], (Fs, D), dt))
        if cfg.dense_residual:
            p.update(wd1=_dense(ks[7], (D, F), dt),
                     wd3=_dense(ks[8], (D, F), dt),
                     wd2=_dense(ks[9], (F, D), dt))
        return p
    if kind == RGLRU:
        return {
            "norm": jnp.zeros((D,), dt),
            "wx": _dense(ks[0], (D, R), dt),
            "wg": _dense(ks[1], (D, R), dt),
            "wr": _dense(ks[2], (D, R), dt),
            "wo": _dense(ks[3], (R, D), dt),
            "a_param": jnp.full((R,), 2.0, jnp.float32),  # sigmoid≈0.88
        }
    if kind == MLSTM:
        return {
            "norm": jnp.zeros((D,), dt),
            "wq": _dense(ks[0], (D, H * hd), dt),
            "wk": _dense(ks[1], (D, H * hd), dt),
            "wv": _dense(ks[2], (D, H * hd), dt),
            "wi": _dense(ks[3], (D, H), dt, scale=0.01),
            "wf": _dense(ks[4], (D, H), dt, scale=0.01),
            "wog": _dense(ks[5], (D, H * hd), dt),
            "wo": _dense(ks[6], (H * hd, D), dt),
        }
    if kind == SLSTM:
        return {
            "norm": jnp.zeros((D,), dt),
            "wz": _dense(ks[0], (D, R), dt),
            "wi": _dense(ks[1], (D, R), dt, scale=0.01),
            "wf": _dense(ks[2], (D, R), dt, scale=0.01),
            "wog": _dense(ks[3], (D, R), dt),
            "rz": _dense(ks[4], (R, R), dt),
            "wo": _dense(ks[5], (R, D), dt),
        }
    raise ValueError(kind)


# Logical sharding for each parameter (maps via models/sharding.py rules).
_BLOCK_SPECS = {
    "norm": (None,),
    "wq": ("embed_fsdp", "heads"), "wk": ("embed_fsdp", "heads"),
    "wv": ("embed_fsdp", "heads"), "wo": ("heads", "embed_fsdp"),
    "w1": ("embed_fsdp", "ffn"), "w3": ("embed_fsdp", "ffn"),
    "w2": ("ffn", "embed_fsdp"),
    "router": (None, None),
    # experts over "model" (EP); FSDP shard on the Fe dim so both the
    # weight-gather and weight-stationary dispatch paths use one layout
    "we1": ("experts", None, "embed_fsdp"),
    "we3": ("experts", None, "embed_fsdp"),
    "we2": ("experts", "embed_fsdp", None),
    "ws1": ("embed_fsdp", "ffn"), "ws3": ("embed_fsdp", "ffn"),
    "ws2": ("ffn", "embed_fsdp"),
    "wd1": ("embed_fsdp", "ffn"), "wd3": ("embed_fsdp", "ffn"),
    "wd2": ("ffn", "embed_fsdp"),
    "wx": ("embed_fsdp", "ffn"), "wg": ("embed_fsdp", "ffn"),
    "wr": ("embed_fsdp", "ffn"),
    "a_param": (None,),
    "wi": ("embed_fsdp", None), "wf": ("embed_fsdp", None),
    "wog": ("embed_fsdp", "heads"),
    "wz": ("embed_fsdp", "ffn"), "rz": (None, None),
}


def block_param_specs(cfg: ArchConfig, kind: str, stacked: bool
                      ) -> Dict[str, tuple]:
    p = jax.eval_shape(lambda: init_block(cfg, kind, jax.random.PRNGKey(0)))
    lead = ("stage",) if stacked else ()
    out = {}
    for name in p:
        spec = _BLOCK_SPECS[name]
        if kind == SLSTM and name == "wo":
            spec = ("ffn", "embed_fsdp")
        if kind == RGLRU and name == "wo":
            spec = ("ffn", "embed_fsdp")
        out[name] = lead + spec
    return out


# ===========================================================================
# Block application
# ===========================================================================
def _attn_qkv(p, x, cfg, positions, decode: bool = False):
    B, S, D = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    if decode and perf.flag("decode_wstat"):
        # §Perf decode_wstat: one-token activations are tiny; replicate
        # them so XLA computes with the FSDP weight shards in place
        # (partial-sum) instead of all-gathering the weights every token.
        h = shd.logical(h, None, None, "embed")
    else:
        h = shd.logical(h, "batch", None, "embed")
    q = (h @ p["wq"]).reshape(B, S, H, hd)
    k = (h @ p["wk"]).reshape(B, S, Hkv, hd)
    v = (h @ p["wv"]).reshape(B, S, Hkv, hd)
    q = shd.logical(q, "batch", None, "heads", None)
    k = rope(k, positions, cfg.rope_theta)
    q = rope(q, positions, cfg.rope_theta)
    return q, k, v


def attn_block_train(p, x, cfg, kind: str) -> Array:
    """Full-sequence attention (train / prefill); returns residual delta."""
    B, S, D = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = _attn_qkv(p, x, cfg, positions)
    causal = kind != EATTN
    window = cfg.local_window if kind == LATTN else 0
    out = chunked_flash(q, k, v, causal=causal, window=window)
    out = shd.logical(out, "batch", None, "heads", None)
    y = out.reshape(B, S, -1) @ p["wo"]
    return shd.logical(y, "batch", "seq", "embed")


def attn_block_decode(p, x, cfg, kind: str, cache, pos):
    """One-token decode. cache = {k,v: (B, S_c, Hkv, hd)}; pos (B,) current
    length. Global attn: S_c = max context. Local attn: ring of window W.

    Backend selection (paper §3): 'rpc' keeps the cache seq-sharded and
    reduces flash stats across shards (XLA distributed softmax — constant
    reply bytes); 'rdma' gathers the cache to the query owner.
    """
    B, S, D = x.shape
    assert S == 1
    Hkv, hd = cfg.n_kv_heads, cfg.hd
    W = cache["k"].shape[1]
    q, k, v = _attn_qkv(p, x, cfg, pos[:, None], decode=True)
    slot = (pos % W) if kind == LATTN else pos

    def upd(c, new):
        idx = slot[:, None, None, None]
        oh = (jnp.arange(W)[None, :, None, None] == idx)
        return jnp.where(oh, new, c)

    ck = upd(cache["k"], k)
    cv = upd(cache["v"], v)
    backend = _decode_backend(cfg, W, B)
    if backend == Backend.RDMA:
        ck = shd.logical(ck, "batch", None, None, None)      # gather cache
        cv = shd.logical(cv, "batch", None, None, None)
    else:
        ck = shd.logical(ck, "batch", "kv_seq", None, None)  # owner-compute
        cv = shd.logical(cv, "batch", "kv_seq", None, None)
    if kind == LATTN:
        # ring buffer: slot j holds absolute position p_j <= pos with
        # p_j ≡ j (mod W); valid if within window.
        pj = pos[:, None] - ((pos[:, None] - jnp.arange(W)[None]) % W)
        valid = (pj >= 0) & (pj > pos[:, None] - W) & (pj <= pos[:, None])
        out = _decode_attn_masked(q, ck, cv, valid)
    else:
        out = _decode_attn_distributed(q, ck, cv, pos, backend)
    y = out.reshape(B, 1, -1) @ p["wo"]
    return shd.logical(y, "batch", None, "embed"), {"k": ck, "v": cv}


def _decode_backend(cfg: ArchConfig, kv_len: int, batch: int) -> Backend:
    b = Backend(cfg.decode_backend) if cfg.decode_backend != "auto" else None
    if b is not None:
        return b
    shards = 16  # model-axis width of the production mesh
    choice = costmodel.choose_attention_backend(
        kv_bytes_per_shard=2 * kv_len // shards * cfg.n_kv_heads * cfg.hd * 2,
        q_heads=cfg.n_heads, head_dim=cfg.hd, shards=shards)
    return choice


def _decode_attn_distributed(q, ck, cv, pos, backend: Backend):
    """Global-attention decode over the (possibly seq-sharded) cache.

    RPC style (shard_map): every KV shard runs flash partials over its
    LOCAL slice and replies with (o, m, l) — constant-size stats — which
    are combined associatively at the query owner (ref.combine_decode
    semantics, the paper's aggregated-AM pattern; kernels/flash_decode.py
    is the TPU kernel of the shard-local body). This also avoids the
    baseline pathology where scanning kv chunks slices across the sharded
    axis and XLA re-gathers the whole cache every chunk (§Perf log).
    """
    B, S, H, hd = q.shape
    W, Hkv = ck.shape[1], ck.shape[2]
    mesh = shd.current_mesh()
    tp = mesh.shape.get("model", 1) if mesh is not None else 1
    if (backend == Backend.RDMA or mesh is None or tp == 1 or W % tp
            or not perf.flag("decode_wstat")):
        return chunked_flash(q, ck, cv, causal=False, kv_len=pos + 1)
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = 1
    for a in batch_axes:
        dp *= mesh.shape[a]
    if B % dp:
        return chunked_flash(q, ck, cv, causal=False, kv_len=pos + 1)
    W_loc = W // tp
    g = H // Hkv

    def body(q_l, k_l, v_l, pos_l):
        # q_l (Bl,1,H,hd); k_l/v_l (Bl, W_loc, Hkv, hd); pos_l (Bl,)
        i = jax.lax.axis_index("model")
        ln = jnp.clip(pos_l + 1 - i * W_loc, 0, W_loc)
        qg = q_l[:, 0].reshape(-1, Hkv, g, hd).astype(jnp.float32)
        kf = k_l.astype(jnp.float32)
        s = jnp.einsum("bkgd,bwkd->bkgw", qg, kf) * hd ** -0.5
        ok = (jnp.arange(W_loc)[None, None, None, :]
              < ln[:, None, None, None])
        s = jnp.where(ok, s, -jnp.inf)
        m = jnp.max(s, axis=-1)
        msafe = jnp.where(jnp.isfinite(m), m, 0.0)
        p = jnp.where(ok, jnp.exp(s - msafe[..., None]), 0.0)
        l = jnp.sum(p, axis=-1)
        o = jnp.einsum("bkgw,bwkd->bkgd", p, v_l.astype(jnp.float32))
        # --- the AM reply: constant-size flash stats to the query owner
        oall = jax.lax.all_gather(o, "model")          # (tp, Bl, ...)
        mall = jax.lax.all_gather(m, "model")
        lall = jax.lax.all_gather(l, "model")
        from ..kernels import ref as kref
        Bl = q_l.shape[0]
        comb = kref.combine_decode_stats(
            oall.reshape(tp, Bl, Hkv * g, hd),
            mall.reshape(tp, Bl, Hkv * g),
            lall.reshape(tp, Bl, Hkv * g))
        return comb.reshape(Bl, 1, H, hd).astype(q_l.dtype)

    fn = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(batch_axes, None, None, None),
                  P(batch_axes, "model", None, None),
                  P(batch_axes, "model", None, None),
                  P(batch_axes)),
        out_specs=P(batch_axes, None, None, None), check_vma=False)
    return fn(q, ck, cv, pos)


def _decode_attn_masked(q, k, v, valid):
    """q (B,1,H,hd); k/v (B,W,Hkv,hd); valid (B,W)."""
    B, _, H, hd = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    qg = q.reshape(B, Hkv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,bwkd->bkgw", qg,
                   k.astype(jnp.float32)) * hd ** -0.5
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgw,bwkd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def cross_block(p, x, cfg, enc_states):
    """Cross attention: each decoder layer projects K/V from the raw
    encoder states (B, Se, D)."""
    B, S, D = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    q = (h @ p["wq"]).reshape(B, S, H, hd)
    e = enc_states.astype(h.dtype)
    ke = (e @ p["wk"]).reshape(B, -1, Hkv, hd)
    ve = (e @ p["wv"]).reshape(B, -1, Hkv, hd)
    out = chunked_flash(q, ke, ve, causal=False)
    y = out.reshape(B, S, -1) @ p["wo"]
    return shd.logical(y, "batch", None, "embed")


def mlp_block(p, x, cfg, w1="w1", w3="w3", w2="w2"):
    h = rms_norm(x, p["norm"], cfg.norm_eps) if "norm" in p else x
    h = shd.logical(h, "batch", None, "embed")
    u = jax.nn.silu(h @ p[w1]) * (h @ p[w3])
    u = shd.logical(u, "batch", None, "ffn")
    y = u @ p[w2]
    return shd.logical(y, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# MoE block — the paper's technique as a first-class feature
# ---------------------------------------------------------------------------
def moe_block(p, x, cfg: ArchConfig) -> Array:
    B, S, D = x.shape
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    backend = _moe_backend(cfg, B * S)
    mesh = shd.current_mesh()
    tp = mesh.shape.get("model", 1) if mesh is not None else 1
    dp = 1
    if mesh is not None:
        for a in ("pod", "data"):
            dp *= mesh.shape.get(a, 1)
    shardable = (mesh is not None and tp > 1 and cfg.n_experts % tp == 0
                 and B % dp == 0)
    if not shardable or backend == Backend.RDMA:
        routed = _moe_local(p, h, cfg, gather_weights=backend == Backend.RDMA)
    else:
        routed = _moe_a2a(p, h, cfg, mesh)
    y = routed
    if cfg.n_shared_experts:
        y = y + mlp_block(p, x, cfg, "ws1", "ws3", "ws2")
    if cfg.dense_residual:
        y = y + mlp_block(p, x, cfg, "wd1", "wd3", "wd2")
    return shd.logical(y, "batch", "seq", "embed")


def _moe_backend(cfg: ArchConfig, tokens: int) -> Backend:
    if cfg.moe_backend != "auto":
        return Backend(cfg.moe_backend)
    expert_bytes = 3 * cfg.n_experts * cfg.d_model * cfg.moe_d_ff * 2
    return costmodel.choose_moe_backend(
        tokens_per_rank=max(tokens // 256, 1), d_model=cfg.d_model,
        expert_bytes_per_rank=expert_bytes)


def _route(h2, p, cfg):
    """h2 (T, D) -> (expert_ids (T*k,), weights (T*k,), flat order)."""
    logits = h2.astype(jnp.float32) @ p["router"]
    w, ids = jax.lax.top_k(logits, cfg.top_k)          # (T, k)
    w = jax.nn.softmax(w, axis=-1)
    return ids.reshape(-1), w.reshape(-1).astype(h2.dtype)


def _capacity(T: int, cfg: ArchConfig) -> int:
    return max(4, int(T * cfg.top_k / cfg.n_experts * cfg.capacity_factor))


def _expert_ffn(we1, we3, we2, buf):
    """buf (E, C, D) -> (E, C, D) through each expert's SwiGLU."""
    u = jnp.einsum("ecd,edf->ecf", buf, we1)
    g = jnp.einsum("ecd,edf->ecf", buf, we3)
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(u) * g, we2)


def _moe_local(p, h, cfg: ArchConfig, gather_weights: bool) -> Array:
    """RDMA-style / single-device MoE: expert weights come to the data
    owner (all-gather when sharded); tokens never leave their shard."""
    B, S, D = h.shape
    h2 = h.reshape(-1, D)
    T = h2.shape[0]
    ids, w = _route(h2, p, cfg)
    cap = _capacity(T, cfg)
    we1, we3, we2 = p["we1"], p["we3"], p["we2"]
    if gather_weights and shd.current_mesh() is not None:
        # the explicit 'pull the structure to the requester' phase
        we1 = shd.logical(we1, None, None, None)
        we3 = shd.logical(we3, None, None, None)
        we2 = shd.logical(we2, None, None, None)
    counts, pos = kops.moe_dispatch(ids, n_experts=cfg.n_experts)
    keep = pos < cap
    tok = jnp.repeat(h2, cfg.top_k, axis=0)
    buf = jnp.zeros((cfg.n_experts, cap, D), h.dtype)
    buf = buf.at[jnp.where(keep, ids, cfg.n_experts),
                 jnp.where(keep, pos, 0)].add(tok, mode="drop")
    out_buf = _expert_ffn(we1, we3, we2, buf)
    picked = out_buf.at[jnp.where(keep, ids, cfg.n_experts),
                        jnp.where(keep, pos, 0)].get(
        mode="fill", fill_value=0)
    y = (picked * w[:, None]).reshape(T, cfg.top_k, D).sum(1)
    return y.reshape(B, S, D)


def _moe_a2a(p, h, cfg: ArchConfig, mesh) -> Array:
    """RPC-style MoE: tokens are aggregated active messages shipped to the
    expert owner over an explicit all_to_all; the 'handler' is the expert
    FFN; one reply all_to_all returns results. Exactly the paper's Fig. 2
    pattern at pod scale."""
    tp = mesh.shape["model"]
    E, k = cfg.n_experts, cfg.top_k
    D = cfg.d_model
    e_loc = E // tp
    axes = mesh.axis_names
    batch_axes = tuple(a for a in ("pod", "data") if a in axes)
    _, S_full, _ = h.shape
    seq_over_model = S_full % tp == 0 and S_full > 1
    xspec = P(batch_axes, "model" if seq_over_model else None, None)
    pspec = {name: shd.resolve(*spec) for name, spec in
             block_param_specs(cfg, MOE, stacked=False).items()
             if name in ("router", "we1", "we3", "we2")}

    dp = 1
    for a in batch_axes:
        dp *= mesh.shape[a]

    def body(h_loc, router, we1, we3, we2):
        Bl, Sl, _ = h_loc.shape
        h2 = h_loc.reshape(-1, D)
        T = h2.shape[0]
        cap = _capacity(T, cfg)
        # --- the paper's chooser, inside the model: move the structure's
        # contents (expert weight shards) to the requester, or move the
        # aggregated requests (tokens) to the owner? Static byte compare.
        token_bytes = 2 * dp * tp * cap * D          # AG + RS of tokens
        weight_bytes = 3 * D * cfg.moe_d_ff          # AG of w1/w3/w2 shards
        wstat = (perf.flag("moe_wstat") and bool(batch_axes)
                 and token_bytes < weight_bytes)
        if batch_axes and not wstat:
            # weight-gather (ZeRO-3 style): weights move to the tokens
            we1 = jax.lax.all_gather(we1, batch_axes, axis=2, tiled=True)
            we3 = jax.lax.all_gather(we3, batch_axes, axis=2, tiled=True)
            we2 = jax.lax.all_gather(we2, batch_axes, axis=1, tiled=True)
        logits = h2.astype(jnp.float32) @ router
        w, ids = jax.lax.top_k(logits, k)
        w = jax.nn.softmax(w, axis=-1).astype(h_loc.dtype)
        ids_f, w_f = ids.reshape(-1), w.reshape(-1)
        counts, pos = kops.moe_dispatch(ids_f, n_experts=E)
        keep = pos < cap
        tok = jnp.repeat(h2, k, axis=0)
        buf = jnp.zeros((E, cap, D), h_loc.dtype)
        buf = buf.at[jnp.where(keep, ids_f, E),
                     jnp.where(keep, pos, 0)].add(tok, mode="drop")
        # ---- request phase: ship token buffers to expert owners --------
        buf = buf.reshape(tp, e_loc, cap, D)
        buf = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=0,
                                 tiled=True)            # (tp*e_loc... )
        buf = buf.reshape(tp, e_loc, cap, D).transpose(1, 0, 2, 3)
        buf = buf.reshape(e_loc, tp * cap, D)
        # ---- handler: local experts run their FFN ----------------------
        if wstat:
            # weight-stationary: tokens visit every Fe shard; partial
            # outputs reduce-scatter back to the owning data row
            bufg = jax.lax.all_gather(buf, batch_axes, axis=1, tiled=True)
            part = _expert_ffn(we1, we3, we2, bufg)   # partial over Fe
            out = jax.lax.psum_scatter(part, batch_axes,
                                       scatter_dimension=1, tiled=True)
        else:
            out = _expert_ffn(we1, we3, we2, buf)
        # ---- reply phase ------------------------------------------------
        out = out.reshape(e_loc, tp, cap, D).transpose(1, 0, 2, 3)
        out = out.reshape(tp, e_loc, cap, D)
        out = jax.lax.all_to_all(out, "model", split_axis=0, concat_axis=0,
                                 tiled=True)
        out = out.reshape(E, cap, D)
        picked = out.at[jnp.where(keep, ids_f, E),
                        jnp.where(keep, pos, 0)].get(mode="fill",
                                                     fill_value=0)
        y = (picked * w_f[:, None]).reshape(T, k, D).sum(1)
        return y.reshape(Bl, Sl, D)

    fn = jax.shard_map(
        body, mesh=mesh,
        in_specs=(xspec, pspec["router"], pspec["we1"], pspec["we3"],
                  pspec["we2"]),
        out_specs=xspec, check_vma=False)
    return fn(h, p["router"], p["we1"], p["we3"], p["we2"])


# ---------------------------------------------------------------------------
# Recurrent blocks
# ---------------------------------------------------------------------------
def _rnn_scan(step, carry0, xs, chunk: int = 64):
    """scan with two-level remat (§Perf `mlstm_chunked`): the outer scan
    saves only per-chunk carries; inner per-step residuals are
    rematerialized in the backward — per-step state stacks (the xLSTM
    memory catastrophe) shrink by ~chunk."""
    S = jax.tree.leaves(xs)[0].shape[0]
    if not perf.flag("mlstm_chunked") or S % chunk or S <= chunk:
        return jax.lax.scan(step, carry0, xs)

    xs_c = jax.tree.map(
        lambda a: a.reshape((S // chunk, chunk) + a.shape[1:]), xs)

    def outer(carry, xc):
        return jax.lax.scan(step, carry, xc)

    outer_r = jax.checkpoint(outer, prevent_cse=False)
    carry, ys_c = jax.lax.scan(outer_r, carry0, xs_c)
    ys = jax.tree.map(
        lambda a: a.reshape((S,) + a.shape[2:]), ys_c)
    return carry, ys


def _pin_batch_only(*arrays):
    """§Perf `rnn_local`: pin recurrence inputs to data-parallel-only
    sharding so the timestep loop contains zero collectives (the baseline
    emitted one all-gather per step per cell — 4e5 per train step on
    xlstm — which is launch-latency death at pod scale)."""
    if not perf.flag("rnn_local"):
        return arrays
    out = []
    for a in arrays:
        names = ["batch"] + [None] * (a.ndim - 1)
        out.append(shd.logical(a, *names))
    return tuple(out)


def rglru_block(p, x, cfg, state=None):
    """RecurrentGemma RG-LRU mixer. state (B, R) or None (train, h0=0).
    Returns (delta, new_state)."""
    B, S, D = x.shape
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    xr = h @ p["wx"]
    gate = jax.nn.sigmoid(h @ p["wg"])
    r = jax.nn.sigmoid(h @ p["wr"]).astype(jnp.float32)
    log_a = 8.0 * r * jax.nn.log_sigmoid(p["a_param"])[None, None, :]
    a = jnp.exp(log_a)
    b = (jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
         * (xr * gate).astype(jnp.float32))
    if x.shape[1] > 1:
        # elementwise recurrence: keep the D axis model-sharded (unlike the
        # matrix-state cells, no cross-D mixing happens inside the scan)
        a = shd.logical(a, "batch", None, "ffn")
        b = shd.logical(b, "batch", None, "ffn")
    hs = kops.rg_lru_scan(a, b, state)
    new_state = hs[:, -1]
    y = hs.astype(x.dtype) @ p["wo"]
    return shd.logical(y, "batch", "seq", "embed"), new_state


def _mlstm_chunkwise(q, kk, v, it, ft, state, chunk: int = 128):
    """Chunkwise-parallel mLSTM (§Perf `mlstm_chunked`, exact): the
    C/n/m recurrence is materialized only at chunk boundaries; within a
    chunk the output is the stabilized intra-chunk attention form plus the
    inter-chunk carry term. Numerically identical to the sequential cell
    (same stabilizer: m_t = F_t + max(m_prev, cummax_s(li_s - F_s))),
    validated by the decode==forward tests.

    q/kk/v (B,S,H,hd) f32 (pre-scaled); it/ft (B,S,H) raw gate logits.
    Returns (h (B,S,H,hd), (C,n,m))."""
    B, S, H, hd = q.shape
    c = min(chunk, S)
    while S % c:
        c //= 2
    nc = S // c
    C0, n0, m0 = state

    def chunk_step(carry, xs):
        C, n, m = carry                       # (B,H,hd,hd),(B,H,hd),(B,H)
        qc, kc, vc, ic, fc = xs               # (B,c,H,*)
        lf = jax.nn.log_sigmoid(fc)           # (B,c,H)
        F = jnp.cumsum(lf, axis=1)
        rel = ic - F                          # li_s - F_s
        M = jnp.maximum(m[:, None],
                        jax.lax.cummax(rel, axis=1))        # (B,c,H)
        inter = jnp.exp(m[:, None] - M)                     # (B,c,H)
        d = jnp.exp(rel[:, None] - M[:, :, None])           # (B,t,s,H)
        tri = jnp.tril(jnp.ones((c, c), bool))
        d = jnp.where(tri[None, :, :, None], d, 0.0)
        scores = jnp.einsum("bthd,bshd->btsh", qc, kc) * d
        num = (inter[..., None] * jnp.einsum("bthd,bhde->bthe", qc, C)
               + jnp.einsum("btsh,bshd->bthd", scores, vc))
        # q·n_t decomposes into the same gate weights: no ñ materialization
        qn = (inter * jnp.einsum("bthd,bhd->bth", qc, n)
              + jnp.sum(scores, axis=2))
        den = jnp.abs(qn)
        h = num / jnp.maximum(den, 1.0)[..., None]
        # chunk-end state
        M_end, F_end = M[:, -1], F[:, -1]
        w_end = jnp.exp(rel - M_end[:, None])               # (B,c,H)
        C_new = (jnp.exp(m - M_end)[..., None, None] * C
                 + jnp.einsum("bsh,bshd,bshe->bhde", w_end, kc, vc))
        n_new = (jnp.exp(m - M_end)[..., None] * n
                 + jnp.einsum("bsh,bshd->bhd", w_end, kc))
        m_new = F_end + M_end
        return (C_new, n_new, m_new), h

    def to_chunks(a):
        return a.reshape((B, nc, c) + a.shape[2:]).swapaxes(0, 1)

    xs = tuple(to_chunks(a) for a in (q, kk, v, it, ft))
    (C, n, m), hs = jax.lax.scan(chunk_step, (C0, n0, m0), xs)
    h = hs.swapaxes(0, 1).reshape(B, S, H, hd)
    return h, (C, n, m)


def mlstm_block(p, x, cfg, state=None):
    """xLSTM mLSTM: matrix-memory cell, stabilized exponential gating.
    state = (C (B,H,hd,hd), n (B,H,hd), m (B,H)). Returns (delta, state')."""
    B, S, D = x.shape
    H, hd = cfg.n_heads, cfg.hd
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    q = (h @ p["wq"]).reshape(B, S, H, hd).astype(jnp.float32) * hd ** -0.5
    kk = (h @ p["wk"]).reshape(B, S, H, hd).astype(jnp.float32) * hd ** -0.25
    v = (h @ p["wv"]).reshape(B, S, H, hd).astype(jnp.float32)
    it = (h @ p["wi"]).astype(jnp.float32)           # (B, S, H)
    ft = (h @ p["wf"]).astype(jnp.float32)
    og = jax.nn.sigmoid((h @ p["wog"]).reshape(B, S, H, hd))
    if state is None:
        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, i_, f_ = xs
        logf = jax.nn.log_sigmoid(f_)
        m_new = jnp.maximum(logf + m, i_)
        i = jnp.exp(i_ - m_new)
        f = jnp.exp(logf + m - m_new)
        C = f[..., None, None] * C + i[..., None, None] * (
            kt[..., :, None] * vt[..., None, :])
        n = f[..., None] * n + i[..., None] * kt
        num = jnp.einsum("bhd,bhde->bhe", qt, C)
        den = jnp.abs(jnp.einsum("bhd,bhd->bh", qt, n))
        ht = num / jnp.maximum(den, 1.0)[..., None]
        return (C, n, m_new), ht

    q, kk, v, it, ft, og = _pin_batch_only(q, kk, v, it, ft, og)
    C0, n0, m0 = _pin_batch_only(C0, n0, m0)
    if S > 1 and perf.flag("mlstm_chunked") and S % 2 == 0:
        hs, (C, n, m) = _mlstm_chunkwise(q, kk, v, it, ft, (C0, n0, m0))
    else:
        xs = (q.transpose(1, 0, 2, 3), kk.transpose(1, 0, 2, 3),
              v.transpose(1, 0, 2, 3), it.transpose(1, 0, 2),
              ft.transpose(1, 0, 2))
        if S == 1:
            (C, n, m), hs = jax.lax.scan(step, (C0, n0, m0), xs)
        else:
            (C, n, m), hs = _rnn_scan(step, (C0, n0, m0), xs)
        hs = hs.transpose(1, 0, 2, 3)                # (B, S, H, hd)
    if perf.flag("rnn_local"):
        hs = shd.logical(hs, "batch", None, None, None)
    y = (og * hs.astype(x.dtype)).reshape(B, S, -1) @ p["wo"]
    return shd.logical(y, "batch", "seq", "embed"), (C, n, m)


def slstm_block(p, x, cfg, state=None):
    """xLSTM sLSTM: scalar-memory cell with recurrent connection R_z.
    state = (c, n, hprev, m) each (B, R)."""
    B, S, D = x.shape
    R = cfg.rnn_width or D
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    z_in = (h @ p["wz"]).astype(jnp.float32)
    i_in = (h @ p["wi"]).astype(jnp.float32)
    f_in = (h @ p["wf"]).astype(jnp.float32)
    og = jax.nn.sigmoid((h @ p["wog"]).astype(jnp.float32))
    rz = p["rz"].astype(jnp.float32)
    if state is None:
        c0 = jnp.zeros((B, R), jnp.float32)
        n0 = jnp.zeros((B, R), jnp.float32)
        h0 = jnp.zeros((B, R), jnp.float32)
        m0 = jnp.full((B, R), -1e30, jnp.float32)
    else:
        c0, n0, h0, m0 = state

    def step(carry, xs):
        c, n, hp, m = carry
        zt, it_, ft_, ot = xs
        z = jnp.tanh(zt + hp @ rz)
        logf = jax.nn.log_sigmoid(ft_)
        m_new = jnp.maximum(logf + m, it_)
        i = jnp.exp(it_ - m_new)
        f = jnp.exp(logf + m - m_new)
        c = f * c + i * z
        n = f * n + i
        ht = ot * c / jnp.maximum(n, 1.0)
        return (c, n, ht, m_new), ht

    z_in, i_in, f_in, og = _pin_batch_only(z_in, i_in, f_in, og)
    c0, n0, h0, m0 = _pin_batch_only(c0, n0, h0, m0)
    xs = (z_in.transpose(1, 0, 2), i_in.transpose(1, 0, 2),
          f_in.transpose(1, 0, 2), og.transpose(1, 0, 2))
    if S == 1:
        (c, n, hl, m), hs = jax.lax.scan(step, (c0, n0, h0, m0), xs)
    else:
        (c, n, hl, m), hs = _rnn_scan(step, (c0, n0, h0, m0), xs)
    hs = hs.transpose(1, 0, 2)
    if perf.flag("rnn_local"):
        hs = shd.logical(hs, "batch", None, None)
    y = hs.astype(x.dtype) @ p["wo"]
    return shd.logical(y, "batch", "seq", "embed"), (c, n, hl, m)


# ===========================================================================
# Stack: init / train forward / prefill / decode
# ===========================================================================
def init_params(cfg: ArchConfig, key) -> Dict[str, Any]:
    keys = jax.random.split(key, 8)
    dt = cfg.compute_dtype
    pattern = cfg.layer_pattern()
    G = cfg.n_groups

    def stacked_group(key):
        def one(k):
            ks = jax.random.split(k, sum(len(l) for l in pattern))
            i, out = 0, []
            for layer in pattern:
                blocks = []
                for kind in layer:
                    blocks.append(init_block(cfg, kind, ks[i]))
                    i += 1
                out.append(tuple(blocks))
            return tuple(out)

        return jax.vmap(one)(jax.random.split(key, G))

    params = {
        "embed": _dense(keys[0], (cfg.vocab_padded, cfg.d_model), dt,
                        scale=0.02),
        "groups": stacked_group(keys[1]),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
    }
    if cfg.family == "encdec":
        enc_pat = ((EATTN, MLP),)
        dec_pat = ((ATTN, CROSS, MLP),)
        def enc_stack(k):
            def one(kk):
                ks = jax.random.split(kk, 2)
                return ((init_block(cfg, EATTN, ks[0]),
                         init_block(cfg, MLP, ks[1])),)
            return jax.vmap(one)(jax.random.split(k, cfg.n_enc_layers))
        def dec_stack(k):
            def one(kk):
                ks = jax.random.split(kk, 3)
                return ((init_block(cfg, ATTN, ks[0]),
                         init_block(cfg, CROSS, ks[1]),
                         init_block(cfg, MLP, ks[2])),)
            return jax.vmap(one)(jax.random.split(k, cfg.n_layers))
        params["enc_groups"] = enc_stack(keys[2])
        params["groups"] = dec_stack(keys[3])
        params["enc_norm"] = jnp.zeros((cfg.d_model,), dt)
    return params


def param_specs(cfg: ArchConfig) -> Dict[str, Any]:
    """Logical-name tuples matching init_params' tree structure."""
    pattern = cfg.layer_pattern()

    def group_specs(pat):
        return tuple(tuple(block_param_specs(cfg, kind, stacked=True)
                           for kind in layer) for layer in pat)

    specs = {
        "embed": ("vocab", "embed_fsdp"),
        "groups": group_specs(pattern),
        "final_norm": (None,),
    }
    if cfg.family == "encdec":
        specs["enc_groups"] = group_specs(((EATTN, MLP),))
        specs["groups"] = group_specs(((ATTN, CROSS, MLP),))
        specs["enc_norm"] = (None,)
    return specs


def embed_tokens(params, cfg: ArchConfig, tokens: Array) -> Array:
    table = params["embed"]
    if cfg.embed_backend == "rdma" and shd.current_mesh() is not None:
        # pull rows to the requester: table replicated first (all-gather)
        table = shd.logical(table, None, None)
    else:
        # owner-compute: vocab-sharded table; XLA lowers the gather to
        # local masked lookup + all-reduce (the aggregated-AM pattern)
        table = shd.logical(table, "vocab", None)
    x = jnp.take(table, tokens, axis=0)
    return shd.logical(x, "batch", "seq", "embed") * cfg.d_model ** 0.5


def _apply_layer(cfg, layer_blocks, layer_params, x, mode, cache_in,
                 pos, enc_kv):
    """Apply one layer (tuple of blocks) with residual connections.
    Returns (x, cache_out)."""
    cache_out = []
    for b_idx, kind in enumerate(layer_blocks):
        p = layer_params[b_idx]
        if kind in (ATTN, LATTN, EATTN):
            if mode == "decode":
                delta, c = attn_block_decode(p, x, cfg, kind,
                                             cache_in[b_idx], pos)
                cache_out.append(c)
            else:
                delta = attn_block_train(p, x, cfg, kind)
                cache_out.append(None)
        elif kind == CROSS:
            delta = cross_block(p, x, cfg, enc_kv)
            cache_out.append(None)
        elif kind == MLP:
            delta = mlp_block(p, x, cfg)
            cache_out.append(None)
        elif kind == MOE:
            delta = moe_block(p, x, cfg)
            cache_out.append(None)
        elif kind in (RGLRU, MLSTM, SLSTM):
            fn = {RGLRU: rglru_block, MLSTM: mlstm_block,
                  SLSTM: slstm_block}[kind]
            st = cache_in[b_idx] if mode == "decode" else None
            delta, st2 = fn(p, x, cfg, st)
            cache_out.append(st2 if mode == "decode" else None)
        else:
            raise ValueError(kind)
        x = x + delta
    return x, tuple(cache_out)


def _run_stack(params_groups, cfg: ArchConfig, x: Array, mode: str,
               caches=None, pos=None, enc_kv=None, pattern=None):
    pattern = pattern or cfg.layer_pattern()

    def group_fn(x, xs):
        g_params, g_cache = xs
        new_cache = []
        for li, layer_blocks in enumerate(pattern):
            cin = g_cache[li] if g_cache is not None else \
                tuple(None for _ in layer_blocks)
            x, cout = _apply_layer(cfg, layer_blocks, g_params[li], x,
                                   mode, cin, pos, enc_kv)
            new_cache.append(cout)
        return x, tuple(new_cache)

    if mode == "train" and cfg.remat:
        group_fn = jax.checkpoint(group_fn,
                                  prevent_cse=False)

    if mode == "decode" and perf.flag("decode_unroll"):
        # §Perf decode_unroll: a scanned group loop dynamic-slices the
        # (G, ...) stacked KV caches every iteration, which XLA can only
        # reshard by full rematerialization (gathers the whole cache per
        # layer group). Static per-group indexing keeps cache shards in
        # place; decode bodies are small so the unrolled HLO stays cheap.
        G = jax.tree.leaves(params_groups)[0].shape[0]
        new_caches = []
        for g in range(G):
            g_params = jax.tree.map(lambda a: a[g], params_groups)
            g_cache = jax.tree.map(lambda a: a[g], caches)
            x, cout = group_fn(x, (g_params, g_cache))
            new_caches.append(cout)
        new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches)
        return x, new_caches

    x, new_caches = jax.lax.scan(group_fn, x, (params_groups, caches))
    return x, new_caches


def logits_fn(params, cfg: ArchConfig, x: Array) -> Array:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = shd.logical(params["embed"], "vocab", None)
    logits = jnp.einsum("bsd,vd->bsv", x, table)
    if cfg.vocab_padded != cfg.vocab:
        pad_mask = jnp.arange(cfg.vocab_padded) >= cfg.vocab
        logits = jnp.where(pad_mask, -1e30, logits)
    return shd.logical(logits, "batch", None, "vocab")


def _forward(params, cfg: ArchConfig, tokens: Array,
             extra: Optional[Dict[str, Array]] = None) -> Array:
    """Token (+frontend stub) -> final hidden states (train/prefill)."""
    x = embed_tokens(params, cfg, tokens)
    if cfg.family == "vlm" and extra is not None and "patch_embeds" in extra:
        # anyres frontend stub: precomputed patch embeddings prepended
        pe = extra["patch_embeds"].astype(x.dtype)
        x = jnp.concatenate([shd.logical(pe, "batch", None, "embed"), x], 1)
    if cfg.family == "encdec":
        frames = extra["frames"].astype(x.dtype)
        e = shd.logical(frames, "batch", None, "embed")
        e, _ = _run_stack(params["enc_groups"], cfg, e, "train",
                          pattern=((EATTN, MLP),))
        enc_states = rms_norm(e, params["enc_norm"], cfg.norm_eps)
        x, _ = _run_stack(params["groups"], cfg, x, "train",
                          enc_kv=enc_states, pattern=((ATTN, CROSS, MLP),))
    else:
        x, _ = _run_stack(params["groups"], cfg, x, "train")
    return x


def loss_fn(params, cfg: ArchConfig, batch: Dict[str, Array]) -> Array:
    tokens = batch["tokens"]
    x = _forward(params, cfg, tokens, extra=batch)
    if cfg.family == "vlm":
        x = x[:, -tokens.shape[1]:]           # loss on text positions only
    logits = logits_fn(params, cfg, x)
    targets = batch.get("labels", tokens)
    lg = logits[:, :-1].astype(jnp.float32)
    tg = targets[:, 1:]
    lse = jax.nn.logsumexp(lg, axis=-1)
    picked = jnp.take_along_axis(lg, tg[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - picked)


# ---------------------------------------------------------------------------
# Decode (serve path)
# ---------------------------------------------------------------------------
def init_decode_state(cfg: ArchConfig, batch: int, max_len: int,
                      dtype=None) -> Dict[str, Any]:
    """Cache template; shapes only — usable with jax.eval_shape for the
    dry run. Ring buffers for local attention, full rings for global."""
    dt = dtype or cfg.compute_dtype
    Hkv, hd = cfg.n_kv_heads, cfg.hd
    G = cfg.n_groups
    R = cfg.rnn_width or cfg.d_model
    H = cfg.n_heads

    def layer_cache(kind):
        if kind in (ATTN, EATTN):
            W = max_len
            return {"k": jnp.zeros((G, batch, W, Hkv, hd), dt),
                    "v": jnp.zeros((G, batch, W, Hkv, hd), dt)}
        if kind == LATTN:
            W = min(cfg.local_window, max_len)
            return {"k": jnp.zeros((G, batch, W, Hkv, hd), dt),
                    "v": jnp.zeros((G, batch, W, Hkv, hd), dt)}
        if kind == RGLRU:
            return jnp.zeros((G, batch, R), jnp.float32)
        if kind == MLSTM:
            return (jnp.zeros((G, batch, H, hd, hd), jnp.float32),
                    jnp.zeros((G, batch, H, hd), jnp.float32),
                    jnp.full((G, batch, H), -1e30, jnp.float32))
        if kind == SLSTM:
            return tuple(jnp.zeros((G, batch, R), jnp.float32)
                         if i != 3 else
                         jnp.full((G, batch, R), -1e30, jnp.float32)
                         for i in range(4))
        return None

    pattern = (((ATTN, CROSS, MLP),) if cfg.family == "encdec"
               else cfg.layer_pattern())
    caches = tuple(tuple(layer_cache(kind) for kind in layer)
                   for layer in pattern)
    state = {"caches": caches, "pos": jnp.zeros((batch,), jnp.int32)}
    if cfg.family == "encdec":
        Se = 1500  # whisper frame capacity
        state["enc"] = jnp.zeros((batch, Se, cfg.d_model), dt)
    return state


def decode_state_logical_specs(cfg: ArchConfig) -> Dict[str, Any]:
    """Logical-axis tuples mirroring init_decode_state's tree structure
    (the serve-path analogue of param_specs)."""

    def layer_cache(kind):
        if kind in (ATTN, EATTN, LATTN):
            return {"k": (None, "batch", "kv_seq", None, None),
                    "v": (None, "batch", "kv_seq", None, None)}
        if kind == RGLRU:
            return (None, "batch", "ffn")
        if kind == MLSTM:
            # heads are few (4); shard the wide hd dims over the model axis
            return ((None, "batch", None, None, "ffn"),
                    (None, "batch", None, "ffn"),
                    (None, "batch", None))
        if kind == SLSTM:
            return tuple((None, "batch", "ffn") for _ in range(4))
        return None

    pattern = (((ATTN, CROSS, MLP),) if cfg.family == "encdec"
               else cfg.layer_pattern())
    caches = tuple(tuple(layer_cache(kind) for kind in layer)
                   for layer in pattern)
    specs = {"caches": caches, "pos": ("batch",)}
    if cfg.family == "encdec":
        specs["enc"] = ("batch", None, None)
    return specs


def decode_step(params, cfg: ArchConfig, state, tokens: Array
                ) -> Tuple[Array, Any]:
    """One token for every sequence. tokens (B,) -> (logits (B, V), state')."""
    B = tokens.shape[0]
    x = embed_tokens(params, cfg, tokens[:, None])
    pos = state["pos"]
    pattern = (((ATTN, CROSS, MLP),) if cfg.family == "encdec"
               else cfg.layer_pattern())
    x, new_caches = _run_stack(params["groups"], cfg, x, "decode",
                               caches=state["caches"], pos=pos,
                               enc_kv=state.get("enc"), pattern=pattern)
    logits = logits_fn(params, cfg, x)[:, 0]
    new_state = dict(state)
    new_state["caches"] = new_caches
    new_state["pos"] = pos + 1
    return logits, new_state
