"""Logical-axis sharding rules (MaxText-style) + the mesh context the model
layer reads.

The model code annotates tensors with *logical* axis names; the rules table
maps those to physical mesh axes. The launch layer installs a mesh +
(optionally overridden) rules; on a bare CPU (smoke tests) no mesh is set
and every annotation is a no-op, so the same model code runs everywhere.

Default physical mapping (production mesh (pod, data, model) or
(data, model)):

  batch        -> ("pod", "data")   data parallel (+ pod axis when present)
  seq          -> "model"           sequence parallelism for inter-block
                                    activations (Megatron-SP): saved
                                    activations are seq-sharded
  heads/kv     -> "model"           tensor parallel attention
  ffn/experts  -> "model"           tensor / expert parallel FFN
  vocab        -> "model"           vocab-sharded embedding + logits
  embed_fsdp   -> ("pod", "data")   ZeRO-3 style weight sharding on the
                                    embed dim of weight matrices
  kv_seq       -> "model"           seq-sharded KV cache in decode (the
                                    RPC-style distributed decode, §DESIGN 3)
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, Tuple[str, ...], None]

DEFAULT_RULES: dict[str, Axis] = {
    "batch": ("pod", "data"),
    "seq": "model",
    "heads": "model",
    "kv_heads": "model",
    "ffn": "model",
    "experts": "model",
    "vocab": "model",
    "embed": None,
    "embed_fsdp": ("pod", "data"),
    "kv_seq": "model",
    "stage": None,
    "frames": None,
}

_STATE = threading.local()


def _get() -> dict:
    if not hasattr(_STATE, "ctx"):
        _STATE.ctx = {"mesh": None, "rules": dict(DEFAULT_RULES)}
    return _STATE.ctx


@contextlib.contextmanager
def mesh_context(mesh: Optional[Mesh], rules: Optional[dict] = None):
    """Install mesh+rules for model tracing. Also enters jax.set_mesh so
    with_sharding_constraint works inside jit."""
    ctx = _get()
    prev = dict(ctx)
    ctx["mesh"] = mesh
    if rules is not None:
        ctx["rules"] = {**DEFAULT_RULES, **rules}
    try:
        if mesh is not None:
            # jax >= 0.5: jax.sharding.set_mesh / use_mesh. jax 0.4.x has
            # neither; there the Mesh object itself is the context manager
            # that makes bare-PartitionSpec with_sharding_constraint work.
            enter = (getattr(jax.sharding, "set_mesh", None)
                     or getattr(jax.sharding, "use_mesh", None))
            with (enter(mesh) if enter is not None else mesh):
                yield
        else:
            yield
    finally:
        ctx.clear()
        ctx.update(prev)


def current_mesh() -> Optional[Mesh]:
    return _get()["mesh"]


def rules() -> dict:
    return _get()["rules"]


def resolve(*logical: Optional[str]) -> P:
    """Map logical axis names to a PartitionSpec under the current rules,
    dropping mesh axes that don't exist on the current mesh."""
    mesh = current_mesh()
    axes = set(mesh.axis_names) if mesh is not None else set()
    out = []
    for name in logical:
        if name is None:
            out.append(None)
            continue
        phys = rules().get(name)
        if phys is None:
            out.append(None)
        elif isinstance(phys, tuple):
            keep = tuple(a for a in phys if a in axes)
            out.append(keep if len(keep) > 1 else (keep[0] if keep else None))
        else:
            out.append(phys if phys in axes else None)
    return P(*out)


def logical(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """Annotate x with logical axes; no-op without a mesh."""
    mesh = current_mesh()
    if mesh is None:
        return x
    assert len(names) == x.ndim, (names, x.shape)
    return jax.lax.with_sharding_constraint(x, resolve(*names))


def named_sharding(*logical_names: Optional[str]) -> Optional[NamedSharding]:
    mesh = current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, resolve(*logical_names))


def spec_for_tree(tree_of_logical):
    """Map a pytree of logical-name tuples to NamedShardings (or None)."""
    return jax.tree.map(lambda names: named_sharding(*names),
                        tree_of_logical,
                        is_leaf=lambda x: isinstance(x, tuple))
