from . import lm, sharding
