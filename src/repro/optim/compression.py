"""Gradient compression: int8 block quantization with error feedback.

Used for the *cross-pod* gradient reduction (the thin axis of the
production mesh): gradients are quantized to int8 + per-block f32 scales
(≈4.06x byte reduction at block 128), reduced, dequantized, and the
quantization error is fed back into the next step's gradient — the
standard EF-SGD trick that keeps convergence unbiased in expectation.

This is a beyond-paper distributed-optimization feature; its collective-
bytes effect is measured in EXPERIMENTS.md §Perf (hillclimb of the
collective-bound cell).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
BLOCK = 128


def compress_int8(x: Array) -> Tuple[Array, Array]:
    """x (any shape) -> (int8 codes, f32 scales per 128-block of the
    flattened tensor)."""
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    codes = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return codes, scale[:, 0]


def decompress_int8(codes: Array, scales: Array, shape, dtype) -> Array:
    blocks = codes.astype(jnp.float32) * scales[:, None]
    flat = blocks.reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def compressed_mean_grads(grads, axis_name: str, error: Optional[dict]
                          ) -> Tuple[dict, dict]:
    """Inside shard_map: psum-of-int8 gradient mean over `axis_name` with
    error feedback. Returns (mean grads, new error state).

    Note int8 codes are summed in int32 (no overflow below 2^23 ranks),
    then rescaled — one all-reduce of ~1/4 the bytes plus a tiny scale
    all-reduce.
    """
    nranks = jax.lax.psum(1, axis_name)

    def one(g, e):
        gf = g.astype(jnp.float32) + (0.0 if e is None else e)
        codes, scales = compress_int8(gf)
        # max-scale across ranks so codes are additive in a shared scale
        gscale = jax.lax.pmax(scales, axis_name)
        blocks = gf.reshape(-1)
        pad = (-blocks.shape[0]) % BLOCK
        blocks = jnp.pad(blocks, (0, pad)).reshape(-1, BLOCK)
        codes = jnp.clip(jnp.round(blocks / jnp.maximum(
            gscale[:, None], 1e-30)), -127, 127).astype(jnp.int8)
        local_deq = codes.astype(jnp.float32) * gscale[:, None]
        new_err = (blocks - local_deq).reshape(-1)[
            :gf.size].reshape(gf.shape)
        summed = jax.lax.psum(codes.astype(jnp.int32), axis_name)
        mean = (summed.astype(jnp.float32) * gscale[:, None] / nranks)
        mean = mean.reshape(-1)[:gf.size].reshape(gf.shape)
        return mean.astype(g.dtype), new_err

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = (jax.tree.leaves(error) if error is not None
              else [None] * len(flat_g))
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))
