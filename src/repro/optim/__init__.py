from .optimizers import (adafactor_init, adafactor_update, adamw_init,
                         adamw_update, clip_by_global_norm, make_optimizer,
                         warmup_cosine)
from .compression import (compress_int8, decompress_int8,
                          compressed_mean_grads)
