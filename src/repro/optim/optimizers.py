"""Optimizers: AdamW (f32 moments, small/medium archs) and Adafactor
(factored second moment, β1=0 — the only thing that fits 0.5T-param arctic
on one v5e pod). Both keep state sharded exactly like the parameters
(FSDP/ZeRO-style: the param tree is already fully sharded over
(pod, data) × model, so optimizer state inherits that).

All update math runs in f32 regardless of param dtype (bf16 params get an
f32 master step applied then cast back — stochastic-rounding-free variant,
documented).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def warmup_cosine(base_lr: float, warmup: int, total: int,
                  floor: float = 0.1) -> Callable[[Array], Array]:
    def schedule(step):
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1),
                        0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * jnp.where(step < warmup, warm, cos)
    return schedule


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                      for x in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
def adamw_init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.copy, zeros),
            "count": jnp.zeros((), jnp.int32)}


def adamw_update(grads, state, params, lr, *, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1):
    count = state["count"] + 1
    cf = count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** cf)
        vh = v / (1 - b2 ** cf)
        step = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(
            jnp.float32)
        return m, v, (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    m2, v2, p2 = _tree_map3(upd, grads, state["m"], state["v"], params)
    return p2, {"m": m2, "v": v2, "count": count}


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern 2018), β1=0, factored v for >=2D tensors.
# ---------------------------------------------------------------------------
def adafactor_init(params):
    def one(p):
        if p.ndim >= 2:
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                    jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {"slots": jax.tree.map(one, params,
                                  is_leaf=lambda x: isinstance(x, jax.Array)),
            "count": jnp.zeros((), jnp.int32)}


def adafactor_update(grads, state, params, lr, *, d=1e-3, eps=1e-30,
                     clip_thresh=1.0, weight_decay=0.0):
    count = state["count"] + 1
    cf = count.astype(jnp.float32)
    beta2 = 1.0 - cf ** -0.8

    def one(g, slot, p):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if p.ndim >= 2:
            vr = beta2 * slot["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * slot["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
            denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
            vhat = (vr[..., None] / denom[..., None]) * vc[..., None, :]
            u = g / jnp.sqrt(jnp.maximum(vhat, eps))
            new_slot = {"vr": vr, "vc": vc}
        else:
            v = beta2 * slot["v"] + (1 - beta2) * g2
            u = g / jnp.sqrt(jnp.maximum(v, eps))
            new_slot = {"v": v}
        rms_u = jnp.sqrt(jnp.mean(u * u) + eps)
        u = u / jnp.maximum(1.0, rms_u / clip_thresh)
        pf = p.astype(jnp.float32)
        step_size = jnp.maximum(d, lr)
        new_p = pf - step_size * u - lr * weight_decay * pf
        return new_slot, new_p.astype(p.dtype)

    is_slot = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
    flat_g, treedef = jax.tree.flatten(grads)
    flat_s = treedef.flatten_up_to(state["slots"])
    flat_p = jax.tree.leaves(params)
    out = [one(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
    slots = treedef.unflatten([o[0] for o in out])
    new_p = treedef.unflatten([o[1] for o in out])
    return new_p, {"slots": slots, "count": count}


def _tree_map3(fn, a, b, c, d):
    flat_a, treedef = jax.tree.flatten(a)
    flat_b = jax.tree.leaves(b)
    flat_c = jax.tree.leaves(c)
    flat_d = jax.tree.leaves(d)
    out = [fn(x, y, z, w) for x, y, z, w in
           zip(flat_a, flat_b, flat_c, flat_d)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]),
            treedef.unflatten([o[2] for o in out]))


# ---------------------------------------------------------------------------
# Front-end
# ---------------------------------------------------------------------------
def make_optimizer(kind: str, schedule, *, max_grad_norm: float = 1.0,
                   weight_decay: float = 0.1):
    """Returns (init_fn, update_fn(grads, state, params, step))."""
    if kind == "adamw":
        def update(grads, state, params, step):
            grads, gn = clip_by_global_norm(grads, max_grad_norm)
            p2, s2 = adamw_update(grads, state, params, schedule(step),
                                  weight_decay=weight_decay)
            return p2, s2, gn
        return adamw_init, update
    if kind == "adafactor":
        def update(grads, state, params, step):
            grads, gn = clip_by_global_norm(grads, max_grad_norm)
            p2, s2 = adafactor_update(grads, state, params, schedule(step),
                                      weight_decay=weight_decay)
            return p2, s2, gn
        return adafactor_init, update
    raise ValueError(kind)
