"""Differential conformance suite (DESIGN.md §4): every backend — the
python oracle, `am`, `rdma`, `rdma_fused`, the adaptive `auto`, and the
cache-fronted `auto_cached` (DESIGN.md §8) — must
produce bit-identical *visible* results (ok/found flags, values) for the
same randomized op sequences, before the adaptive chooser is allowed to
swap backends under traffic.

Semantic domain: inserts use values derived deterministically from the key
(val = f(key)), so duplicate-key inserts are idempotent and the RDMA
engine's insert-only semantics agree with the RPC handler's
insert-or-assign (the paper's §II-B expressivity asymmetry) on everything a
reader can observe. Edge cases that depend on slot-level occupancy
(full-table, full-ring, empty-pop) are checked backend-vs-backend, where
the probe/ticket semantics are identical by construction.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import adaptive as ad_mod
from repro.core import am as am_mod
from repro.core import hashtable as ht_mod
from repro.core import queue as q_mod
from repro.core.types import Promise

P = 4
VW = 1
HT_BACKENDS = ("am", "rdma", "rdma_fused", "auto", "auto_cached")
Q_BACKENDS = ("am", "rdma", "rdma_fused", "auto")


def _val_of(keys):
    """Deterministic value for a key (idempotent duplicate inserts)."""
    return ((keys * 31 + 7) & 0x7FFFFF)[..., None]


def _np_val_of(key):
    return (key * 31 + 7) & 0x7FFFFF


# ---------------------------------------------------------------------------
# Backend runners: execute one insert or find batch on a named backend.
# Each runner owns its table copy; `auto` cycles arms via round_robin so a
# multi-batch sequence crosses every arm boundary.
# ---------------------------------------------------------------------------
class HtRunner:
    def __init__(self, backend, nslots=64, max_probes=8, coalesce=False):
        self.backend = backend
        self.max_probes = max_probes
        self.coalesce = coalesce
        self.ht = ht_mod.make_hashtable(P, nslots, VW)
        self.eng = am_mod.AMEngine(P)
        ht_mod.build_am_handlers(self.ht, self.eng, max_probes=max_probes)
        if backend in ("auto", "auto_cached"):
            self.auto = ad_mod.AdaptiveEngine(P, am_engine=self.eng,
                                              policy="round_robin")
        if backend == "auto_cached":
            # hot-bucket cache (DESIGN.md §8) riding the same adaptive
            # engine: visible results must stay bit-identical while finds
            # are served from cache whenever entries are fresh
            from repro.core import cache as cache_mod
            self.auto.attach_cache(cache_mod.BucketCache(
                P, nslots, VW, capacity=256, max_probes=max_probes))

    def insert(self, keys, valid=None):
        vals = _val_of(keys)
        if self.backend == "am":
            self.ht, ok, _ = ht_mod.insert_rpc(self.ht, self.eng, keys,
                                               vals, valid=valid,
                                               coalesce=self.coalesce)
        elif self.backend in ("auto", "auto_cached"):
            self.ht, ok, _ = self.auto.ht_insert(
                self.ht, keys, vals, promise=Promise.CRW, valid=valid,
                max_probes=self.max_probes)
        else:
            self.ht, ok, _ = ht_mod.insert_rdma(
                self.ht, keys, vals, promise=Promise.CRW, valid=valid,
                max_probes=self.max_probes,
                fused=self.backend == "rdma_fused",
                coalesce=self.coalesce)
        return np.asarray(ok)

    def find(self, keys, promise=Promise.CR, valid=None):
        if self.backend == "am":
            found, vals = ht_mod.find_rpc(self.ht, self.eng, keys,
                                          valid=valid,
                                          coalesce=self.coalesce)
        elif self.backend in ("auto", "auto_cached"):
            self.ht, found, vals = self.auto.ht_find(
                self.ht, keys, promise=promise, valid=valid,
                max_probes=self.max_probes)
        else:
            self.ht, found, vals = ht_mod.find_rdma(
                self.ht, keys, promise=promise, valid=valid,
                max_probes=self.max_probes,
                fused=self.backend == "rdma_fused",
                coalesce=self.coalesce)
        return np.asarray(found), np.asarray(vals)


class HtOracle:
    """Plain python dict applied in the engine's (src_rank, slot)
    serialization order. Valid only while the table has headroom (probe
    failures are slot-level, which a dict cannot see)."""

    def __init__(self):
        self.d = {}

    def insert(self, keys, valid=None):
        k = np.asarray(keys)
        v = np.ones(k.shape, bool) if valid is None else np.asarray(valid)
        for key, ok in zip(k.ravel().tolist(), v.ravel().tolist()):
            if ok:
                self.d[key] = _np_val_of(key)
        return v

    def find(self, keys, valid=None):
        k = np.asarray(keys)
        v = np.ones(k.shape, bool) if valid is None else np.asarray(valid)
        found = np.zeros(k.shape, bool)
        vals = np.zeros(k.shape + (VW,), np.int32)
        it = np.nditer(k, flags=["multi_index"])
        for key in it:
            idx = it.multi_index
            if v[idx] and int(key) in self.d:
                found[idx] = True
                vals[idx] = self.d[int(key)]
        return found, vals


def _distinct_keys(rng, shape, used=None):
    used = set() if used is None else used
    out = np.empty(int(np.prod(shape)), np.int64)
    i = 0
    while i < out.size:
        k = int(rng.integers(1, 1 << 30))
        if k not in used:
            used.add(k)
            out[i] = k
            i += 1
    return jnp.asarray(out.reshape(shape), jnp.int32)


def _assert_all_agree(results, label):
    names = list(results)
    ref = results[names[0]]
    for name in names[1:]:
        np.testing.assert_array_equal(
            ref, results[name],
            err_msg=f"{label}: {names[0]} != {name}")


# ---------------------------------------------------------------------------
# Hash table
# ---------------------------------------------------------------------------
def test_ht_random_sequences_all_backends_agree():
    """Multi-batch insert/find sequences with distinct keys: ok flags, found
    flags and values are bit-identical across backends and match the dict
    oracle."""
    rng = np.random.default_rng(0)
    runners = {b: HtRunner(b, nslots=128) for b in HT_BACKENDS}
    oracle = HtOracle()
    used: set = set()
    inserted = []
    for step in range(4):
        keys = _distinct_keys(rng, (P, 6), used)
        inserted.append(keys)
        oks = {b: r.insert(keys) for b, r in runners.items()}
        oks["oracle"] = oracle.insert(keys)
        _assert_all_agree(oks, f"insert ok step {step}")
        # probe: half previously inserted keys, half fresh (missing) keys
        probe = jnp.concatenate(
            [inserted[rng.integers(0, len(inserted))][:, :3],
             _distinct_keys(rng, (P, 3), used)], axis=1)
        founds = {b: r.find(probe) for b, r in runners.items()}
        founds["oracle"] = oracle.find(probe)
        _assert_all_agree({b: f[0] for b, f in founds.items()},
                          f"found step {step}")
        _assert_all_agree({b: f[1] for b, f in founds.items()},
                          f"find vals step {step}")


def test_ht_duplicate_keys_within_batch_agree():
    """Same-batch duplicate keys (idempotent values): RDMA claims a second
    slot, RPC updates in place — visible results must not differ."""
    rng = np.random.default_rng(1)
    runners = {b: HtRunner(b, nslots=128) for b in HT_BACKENDS}
    oracle = HtOracle()
    base = _distinct_keys(rng, (P, 3))
    dup = jnp.concatenate([base, base[:, :2], jnp.roll(base[:, :1], 1, 0)],
                         axis=1)
    oks = {b: r.insert(dup) for b, r in runners.items()}
    oks["oracle"] = oracle.insert(dup)
    _assert_all_agree(oks, "duplicate insert ok")
    founds = {b: r.find(base) for b, r in runners.items()}
    founds["oracle"] = oracle.find(base)
    _assert_all_agree({b: f[0] for b, f in founds.items()}, "dup found")
    _assert_all_agree({b: f[1] for b, f in founds.items()}, "dup vals")


def test_ht_duplicate_keys_across_batches_agree():
    rng = np.random.default_rng(2)
    runners = {b: HtRunner(b, nslots=128) for b in HT_BACKENDS}
    keys = _distinct_keys(rng, (P, 4))
    for _ in range(3):  # re-insert the same keys three times
        oks = {b: r.insert(keys) for b, r in runners.items()}
        _assert_all_agree(oks, "re-insert ok")
    founds = {b: r.find(keys) for b, r in runners.items()}
    _assert_all_agree({b: f[0] for b, f in founds.items()}, "re-found")
    _assert_all_agree({b: f[1] for b, f in founds.items()}, "re-vals")


def _keys_per_owner(rng, per_owner, used):
    """(P, per_owner) distinct keys, row p all owned by rank p (rejection
    sampled against the engine's hash placement)."""
    from repro.core.hashtable import hash_mix
    out = [[] for _ in range(P)]
    while any(len(row) < per_owner for row in out):
        k = int(rng.integers(1, 1 << 30))
        owner = int(np.asarray(hash_mix(jnp.int32(k)) % np.uint32(P)))
        if k not in used and len(out[owner]) < per_owner:
            used.add(k)
            out[owner].append(k)
    return jnp.asarray(out, jnp.int32)


def test_ht_full_table_fill_and_overflow_agree():
    """Saturate a tiny table (max_probes == nslots, exactly nslots keys per
    owner: every op can reach every slot, so the fill succeeds everywhere
    and deterministically), then overflow it: with zero free slots, every
    backend must fail every further insert identically, and every fill key
    stays findable with identical values.

    (Partial-fill probe-exhaustion races are deliberately out of the
    conformance domain: WHICH op wins a nearly-full region legitimately
    differs between the phase-wise RDMA engine and the op-sequential AM
    handler — both are linearizable, but not bit-identical.)"""
    rng = np.random.default_rng(3)
    nslots = 4
    runners = {b: HtRunner(b, nslots=nslots, max_probes=nslots)
               for b in HT_BACKENDS}
    used: set = set()
    fill = _keys_per_owner(rng, nslots, used)
    oks = {b: r.insert(fill) for b, r in runners.items()}
    _assert_all_agree(oks, "fill insert ok")
    assert next(iter(oks.values())).all()  # table now completely full
    over = _distinct_keys(rng, (P, 3), used)
    oks = {b: r.insert(over) for b, r in runners.items()}
    _assert_all_agree(oks, "overflow insert ok")
    assert not next(iter(oks.values())).any()
    probe = jnp.concatenate([fill, over], axis=1)
    founds = {b: r.find(probe) for b, r in runners.items()}
    _assert_all_agree({b: f[0] for b, f in founds.items()}, "overflow found")
    _assert_all_agree({b: f[1] for b, f in founds.items()}, "overflow vals")
    ref = next(iter(founds.values()))[0]
    np.testing.assert_array_equal(ref[:, :nslots], True)
    np.testing.assert_array_equal(ref[:, nslots:], False)


def test_ht_missing_keys_and_valid_mask_agree():
    rng = np.random.default_rng(4)
    runners = {b: HtRunner(b, nslots=64) for b in HT_BACKENDS}
    used: set = set()
    keys = _distinct_keys(rng, (P, 5), used)
    valid = jnp.asarray(rng.integers(0, 2, (P, 5)).astype(bool))
    for b, r in runners.items():
        r.insert(keys, valid=valid)
    probe = jnp.concatenate([keys, _distinct_keys(rng, (P, 3), used)],
                            axis=1)
    founds = {b: r.find(probe) for b, r in runners.items()}
    _assert_all_agree({b: f[0] for b, f in founds.items()}, "masked found")
    _assert_all_agree({b: f[1] for b, f in founds.items()}, "masked vals")
    # only ops valid at insert time are findable
    ref = next(iter(founds.values()))[0]
    np.testing.assert_array_equal(ref[:, :5], np.asarray(valid))


def test_ht_crw_locked_find_agrees_with_cr():
    """The C_RW read-locked find path returns the same visible results as
    C_R on a quiescent table, on every RDMA engine and vs the oracle."""
    rng = np.random.default_rng(5)
    runners = {b: HtRunner(b, nslots=64) for b in ("rdma", "rdma_fused",
                                                   "auto")}
    oracle = HtOracle()
    keys = _distinct_keys(rng, (P, 6))
    for r in runners.values():
        r.insert(keys)
    oracle.insert(keys)
    founds = {b: r.find(keys, promise=Promise.CRW)
              for b, r in runners.items()}
    founds["oracle"] = oracle.find(keys)
    _assert_all_agree({b: f[0] for b, f in founds.items()}, "crw found")
    _assert_all_agree({b: f[1] for b, f in founds.items()}, "crw vals")


# ---------------------------------------------------------------------------
# Queue
# ---------------------------------------------------------------------------
class QRunner:
    def __init__(self, backend, capacity=64):
        self.backend = backend
        self.q = q_mod.make_queue(P, host=1, capacity=capacity, val_words=VW)
        self.eng = am_mod.AMEngine(P)
        q_mod.build_am_handlers(self.q, self.eng)
        if backend == "auto":
            self.auto = ad_mod.AdaptiveEngine(P, am_engine=self.eng,
                                              policy="round_robin")

    def push(self, vals, valid=None):
        if self.backend == "am":
            self.q, ok = q_mod.push_rpc(self.q, self.eng, vals, valid=valid)
        elif self.backend == "auto":
            self.q, ok = self.auto.q_push(self.q, vals, promise=Promise.CRW,
                                          valid=valid)
        else:
            self.q, ok = q_mod.push_rdma(
                self.q, vals, promise=Promise.CRW, valid=valid,
                planned=self.backend == "rdma_fused")
        return np.asarray(ok)

    def pop(self, n):
        if self.backend == "am":
            self.q, got, vals = q_mod.pop_rpc(self.q, self.eng, n)
        elif self.backend == "auto":
            self.q, got, vals = self.auto.q_pop(self.q, n,
                                                promise=Promise.CRW)
        else:
            self.q, got, vals = q_mod.pop_rdma(
                self.q, n, promise=Promise.CRW,
                planned=self.backend == "rdma_fused")
        return np.asarray(got), np.asarray(vals)


class QOracle:
    """Bounded FIFO fed in the engine's (src_rank, slot) order."""

    def __init__(self, capacity):
        self.fifo: list = []
        self.capacity = capacity

    def push(self, vals, valid=None):
        v = np.asarray(vals)
        ok_in = (np.ones(v.shape[:2], bool) if valid is None
                 else np.asarray(valid))
        ok = np.zeros(v.shape[:2], bool)
        for p in range(v.shape[0]):
            for i in range(v.shape[1]):
                if ok_in[p, i] and len(self.fifo) < self.capacity:
                    self.fifo.append(v[p, i].copy())
                    ok[p, i] = True
        return ok

    def pop(self, n):
        got = np.zeros((P, n), bool)
        vals = np.zeros((P, n, VW), np.int32)
        for p in range(P):
            for i in range(n):
                if self.fifo:
                    vals[p, i] = self.fifo.pop(0)
                    got[p, i] = True
        return got, vals


def _batch_vals(rng, n):
    return jnp.asarray(rng.integers(1, 1 << 20, (P, n, VW)), jnp.int32)


def test_queue_push_pop_sequences_agree():
    """Interleaved push/pop batches: got flags and popped values are
    bit-identical across backends and match the FIFO oracle (the owner
    services both engines' batches in the same (src, slot) order)."""
    rng = np.random.default_rng(10)
    runners = {b: QRunner(b, capacity=512) for b in Q_BACKENDS}
    oracle = QOracle(512)
    for step in range(4):
        vals = _batch_vals(rng, 5)
        oks = {b: r.push(vals) for b, r in runners.items()}
        oks["oracle"] = oracle.push(vals)
        _assert_all_agree(oks, f"push ok step {step}")
        pops = {b: r.pop(3) for b, r in runners.items()}
        pops["oracle"] = oracle.pop(3)
        _assert_all_agree({b: g for b, (g, _) in pops.items()},
                          f"pop got step {step}")
        _assert_all_agree({b: v for b, (_, v) in pops.items()},
                          f"pop vals step {step}")


def test_queue_empty_pop_agree():
    runners = {b: QRunner(b) for b in Q_BACKENDS}
    for b, r in runners.items():
        got, vals = r.pop(4)
        assert not got.any(), b
        assert (vals == 0).all(), b
    # pop-after-drain: push 2, pop 8, pop again
    rng = np.random.default_rng(11)
    vals = _batch_vals(rng, 1)  # P pushes total
    for r in runners.values():
        r.push(vals)
    pops = {b: r.pop(8) for b, r in runners.items()}
    _assert_all_agree({b: g for b, (g, _) in pops.items()}, "drain got")
    _assert_all_agree({b: v for b, (_, v) in pops.items()}, "drain vals")
    again = {b: r.pop(2) for b, r in runners.items()}
    for b, (g, _) in again.items():
        assert not g.any(), b


def test_queue_full_ring_overflow_agree():
    """Pushes beyond ring capacity fail the same ops on every backend and
    the surviving FIFO contents stay identical."""
    rng = np.random.default_rng(12)
    cap = 8
    runners = {b: QRunner(b, capacity=cap) for b in Q_BACKENDS}
    oracle = QOracle(cap)
    vals = _batch_vals(rng, 4)  # P*4 = 16 pushes into 8 slots
    oks = {b: r.push(vals) for b, r in runners.items()}
    oks["oracle"] = oracle.push(vals)
    _assert_all_agree(oks, "overflow push ok")
    assert int(next(iter(oks.values())).sum()) == cap
    pops = {b: r.pop(4) for b, r in runners.items()}
    pops["oracle"] = oracle.pop(4)
    _assert_all_agree({b: g for b, (g, _) in pops.items()}, "overflow got")
    _assert_all_agree({b: v for b, (_, v) in pops.items()}, "overflow vals")


# ---------------------------------------------------------------------------
# Adaptive-specific conformance
# ---------------------------------------------------------------------------
def test_auto_arm_switches_mid_sequence_are_invisible():
    """The round-robin auto runner crosses every arm boundary; its decision
    log must show all arms were actually exercised, and (asserted above)
    results never differ. This pins that conformance covered the chooser,
    not a degenerate single-arm run."""
    rng = np.random.default_rng(13)
    r = HtRunner("auto", nslots=128)
    used: set = set()
    for _ in range(4):
        keys = _distinct_keys(rng, (P, 4), used)
        r.insert(keys)
        r.find(keys)
    arms = {d.arm for d in r.auto.log}
    assert arms == set(ad_mod.ARMS)
    assert all(d.batch_ops == P * 4 for d in r.auto.log)


def test_auto_cost_policy_conformant_and_logged():
    """The real (cost-driven) policy: results equal the rdma_fused
    reference on the same sequence, and every batch logged a Decision with
    scores for all arms."""
    rng = np.random.default_rng(14)
    auto = HtRunner("auto", nslots=128)
    auto.auto = ad_mod.AdaptiveEngine(P, am_engine=auto.eng, policy="cost",
                                      measure=True)
    ref = HtRunner("rdma_fused", nslots=128)
    used: set = set()
    for _ in range(3):
        keys = _distinct_keys(rng, (P, 4), used)
        ok_a, ok_r = auto.insert(keys), ref.insert(keys)
        np.testing.assert_array_equal(ok_a, ok_r)
        fa, fr = auto.find(keys), ref.find(keys)
        np.testing.assert_array_equal(fa[0], fr[0])
        np.testing.assert_array_equal(fa[1], fr[1])
    assert len(auto.auto.log) == 6
    for dec in auto.auto.log:
        assert dec.arm in ad_mod.ARMS
        assert set(dec.scores) == set(ad_mod.ARMS)
        assert dec.skew >= 1.0
    # measured EWMAs were fed back for the chosen arms
    assert auto.auto.ewma


def test_skew_statistic_matches_route_plan():
    """adaptive.batch_skew (host-side bincount) equals routing.plan_skew
    (derived from the exchanged plan occupancy) on random destination
    batches — the chooser sees the same statistic the engine would."""
    from repro.core import routing
    rng = np.random.default_rng(15)
    for _ in range(4):
        dst = jnp.asarray(rng.integers(0, P, (P, 9)), jnp.int32)
        plan = routing.make_plan(dst, cap=9)
        np.testing.assert_allclose(ad_mod.batch_skew(dst, P),
                                   float(routing.plan_skew(plan)), rtol=1e-6)
    hot = jnp.zeros((P, 9), jnp.int32)
    assert ad_mod.batch_skew(hot, P) == pytest.approx(P)
    plan = routing.make_plan(hot, cap=9)
    assert float(routing.plan_skew(plan)) == pytest.approx(P)


# ---------------------------------------------------------------------------
# Coalescing conformance (DESIGN.md §6): duplicate-heavy streams must be
# invisible — oracle == coalesced == uncoalesced on every arm.
# ---------------------------------------------------------------------------
def _zipf_dup_keys(rng, n_universe, shape, alpha=1.2):
    universe = rng.choice(np.arange(1, 1 << 20), size=n_universe,
                          replace=False)
    probs = 1.0 / np.arange(1, n_universe + 1) ** alpha
    probs /= probs.sum()
    return jnp.asarray(rng.choice(universe, size=shape, p=probs), jnp.int32)


def test_ht_zipfian_duplicate_stream_all_arms_coalesced_agree():
    """Zipfian (duplicate-heavy) insert/find streams: visible results are
    identical across {am, rdma, rdma_fused, auto} × {coalesce on, off} and
    match the dict oracle. max_probes covers the worst duplicate group so
    probe exhaustion stays out of the domain (DESIGN.md §4)."""
    rng = np.random.default_rng(20)
    runners = {}
    for b in HT_BACKENDS:
        runners[b] = HtRunner(b, nslots=256, max_probes=64)
        if b != "auto":  # auto coalesces by itself when dedup < 1
            runners[b + "+co"] = HtRunner(b, nslots=256, max_probes=64,
                                          coalesce=True)
    oracle = HtOracle()
    for step in range(3):
        keys = _zipf_dup_keys(rng, 12, (P, 8))
        oks = {b: r.insert(keys) for b, r in runners.items()}
        oks["oracle"] = oracle.insert(keys)
        _assert_all_agree(oks, f"zipf insert ok step {step}")
        probe = _zipf_dup_keys(rng, 12, (P, 8))
        founds = {b: r.find(probe) for b, r in runners.items()}
        founds["oracle"] = oracle.find(probe)
        _assert_all_agree({b: f[0] for b, f in founds.items()},
                          f"zipf found step {step}")
        _assert_all_agree({b: f[1] for b, f in founds.items()},
                          f"zipf vals step {step}")


def test_ht_dup_key_find_coalesced_single_probe():
    """A find batch that repeats one hot key everywhere ships ONE request
    row per origin (checked via the coalescing structure) and still
    returns every duplicate its record."""
    from repro.core import routing
    rng = np.random.default_rng(21)
    runners = {b: HtRunner(b, nslots=128, max_probes=16)
               for b in HT_BACKENDS}
    co_runners = {b + "+co": HtRunner(b, nslots=128, max_probes=16,
                                      coalesce=True)
                  for b in HT_BACKENDS if b != "auto"}
    runners.update(co_runners)
    base = _distinct_keys(rng, (P, 4))
    for r in runners.values():
        r.insert(base)
    hot = jnp.broadcast_to(base[:1, :1], (P, 8)).astype(jnp.int32)
    founds = {b: r.find(hot) for b, r in runners.items()}
    _assert_all_agree({b: f[0] for b, f in founds.items()}, "hot found")
    _assert_all_agree({b: f[1] for b, f in founds.items()}, "hot vals")
    assert next(iter(founds.values()))[0].all()
    dst = jnp.zeros((P, 8), jnp.int32)
    co = routing.coalesce(dst, jnp.zeros((P, 8), jnp.int32),
                          match=hot[..., None])
    np.testing.assert_array_equal(np.asarray(co.rows_out), np.ones(P))


def test_window_repeated_cas_fao_one_slot_coalesced_bit_exact():
    """Repeated CAS / FAO hammering ONE slot (the Fig. 3 single-variable
    pathology): the coalesced engine returns bit-identical fetched values
    and final state, including the chained CAS outcomes, matching the
    sequential kernel oracle."""
    from repro.core import window as win_mod
    from repro.kernels import ref
    rng = np.random.default_rng(22)
    for trial in range(3):
        win = win_mod.make_window(P, 8)
        dst = jnp.asarray(rng.integers(0, P, (P, 10)), jnp.int32)
        off = jnp.zeros((P, 10), jnp.int32)
        operand = jnp.asarray(rng.integers(-3, 4, (P, 10)), jnp.int32)
        o1, w1 = win_mod.rdma_fao(win, dst, off, operand,
                                  win_mod.AmoKind.FAA)
        o2, w2 = win_mod.rdma_fao(win, dst, off, operand,
                                  win_mod.AmoKind.FAA, coalesce=True)
        np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
        np.testing.assert_array_equal(np.asarray(w1.data),
                                      np.asarray(w2.data))
        # chained CAS 0->1 on one slot: exactly one winner, identical set
        c1, v1 = win_mod.rdma_cas(win, dst, off, 0, trial + 1)
        c2, v2 = win_mod.rdma_cas(win, dst, off, 0, trial + 1,
                                  coalesce=True)
        np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
        np.testing.assert_array_equal(np.asarray(v1.data),
                                      np.asarray(v2.data))
        # and the owner-lane duplicate-run combining agrees with the
        # sequential oracle on the same traffic shape
        ops = np.zeros((12, 4), np.int32)
        ops[:, 1] = rng.integers(2, 7, 12)
        ops[:, 2] = rng.integers(-2, 3, 12)
        ops[:, 3] = rng.integers(-2, 3, 12)
        local = jnp.asarray(rng.integers(0, 9, (8,)), jnp.int32)
        mask = jnp.ones((12,), bool)
        old_a, loc_a = ref.amo_apply(local, jnp.asarray(ops), mask)
        old_b, loc_b = ref.amo_apply_combined(local, jnp.asarray(ops), mask)
        np.testing.assert_array_equal(np.asarray(old_a), np.asarray(old_b))
        np.testing.assert_array_equal(np.asarray(loc_a), np.asarray(loc_b))


def test_queue_coalesced_backends_agree():
    """Queue push/pop with coalesced ticket FAOs: bit-identical to every
    other backend and to the FIFO oracle."""
    rng = np.random.default_rng(23)

    class CoQRunner(QRunner):
        def push(self, vals, valid=None):
            self.q, ok = q_mod.push_rdma(self.q, vals, promise=Promise.CRW,
                                         valid=valid, coalesce=True)
            return np.asarray(ok)

        def pop(self, n):
            self.q, got, vals = q_mod.pop_rdma(self.q, n,
                                               promise=Promise.CRW,
                                               coalesce=True)
            return np.asarray(got), np.asarray(vals)

    runners = {b: QRunner(b, capacity=128) for b in Q_BACKENDS}
    runners["rdma+co"] = CoQRunner("rdma", capacity=128)
    oracle = QOracle(128)
    for step in range(3):
        vals = _batch_vals(rng, 4)
        oks = {b: r.push(vals) for b, r in runners.items()}
        oks["oracle"] = oracle.push(vals)
        _assert_all_agree(oks, f"co push ok step {step}")
        pops = {b: r.pop(3) for b, r in runners.items()}
        pops["oracle"] = oracle.pop(3)
        _assert_all_agree({b: g for b, (g, _) in pops.items()},
                          f"co pop got step {step}")
        _assert_all_agree({b: v for b, (_, v) in pops.items()},
                          f"co pop vals step {step}")


def test_auto_records_dedup_and_coalesces_duplicate_batches():
    """The adaptive chooser's third online signal: a duplicate-heavy batch
    records dedup < 1 in its Decision and runs the non-seed arms with
    coalescing on; a distinct-key batch records dedup == 1 and stays
    uncoalesced."""
    rng = np.random.default_rng(24)
    r = HtRunner("auto", nslots=256, max_probes=64)
    dup = _zipf_dup_keys(rng, 6, (P, 8))
    r.insert(dup)
    dec = r.auto.log[-1]
    assert dec.dedup < 1.0
    assert dec.coalesce == (dec.arm != "rdma")
    distinct = _distinct_keys(rng, (P, 8))
    r.insert(distinct)
    dec = r.auto.log[-1]
    assert dec.dedup == 1.0 and not dec.coalesce


# ---------------------------------------------------------------------------
# Scale-parameterized conformance (DESIGN.md §9): the plan / coalesce /
# cache machinery must stay bit-exact when the shard count grows past the
# P=4 default — the scaling benches run these shapes, so correctness at
# P=16/64 is load-bearing, not hypothetical.
# ---------------------------------------------------------------------------
def _distinct_keys_at(rng, p, n, used=None):
    used = set() if used is None else used
    out = np.empty(p * n, np.int64)
    i = 0
    while i < out.size:
        k = int(rng.integers(1, 1 << 30))
        if k not in used:
            used.add(k)
            out[i] = k
            i += 1
    return jnp.asarray(out.reshape(p, n), jnp.int32)


@pytest.mark.parametrize("scale_p", (16, 64))
def test_scale_parameterized_conformance(scale_p):
    """At P=16 and P=64: insert/find visible results are bit-identical
    across {am, rdma, rdma_fused} x {coalesce on, off} and match the dict
    oracle; the fused engine's slot occupancy is bit-identical with
    coalescing on and off for distinct-key traffic (coalescing must be an
    exact no-op there); and the cache-fronted find returns bit-exact
    results both on the fill pass and when serving from cache."""
    from repro.core import cache as cache_mod
    rng = np.random.default_rng(scale_p)
    n, nslots = 4, 64
    eng = am_mod.AMEngine(scale_p)
    ht_am = ht_mod.make_hashtable(scale_p, nslots, VW)
    ht_mod.build_am_handlers(ht_am, eng)
    tables = {"rdma": ht_mod.make_hashtable(scale_p, nslots, VW),
              "rdma_fused": ht_mod.make_hashtable(scale_p, nslots, VW),
              "rdma_fused+co": ht_mod.make_hashtable(scale_p, nslots, VW)}
    used: set = set()
    keys = _distinct_keys_at(rng, scale_p, n, used)
    vals = _val_of(keys)
    oks = {}
    ht_am, oks["am"], _ = ht_mod.insert_rpc(ht_am, eng, keys, vals)
    tables["rdma"], oks["rdma"], _ = ht_mod.insert_rdma(
        tables["rdma"], keys, vals, promise=Promise.CRW, fused=False)
    tables["rdma_fused"], oks["rdma_fused"], _ = ht_mod.insert_rdma(
        tables["rdma_fused"], keys, vals, promise=Promise.CRW, fused=True)
    tables["rdma_fused+co"], oks["rdma_fused+co"], _ = ht_mod.insert_rdma(
        tables["rdma_fused+co"], keys, vals, promise=Promise.CRW,
        fused=True, coalesce=True)
    oracle = {int(k): _np_val_of(int(k))
              for k in np.asarray(keys).ravel().tolist()}
    _assert_all_agree({b: np.asarray(ok) for b, ok in oks.items()},
                      f"P={scale_p} insert ok")
    assert np.asarray(oks["rdma"]).all()
    # occupancy bit-identical: distinct-key coalescing is an exact no-op
    np.testing.assert_array_equal(
        np.asarray(tables["rdma_fused"].win.data),
        np.asarray(tables["rdma_fused+co"].win.data),
        err_msg=f"P={scale_p}: coalescing changed fused slot occupancy")
    probe = jnp.concatenate(
        [keys[:, :2], _distinct_keys_at(rng, scale_p, 2, used)], axis=1)
    founds = {}
    founds["am"] = ht_mod.find_rpc(ht_am, eng, probe)
    for b in ("rdma", "rdma_fused"):
        _, f, v = ht_mod.find_rdma(tables[b], probe, fused=b != "rdma")
        founds[b] = (f, v)
    _, f, v = ht_mod.find_rdma(tables["rdma_fused"], probe, fused=True,
                               coalesce=True)
    founds["rdma_fused+co"] = (f, v)
    _assert_all_agree({b: np.asarray(f[0]) for b, f in founds.items()},
                      f"P={scale_p} found")
    _assert_all_agree({b: np.asarray(f[1]) for b, f in founds.items()},
                      f"P={scale_p} find vals")
    ref_found, ref_vals = founds["rdma_fused"]
    for idx, key in np.ndenumerate(np.asarray(probe)):
        want = oracle.get(int(key))
        assert bool(np.asarray(ref_found)[idx]) == (want is not None)
        if want is not None:
            assert int(np.asarray(ref_vals)[idx + (0,)]) == want
    # cache-fronted find: fill pass and hit-serving pass both bit-exact
    cache = cache_mod.BucketCache(scale_p, nslots, VW, capacity=1024,
                                  max_probes=8)
    _, cf, cv = ht_mod.find_rdma(tables["rdma_fused"], probe, fused=True,
                                 cache=cache)
    np.testing.assert_array_equal(np.asarray(cf), np.asarray(ref_found))
    np.testing.assert_array_equal(np.asarray(cv), np.asarray(ref_vals))
    cache.drain_fills()
    _, cf2, cv2 = ht_mod.find_rdma(tables["rdma_fused"], probe, fused=True,
                                   cache=cache)
    assert cache.counters["hits"] > 0, "second pass never hit the cache"
    np.testing.assert_array_equal(np.asarray(cf2), np.asarray(ref_found))
    np.testing.assert_array_equal(np.asarray(cv2), np.asarray(ref_vals))


@pytest.mark.parametrize("scale_p", (16, 64))
def test_scale_duplicate_stream_coalesced_agree(scale_p):
    """Zipfian duplicate-heavy streams at P=16/64: coalesced and
    uncoalesced fused arms and the AM arm return bit-identical visible
    results (the §6 invariant does not decay with shard count). The key
    universe scales with P so the worst duplicate group stays within
    max_probes — probe exhaustion is out of the conformance domain
    (DESIGN.md §4), at any scale."""
    rng = np.random.default_rng(100 + scale_p)
    nslots, max_probes = 512, 128
    universe = 4 * scale_p
    eng = am_mod.AMEngine(scale_p)
    ht_am = ht_mod.make_hashtable(scale_p, nslots, VW)
    ht_mod.build_am_handlers(ht_am, eng, max_probes=max_probes)
    ht_f = ht_mod.make_hashtable(scale_p, nslots, VW)
    ht_c = ht_mod.make_hashtable(scale_p, nslots, VW)
    keys = _zipf_dup_keys(rng, universe, (scale_p, 4))
    vals = _val_of(keys)
    ht_am, ok_a, _ = ht_mod.insert_rpc(ht_am, eng, keys, vals)
    ht_f, ok_f, _ = ht_mod.insert_rdma(ht_f, keys, vals,
                                       promise=Promise.CRW, fused=True,
                                       max_probes=max_probes)
    ht_c, ok_c, _ = ht_mod.insert_rdma(ht_c, keys, vals,
                                       promise=Promise.CRW, fused=True,
                                       coalesce=True,
                                       max_probes=max_probes)
    _assert_all_agree({"am": np.asarray(ok_a), "fused": np.asarray(ok_f),
                       "fused+co": np.asarray(ok_c)},
                      f"P={scale_p} zipf insert ok")
    probe = _zipf_dup_keys(rng, universe, (scale_p, 4))
    fa, va = ht_mod.find_rpc(ht_am, eng, probe)
    _, ff, vf = ht_mod.find_rdma(ht_f, probe, fused=True,
                                 max_probes=max_probes)
    _, fc, vc = ht_mod.find_rdma(ht_c, probe, fused=True, coalesce=True,
                                 max_probes=max_probes)
    _assert_all_agree({"am": np.asarray(fa), "fused": np.asarray(ff),
                       "fused+co": np.asarray(fc)},
                      f"P={scale_p} zipf found")
    _assert_all_agree({"am": np.asarray(va), "fused": np.asarray(vf),
                       "fused+co": np.asarray(vc)},
                      f"P={scale_p} zipf vals")


def test_auto_depth_decision_flips_with_workload_and_p():
    """The §9 chooser pin: Decision.depth is a real decision axis — the
    bare CR find (no owner-side share to hide) stays at depth 1 while the
    owner-heavy insert runs depth 2; the regressed depth 4 is never
    chosen; a measured depth regression recorded via observe_depth flips
    the choice back to 1; and with P-dependent wire terms the SAME CR
    find flips arm (rdma_fused -> am) and depth (1 -> 2) as P grows."""
    from repro.core import costmodel as cm
    from repro.core.costmodel import DSOp
    eng = am_mod.AMEngine(P)
    a = ad_mod.AdaptiveEngine(P, am_engine=eng)
    assert a.choose_depth(DSOp.HT_FIND, Promise.CR) == 1
    assert a.choose_depth(DSOp.HT_INSERT, Promise.CRW) == 2
    for op in (DSOp.HT_FIND, DSOp.HT_INSERT, DSOp.Q_PUSH, DSOp.Q_POP):
        assert a.choose_depth(op, Promise.CRW) in (1, 2)  # never 4
    # fifth online signal: an observed depth-2 regression wins over the
    # model prior
    a.observe_depth(DSOp.HT_INSERT, 1, 5.0)
    a.observe_depth(DSOp.HT_INSERT, 2, 9.0)
    assert a.choose_depth(DSOp.HT_INSERT, Promise.CRW) == 1
    # P-flip: same op + promise, arms re-ranked by the P-scaled wire terms
    cal = cm.calibrate({"W": 1.0, "R": 1.8, "A_cas": 1.6, "A_fao": 1.6,
                        "am_rt": 2.8, "handler": 0.1, "amo_apply": 0.2,
                        "exch_per_rank": 0.025, "fanout_per_rank": 0.001},
                       base=cm.TPU_V5E_ICI)
    small = ad_mod.AdaptiveEngine(8, am_engine=eng, params=cal)
    large = ad_mod.AdaptiveEngine(256, am_engine=eng, params=cal)
    assert small.peek_arm(DSOp.HT_FIND, Promise.CR) == "rdma_fused"
    assert small.choose_depth(DSOp.HT_FIND, Promise.CR) == 1
    assert large.peek_arm(DSOp.HT_FIND, Promise.CR) == "am"
    assert large.choose_depth(DSOp.HT_FIND, Promise.CR) == 2


def test_auto_depth_through_pipeline_records_decision_depth():
    """End-to-end §9: an auto-depth pipeline retargets its window count
    per submit and the stage-time Decision records the chosen depth —
    depth 2 for the insert, depth 1 for the bare CR find."""
    from repro.core import pipeline as pl_mod
    from repro.core.costmodel import DSOp
    rng = np.random.default_rng(30)
    eng = am_mod.AMEngine(P)
    a = ad_mod.AdaptiveEngine(P, am_engine=eng)
    ht0 = ht_mod.make_hashtable(P, 128, VW)
    ht_mod.build_am_handlers(ht0, eng)
    pipe = pl_mod.Pipeline(ht0, depth=2, am_engine=eng, auto_depth=True)
    keys = _distinct_keys(rng, (P, 4))
    h1 = ht_mod.insert_async(pipe, keys, _val_of(keys), adaptive=a)
    h2 = ht_mod.find_async(pipe, keys, promise=Promise.CR, adaptive=a)
    pipe.flush()
    h1.result(), h2.result()
    by_op = {d.op: d.depth for d in a.log}
    assert by_op[DSOp.HT_INSERT] == 2
    assert by_op[DSOp.HT_FIND] == 1


def test_hypothesis_ht_conformance():
    """Hypothesis-driven randomized sequences (skipped when hypothesis is
    not installed, matching tests/test_properties.py)."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(st.lists(st.integers(1, 1 << 20), min_size=P * 4,
                        max_size=P * 4, unique=True),
               st.integers(0, 3))
    @hyp.settings(max_examples=10, deadline=None)
    def inner(key_list, nbatches_probe):
        keys = jnp.asarray(np.array(key_list).reshape(P, 4), jnp.int32)
        runners = {b: HtRunner(b, nslots=64) for b in HT_BACKENDS}
        oks = {b: r.insert(keys) for b, r in runners.items()}
        _assert_all_agree(oks, "hyp insert")
        founds = {b: r.find(keys) for b, r in runners.items()}
        _assert_all_agree({b: f[0] for b, f in founds.items()}, "hyp found")
        _assert_all_agree({b: f[1] for b, f in founds.items()}, "hyp vals")

    inner()


def test_hypothesis_queue_conformance():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(st.lists(st.integers(1, 1 << 20), min_size=P * 3,
                        max_size=P * 3))
    @hyp.settings(max_examples=10, deadline=None)
    def inner(val_list):
        vals = jnp.asarray(np.array(val_list).reshape(P, 3, VW), jnp.int32)
        runners = {b: QRunner(b, capacity=32) for b in Q_BACKENDS}
        oracle = QOracle(32)
        oks = {b: r.push(vals) for b, r in runners.items()}
        oks["oracle"] = oracle.push(vals)
        _assert_all_agree(oks, "hyp push")
        pops = {b: r.pop(4) for b, r in runners.items()}
        pops["oracle"] = oracle.pop(4)
        _assert_all_agree({b: g for b, (g, _) in pops.items()}, "hyp got")
        _assert_all_agree({b: v for b, (_, v) in pops.items()}, "hyp vals")

    inner()
