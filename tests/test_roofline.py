"""Roofline toolchain: the trip-count-aware HLO analyzer must (a) beat
XLA's body-once cost_analysis on scanned workloads and (b) account every
collective with the ring-model byte formulas."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_stats


def _cost(compiled) -> dict:
    """jax 0.4.x cost_analysis() returns a one-element list of dicts."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def test_xla_cost_analysis_counts_loop_body_once():
    """The documented deficiency that motivates hlo_stats."""
    def f_scan(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    def f_once(x):
        return x @ x

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c_scan = _cost(jax.jit(f_scan).lower(x).compile())
    c_once = _cost(jax.jit(f_once).lower(x).compile())
    assert c_scan.get("flops") == pytest.approx(c_once.get("flops"))


def test_hlo_stats_trip_count_flops():
    def f_scan(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    compiled = jax.jit(f_scan).lower(x).compile()
    st = hlo_stats.analyze(compiled.as_text(), world=1)
    assert st["flops"] == pytest.approx(2 * 128**3 * 10, rel=0.01)


def test_hlo_stats_nested_scan():
    def f(x):
        def inner(c, _):
            return c @ c, None

        def outer(c, _):
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None

        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    compiled = jax.jit(f).lower(x).compile()
    st = hlo_stats.analyze(compiled.as_text(), world=1)
    assert st["flops"] == pytest.approx(2 * 64**3 * 15, rel=0.01)


def test_hlo_stats_collective_accounting():
    crafted = """
HloModule test

ENTRY %main (p: f32[64,128]) -> f32[64,128] {
  %p = f32[64,128]{1,0} parameter(0)
  %ag = f32[64,128]{1,0} all-gather(%p), replica_groups=[16,16]<=[256], dimensions={0}
  %ar = f32[64,128]{1,0} all-reduce(%ag), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %cp = f32[64,128]{1,0} collective-permute(%ar), source_target_pairs={{0,1}}
}
"""
    st = hlo_stats.analyze(crafted, world=256)
    b = 64 * 128 * 4
    coll = st["collectives"]
    assert coll["all-gather"]["bytes"] == pytest.approx(b * 15 / 16)
    assert coll["all-reduce"]["bytes"] == pytest.approx(2 * b * 3 / 4)
    assert coll["collective-permute"]["bytes"] == pytest.approx(b)


def test_hlo_stats_sharded_collectives_end_to_end():
    """all_to_all via shard_map on 1 device degenerates; instead check a
    psum-lowered all-reduce is found and byte-counted."""
    from repro.launch.mesh import _axis_types_kwargs
    mesh = jax.make_mesh((1,), ("d",), **_axis_types_kwargs(1))
    if hasattr(jax, "shard_map"):
        shard_map = jax.shard_map
    else:  # jax 0.4.x
        from jax.experimental.shard_map import shard_map

    def f(x):
        return shard_map(lambda a: jax.lax.psum(a, "d"),
                         mesh=mesh, in_specs=jax.sharding.PartitionSpec(),
                         out_specs=jax.sharding.PartitionSpec())(x)

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    compiled = jax.jit(f).lower(x).compile()
    st = hlo_stats.analyze(compiled.as_text(), world=1)
    # single-device group -> zero wire bytes, but the op is still visible
    assert st["collective_bytes"] == 0.0


def test_shape_bytes_parser():
    assert hlo_stats.shape_bytes("f32[64,128]{1,0}") == 64 * 128 * 4
    assert hlo_stats.shape_bytes("bf16[8]{0}") == 16
    assert hlo_stats.shape_bytes("(f32[2,2]{1,0}, s32[4]{0})") == 32
    assert hlo_stats.shape_bytes("pred[10]{0}") == 10
    assert hlo_stats.shape_dims("f32[3,5,7]{2,1,0}") == [3, 5, 7]
