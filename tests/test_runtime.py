"""Fault-tolerance substrate: checkpoint/restart, async write-behind,
straggler policy, elastic re-scale, DS rehash."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hashtable as ht_mod
from repro.core.types import Promise
from repro.runtime import checkpoint as ck
from repro.runtime.elastic import rehash_table
from repro.runtime.straggler import StragglerMonitor


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": [jnp.ones((5,), jnp.int32), jnp.zeros((2, 2))]}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    ck.save_checkpoint(str(tmp_path), 7, t)
    assert ck.latest_step(str(tmp_path)) == 7
    t2 = ck.load_checkpoint(str(tmp_path), 7, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(t2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_crash_safety(tmp_path):
    """A .tmp (simulated mid-write crash) is never considered complete."""
    t = _tree()
    ck.save_checkpoint(str(tmp_path), 5, t)
    (tmp_path / "step_9.tmp").mkdir()
    (tmp_path / "step_9.tmp" / "leaf_0.npy").write_bytes(b"partial")
    assert ck.latest_step(str(tmp_path)) == 5
    ck.gc_checkpoints(str(tmp_path), keep=3)
    assert not (tmp_path / "step_9.tmp").exists()


def test_checkpoint_gc_keeps_latest(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        ck.save_checkpoint(str(tmp_path), s, t)
    ck.gc_checkpoints(str(tmp_path), keep=2)
    assert ck.latest_step(str(tmp_path)) == 5
    assert not (tmp_path / "step_1").exists()
    assert (tmp_path / "step_4").exists()


def test_async_checkpointer(tmp_path):
    t = _tree()
    acp = ck.AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (10, 20):
        acp.submit(s, t)
    acp.wait()
    acp.close()
    assert ck.latest_step(str(tmp_path)) == 20
    t2 = ck.load_checkpoint(str(tmp_path), 20, t)
    np.testing.assert_array_equal(np.asarray(t2["a"]), np.asarray(t["a"]))


def test_straggler_monitor_flags_slow_and_dead():
    mon = StragglerMonitor(n_hosts=4, threshold=2.0, patience=2,
                           dead_after=3)
    for step in range(6):
        for h in range(4):
            if h == 3 and step >= 2:
                continue                    # host 3 dies at step 2
            dur = 1.0 if h != 1 else 5.0    # host 1 is slow
            mon.heartbeat(h, step, dur)
        mon.classify()
    plan = mon.plan()
    assert plan is not None
    assert 3 in plan["evict"]
    assert 1 in plan["evict"]
    assert 0 in plan["survivors"] and 2 in plan["survivors"]


def test_straggler_healthy_cluster_no_plan():
    mon = StragglerMonitor(n_hosts=4)
    for step in range(5):
        for h in range(4):
            mon.heartbeat(h, step, 1.0 + 0.01 * h)
        mon.classify()
    assert mon.plan() is None


def test_elastic_rehash_preserves_contents():
    """Shrink the DS layer 4 -> 2 virtual ranks: every live key survives."""
    P = 4
    keys = jnp.asarray(np.random.default_rng(0).permutation(5000)[
        :P * 6].reshape(P, 6) + 1, jnp.int32)
    vals = jnp.stack([keys * 2], axis=-1)
    ht = ht_mod.make_hashtable(P, 64, 1)
    ht, ok, _ = ht_mod.insert_rdma(ht, keys, vals, promise=Promise.CW)
    assert bool(ok.all())
    ht2 = rehash_table(ht, new_nranks=2)
    assert ht2.nranks == 2
    k2 = keys.reshape(2, -1)
    ht2, found, got = ht_mod.find_rdma(ht2, k2, promise=Promise.CR,
                                       max_probes=16)
    assert bool(found.all())
    np.testing.assert_array_equal(np.asarray(got[..., 0]),
                                  np.asarray(k2 * 2))


def test_train_restart_bit_exact(tmp_path):
    """kill-and-restore: 6 straight steps == 3 steps + restart + 3 steps."""
    from repro.launch import train as train_mod

    base = ["--arch", "smollm-135m", "--reduced", "--batch", "4",
            "--seq", "32", "--lr", "1e-3", "--total-steps", "6"]
    l_straight = train_mod.main(base + ["--steps", "6"])
    ck1 = str(tmp_path / "ck")
    train_mod.main(base + ["--steps", "3", "--ckpt", ck1,
                           "--ckpt-every", "3"])
    l_resumed = train_mod.main(base + ["--steps", "6", "--ckpt", ck1,
                                       "--ckpt-every", "100"])
    np.testing.assert_allclose(l_straight[3:], l_resumed, rtol=1e-5)
