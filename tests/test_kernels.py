"""Per-kernel validation: Pallas (interpret=True) vs the pure-jnp oracles
in kernels/ref.py, swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.amo_apply import amo_apply
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_decode import flash_decode
from repro.kernels.hash_probe import hash_find, hash_insert
from repro.kernels.moe_dispatch import moe_dispatch
from repro.kernels.rg_lru import rg_lru_scan

RNG = np.random.default_rng(42)


@pytest.mark.parametrize("P,L,m", [(1, 32, 8), (3, 64, 20), (2, 128, 50)])
def test_amo_apply_sweep(P, L, m):
    local = jnp.asarray(RNG.integers(0, 100, (P, L)), jnp.int32)
    ops = np.zeros((P, m, 4), np.int32)
    ops[..., 0] = RNG.integers(0, L, (P, m))
    ops[..., 1] = RNG.integers(0, 7, (P, m))
    ops[..., 2] = RNG.integers(-5, 5, (P, m))
    ops[..., 3] = RNG.integers(-5, 5, (P, m))
    mask = jnp.asarray(RNG.random((P, m)) > 0.25)
    old_k, new_k = amo_apply(local, jnp.asarray(ops), mask)
    old_r, new_r = jax.vmap(ref.amo_apply)(local, jnp.asarray(ops), mask)
    np.testing.assert_array_equal(np.asarray(old_k), np.asarray(old_r))
    np.testing.assert_array_equal(np.asarray(new_k), np.asarray(new_r))


@pytest.mark.parametrize("P,L,m,V,G", [(1, 32, 8, 2, 3), (3, 64, 20, 1, 0),
                                       (2, 128, 50, 3, 4)])
def test_fused_apply_sweep(P, L, m, V, G):
    """Heterogeneous descriptor batches (primitive 0-6 + fused 7-9 opcodes,
    including out-of-range compound offsets) — Pallas vs the sequential
    oracle."""
    from repro.kernels.amo_apply import fused_apply
    local = jnp.asarray(RNG.integers(0, 100, (P, L)), jnp.int32)
    ops = np.zeros((P, m, 6 + V), np.int32)
    ops[..., 0] = RNG.integers(0, L, (P, m))
    ops[..., 1] = RNG.integers(0, 10, (P, m))
    ops[..., 2] = RNG.integers(-5, 5, (P, m))
    ops[..., 3] = RNG.integers(0, 10, (P, m))
    ops[..., 4] = RNG.integers(-2, L + 2, (P, m))
    ops[..., 5] = RNG.integers(-5, 5, (P, m))
    ops[..., 6:] = RNG.integers(0, 100, (P, m, V))
    mask = jnp.asarray(RNG.random((P, m)) > 0.25)
    rep_k, new_k = fused_apply(local, jnp.asarray(ops), mask,
                               reply_width=1 + G)
    rep_r, new_r = jax.vmap(lambda l, o, mm: ref.fused_apply(
        l, o, mm, reply_width=1 + G))(local, jnp.asarray(ops), mask)
    np.testing.assert_array_equal(np.asarray(rep_k), np.asarray(rep_r))
    np.testing.assert_array_equal(np.asarray(new_k), np.asarray(new_r))


@pytest.mark.parametrize("P,nslots,vw,m,bm",
                         [(2, 16, 1, 10, 4), (1, 64, 3, 33, 16),
                          (3, 32, 2, 17, 128)])
def test_hash_probe_sweep(P, nslots, vw, m, bm):
    rec_w = 2 + vw
    table = jnp.zeros((P, nslots * rec_w), jnp.int32)
    starts = jnp.asarray(RNG.integers(0, nslots, (P, m)), jnp.int32)
    keys = jnp.asarray(RNG.integers(1, 60, (P, m)), jnp.int32)
    vals = jnp.asarray(RNG.integers(0, 100, (P, m, vw)), jnp.int32)
    mask = jnp.asarray(RNG.random((P, m)) > 0.1)
    ok_k, pr_k, tab_k = hash_insert(table, starts, keys, vals, mask,
                                    nslots=nslots, rec_w=rec_w, max_probes=8)
    ok_r, pr_r, tab_r = jax.vmap(lambda t, s, k, v, mm: ref.hash_insert(
        t, s, k, v, mm, nslots, rec_w, 8))(table, starts, keys, vals, mask)
    np.testing.assert_array_equal(np.asarray(ok_k), np.asarray(ok_r))
    np.testing.assert_array_equal(np.asarray(pr_k), np.asarray(pr_r))
    np.testing.assert_array_equal(np.asarray(tab_k), np.asarray(tab_r))
    f_k, v_k = hash_find(tab_k, starts, keys, mask, nslots=nslots,
                         rec_w=rec_w, max_probes=8, block_m=bm)
    f_r, v_r = jax.vmap(lambda t, s, k, mm: ref.hash_find(
        t, s, k, mm, nslots, rec_w, 8))(tab_r, starts, keys, mask)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_r))
    np.testing.assert_array_equal(np.asarray(v_k), np.asarray(v_r))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,Hkv,S,d,causal,window",
                         [(2, 4, 2, 64, 32, True, 0),
                          (1, 8, 8, 48, 16, True, 24),
                          (2, 2, 1, 32, 64, False, 0)])
def test_flash_attention_sweep(B, H, Hkv, S, d, causal, window, dtype):
    q = jnp.asarray(RNG.normal(size=(B, H, S, d)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Hkv, S, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Hkv, S, d)), dtype)
    o_k = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=16, block_k=16)
    o_r = ref.mha(q, k, v, causal=causal, window=window)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_r, np.float32), atol=tol)


@pytest.mark.parametrize("B,H,Hkv,S,d,bk",
                         [(2, 8, 2, 128, 32, 32), (1, 4, 4, 96, 64, 256),
                          (3, 2, 1, 64, 16, 16)])
def test_flash_decode_sweep(B, H, Hkv, S, d, bk):
    q = jnp.asarray(RNG.normal(size=(B, H, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, Hkv, S, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, Hkv, S, d)), jnp.float32)
    length = jnp.asarray(RNG.integers(1, S + 1, (B,)), jnp.int32)
    o_k, m_k, l_k = flash_decode(q, k, v, length, block_k=bk)
    o_r, m_r, l_r = ref.decode_attention(q, k, v, length)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=2e-5)
    np.testing.assert_allclose(np.asarray(m_k), np.asarray(m_r), atol=1e-6)
    np.testing.assert_allclose(np.asarray(l_k), np.asarray(l_r), rtol=1e-5)


def test_flash_decode_shard_combine():
    """Sharded partials combine to the exact full result (the RPC-style
    distributed decode invariant)."""
    B, H, Hkv, S, d = 2, 4, 2, 128, 32
    q = jnp.asarray(RNG.normal(size=(B, H, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, Hkv, S, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, Hkv, S, d)), jnp.float32)
    length = jnp.asarray([100, 64], jnp.int32)
    o, m, l = ref.decode_attention(q, k, v, length)
    full = o / jnp.maximum(l, 1e-30)[..., None]
    shards = 4
    parts = []
    for i in range(shards):
        lo, hi = i * S // shards, (i + 1) * S // shards
        ln = jnp.clip(length - lo, 0, hi - lo)
        parts.append(ref.decode_attention(q, k[:, :, lo:hi], v[:, :, lo:hi],
                                          ln))
    comb = ref.combine_decode_stats(
        jnp.stack([p[0] for p in parts]), jnp.stack([p[1] for p in parts]),
        jnp.stack([p[2] for p in parts]))
    np.testing.assert_allclose(np.asarray(comb), np.asarray(full),
                               atol=2e-6)


@pytest.mark.parametrize("T,E,bt", [(100, 4, 32), (1000, 7, 128),
                                    (256, 64, 256), (64, 2, 64)])
def test_moe_dispatch_sweep(T, E, bt):
    ids = jnp.asarray(RNG.integers(0, E, (T,)), jnp.int32)
    c_k, p_k = moe_dispatch(ids, n_experts=E, block_t=bt)
    c_r, p_r = ref.moe_dispatch(ids, E)
    np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_r))
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))


@pytest.mark.parametrize("B,S,D,bs,bd",
                         [(2, 100, 200, 32, 64), (1, 64, 128, 256, 128),
                          (3, 33, 50, 8, 16)])
def test_rg_lru_sweep(B, S, D, bs, bd):
    a = jnp.asarray(RNG.uniform(0.7, 1.0, (B, S, D)), jnp.float32)
    b = jnp.asarray(RNG.normal(size=(B, S, D)), jnp.float32)
    h0 = jnp.asarray(RNG.normal(size=(B, D)), jnp.float32)
    h_k = rg_lru_scan(a, b, h0, block_s=bs, block_d=bd)
    h_r = ref.rg_lru_scan(a, b, h0)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r), atol=1e-5)


def test_kernel_lane_integration():
    """REPRO_USE_PALLAS routes the window AMO lane through the kernel and
    produces identical results to the XLA appliers."""
    import repro.core.window as window
    from repro.core.types import AmoKind
    from repro.kernels import ops as kops
    P = 3
    win_a = window.make_window(P, 16)
    win_b = window.make_window(P, 16)
    dst = jnp.asarray(RNG.integers(0, P, (P, 6)), jnp.int32)
    off = jnp.asarray(RNG.integers(0, 16, (P, 6)), jnp.int32)
    operand = jnp.asarray(RNG.integers(1, 5, (P, 6)), jnp.int32)
    old_a, win_a = window.rdma_fao(win_a, dst, off, operand, AmoKind.FAA)
    prev = kops._USE_PALLAS
    kops._USE_PALLAS = True
    try:
        old_b, win_b = window.rdma_fao(win_b, dst, off, operand,
                                       AmoKind.FAA)
    finally:
        kops._USE_PALLAS = prev
    np.testing.assert_array_equal(np.asarray(old_a), np.asarray(old_b))
    np.testing.assert_array_equal(np.asarray(win_a.data),
                                  np.asarray(win_b.data))


def test_fused_lane_integration():
    """The fused insert/find path produces identical tables and results on
    the XLA and Pallas owner lanes (REPRO_USE_PALLAS toggle)."""
    from repro.core import hashtable as ht_mod
    from repro.core.types import Promise
    from repro.kernels import ops as kops
    P = 3
    keys = jnp.asarray(RNG.permutation(2000)[:P * 6].reshape(P, 6) + 1,
                       jnp.int32)
    vals = jnp.stack([keys * 2, keys + 3], axis=-1)

    def run():
        ht = ht_mod.make_hashtable(P, 16, 2)
        ht, ok, pr = ht_mod.insert_rdma(ht, keys, vals, promise=Promise.CRW,
                                        fused=True)
        ht, f, v = ht_mod.find_rdma(ht, keys, promise=Promise.CRW,
                                    fused=True)
        return ht.win.data, ok, pr, f, v

    a = run()
    prev = kops._USE_PALLAS
    kops._USE_PALLAS = True
    try:
        b = run()
    finally:
        kops._USE_PALLAS = prev
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# Duplicate-run combining (DESIGN.md §6) at the owner lane
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("P,L,m,span", [(2, 32, 16, 2), (3, 64, 40, 4),
                                        (1, 16, 8, 1)])
def test_amo_combine_runs_bit_exact_all_lanes(P, L, m, span):
    """ops.amo_apply(combine_runs=True) == the plain serialized apply on
    duplicate-heavy op lists (offsets drawn from a tiny span so runs are
    long), across the ref, XLA, and Pallas lanes."""
    from repro.kernels import ops as kops
    local = jnp.asarray(RNG.integers(0, 100, (P, L)), jnp.int32)
    ops = np.zeros((P, m, 4), np.int32)
    ops[..., 0] = RNG.integers(0, span, (P, m))
    ops[..., 1] = RNG.integers(0, 7, (P, m))
    ops[..., 2] = RNG.integers(-5, 6, (P, m))
    ops[..., 3] = RNG.integers(-5, 6, (P, m))
    mask = jnp.asarray(RNG.random((P, m)) > 0.15)
    ops = jnp.asarray(ops)
    old_ref, loc_ref = kops.amo_apply(local, ops, mask, use_pallas=False)
    for use_pallas in (False, True):
        old_c, loc_c = kops.amo_apply(local, ops, mask,
                                      use_pallas=use_pallas,
                                      combine_runs=True)
        np.testing.assert_array_equal(np.asarray(old_ref),
                                      np.asarray(old_c))
        np.testing.assert_array_equal(np.asarray(loc_ref),
                                      np.asarray(loc_c))
    # the sequential-oracle composition agrees too
    for p in range(P):
        old_s, loc_s = ref.amo_apply_combined(local[p], ops[p], mask[p])
        np.testing.assert_array_equal(np.asarray(old_ref[p]),
                                      np.asarray(old_s))
        np.testing.assert_array_equal(np.asarray(loc_ref[p]),
                                      np.asarray(loc_s))


def test_combine_runs_actually_shortens_hot_lists():
    """Structure check: a single-variable FAA hammer combines to ONE
    surviving op per shard with the summed operand."""
    from repro.kernels.amo_apply import combine_runs
    m = 24
    ops = np.zeros((m, 4), np.int32)
    ops[:, 1] = 3  # OP_FAA
    ops[:, 2] = np.arange(1, m + 1)
    mask = jnp.ones((m,), bool)
    ops2, mask2, run_start, prefix = combine_runs(jnp.asarray(ops), mask)
    assert int(mask2.sum()) == 1
    assert int(ops2[0, 2]) == m * (m + 1) // 2
    np.testing.assert_array_equal(np.asarray(run_start), np.zeros(m))
    np.testing.assert_array_equal(np.asarray(prefix),
                                  np.arange(m) * (np.arange(m) + 1) // 2)
