"""Cost-model ordering regression (satellite of ISSUE 3): for both
parameter sets (the paper's Cori/Aries Table I and the derived TPU v5e ICI
constants) `predict` must rank implementations the way the paper's
Figs. 4–5 conclude, `calibrate` must round-trip measured component dicts
(fused descriptors included), and the skew/attentiveness signals must move
the ranking in the documented direction.
"""
import dataclasses

import pytest

from repro.core import costmodel as cm
from repro.core.types import Backend, OpStats, Promise

PARAMS = [cm.CORI_PHASE1, cm.TPU_V5E_ICI]
ATTENTIVE = OpStats(target_busy_us=0.0)


@pytest.mark.parametrize("params", PARAMS, ids=lambda p: p.name)
def test_fig5_hashtable_ordering(params):
    """Fig. 5 conclusions: the bare C_R find is the cheapest operation of
    all; the fully-atomic C_RW RDMA find (3 dependent atomic phases) is
    more expensive than one AM round trip; the composite C_RW RDMA insert
    loses to the AM insert while the C_W insert beats the C_RW insert."""
    find_cr = cm.predict(cm.DSOp.HT_FIND, Promise.CR, Backend.RDMA,
                         ATTENTIVE, params)
    find_am = cm.predict(cm.DSOp.HT_FIND, Promise.CRW, Backend.RPC,
                         ATTENTIVE, params)
    find_crw = cm.predict(cm.DSOp.HT_FIND, Promise.CRW, Backend.RDMA,
                          ATTENTIVE, params)
    assert find_cr < find_am < find_crw
    ins_am = cm.predict(cm.DSOp.HT_INSERT, Promise.CRW, Backend.RPC,
                        ATTENTIVE, params)
    ins_crw = cm.predict(cm.DSOp.HT_INSERT, Promise.CRW, Backend.RDMA,
                         ATTENTIVE, params)
    ins_cw = cm.predict(cm.DSOp.HT_INSERT, Promise.CW, Backend.RDMA,
                        ATTENTIVE, params)
    assert ins_am < ins_crw
    assert ins_cw < ins_crw


@pytest.mark.parametrize("params", PARAMS, ids=lambda p: p.name)
def test_fig4_queue_ordering(params):
    """Fig. 4 conclusions: C_L local push is essentially free; phasal C_W
    beats fully-atomic C_RW; the checksum queue removes the publish CAS and
    lands at the C_W cost; one AM round trip beats the composite C_RW
    RDMA push at an attentive target."""
    local = cm.predict(cm.DSOp.Q_PUSH, Promise.CL, Backend.RDMA,
                       ATTENTIVE, params)
    cw = cm.predict(cm.DSOp.Q_PUSH, Promise.CW, Backend.RDMA,
                    ATTENTIVE, params)
    crw = cm.predict(cm.DSOp.Q_PUSH, Promise.CRW, Backend.RDMA,
                     ATTENTIVE, params)
    csum = cm.predict_checksum_push(ATTENTIVE, params)
    am = cm.predict(cm.DSOp.Q_PUSH, Promise.CRW, Backend.RPC,
                    ATTENTIVE, params)
    assert local < cw <= crw
    assert csum == pytest.approx(cw)
    assert csum < crw
    assert am < crw


@pytest.mark.parametrize("params", PARAMS, ids=lambda p: p.name)
def test_attentiveness_flips_insert_winner(params):
    """The paper's punchline operationalized: at an attentive target the AM
    insert wins; once the target intersperses enough compute, the one-sided
    path takes over (choose_backend flips), and a progress thread restores
    the AM side at a constant factor."""
    assert cm.choose_backend(cm.DSOp.HT_INSERT, Promise.CRW,
                             ATTENTIVE, params) == Backend.RPC
    busy = OpStats(target_busy_us=1000.0)
    assert cm.choose_backend(cm.DSOp.HT_INSERT, Promise.CRW,
                             busy, params) == Backend.RDMA
    pt = OpStats(target_busy_us=1000.0, progress_thread=True)
    assert (cm.predict(cm.DSOp.HT_INSERT, Promise.CRW, Backend.RPC, pt,
                       params)
            < cm.predict(cm.DSOp.HT_INSERT, Promise.CRW, Backend.RPC, busy,
                         params))


@pytest.mark.parametrize("params", PARAMS, ids=lambda p: p.name)
def test_fused_engine_preserves_ordering_and_never_costs_more(params):
    for op, promise in ((cm.DSOp.HT_INSERT, Promise.CRW),
                        (cm.DSOp.HT_INSERT, Promise.CW),
                        (cm.DSOp.HT_FIND, Promise.CRW)):
        fused = cm.predict(op, promise, Backend.RDMA, ATTENTIVE, params,
                           fused=True)
        seed = cm.predict(op, promise, Backend.RDMA, ATTENTIVE, params,
                          fused=False)
        assert fused <= seed, (op, promise)
    # the C_R find ordering survives fusion of its competitors
    assert (cm.predict(cm.DSOp.HT_FIND, Promise.CR, Backend.RDMA,
                       ATTENTIVE, params)
            < cm.predict(cm.DSOp.HT_FIND, Promise.CRW, Backend.RDMA,
                         ATTENTIVE, params, fused=True))


def test_calibrate_round_trips_measured_components():
    """calibrate() must take a benchmarks/components.py-style measured dict
    — fused descriptors included — and report exactly those numbers back
    through the dataclass and the fused accessors."""
    measured = {"W": 1.5, "R": 2.5, "A_cas": 3.25, "A_fao": 3.5,
                "am_rt": 4.75, "handler": 0.125, "local": 0.0625,
                "amo_apply": 0.375, "A_cas_put": 3.75, "A_cas_put_pub": 4.0,
                "A_fao_get": 4.25}
    cal = cm.calibrate(measured)
    assert cal.name == "calibrated"
    for k, v in measured.items():
        assert getattr(cal, k) == v, k
    assert cal.fused_cas_put() == measured["A_cas_put"]
    assert cal.fused_cas_put_pub() == measured["A_cas_put_pub"]
    assert cal.fused_fao_get() == measured["A_fao_get"]
    # unknown keys are ignored, untouched fields keep the base values
    cal2 = cm.calibrate({"W": 9.0, "not_a_component": 1.0})
    assert cal2.W == 9.0 and cal2.R == cm.CORI_PHASE1.R
    assert cal2.pt_overhead == cm.CORI_PHASE1.pt_overhead


def test_calibrate_without_fused_numbers_derives_them_from_atomics():
    cal = cm.calibrate({"A_cas": 2.0, "A_fao": 2.25})
    assert cal.A_cas_put is None
    assert cal.fused_cas_put() == 2.0
    assert cal.fused_fao_get() == 2.25


def test_predictions_linear_in_calibrated_components():
    """predict() with calibrated params equals the Table II formula applied
    to the measured numbers — the calibration path cannot drift from the
    analytical model."""
    cal = cm.calibrate({"W": 2.0, "R": 3.0, "A_cas": 4.0, "A_fao": 5.0,
                        "am_rt": 6.0, "handler": 0.5, "amo_apply": 0.0})
    got = cm.predict(cm.DSOp.HT_INSERT, Promise.CRW, Backend.RDMA,
                     OpStats(expected_probes=2.0), cal)
    assert got == pytest.approx(2.0 * 4.0 + 2.0 + 5.0)   # 2×A_cas + W + A_fao
    got = cm.predict(cm.DSOp.HT_FIND, Promise.CRW, Backend.RDMA, None, cal)
    assert got == pytest.approx(5.0 + 3.0 + 5.0)         # A_fao + R + A_fao
    got = cm.predict(cm.DSOp.Q_PUSH, Promise.CRW, Backend.RDMA,
                     OpStats(contention=3.0), cal)
    assert got == pytest.approx(5.0 + 2.0 + 3.0 * 4.0)   # A_fao + W + 3×A_cas


def test_skew_raises_rdma_faster_than_rpc_and_flips_choice():
    """The adaptive layer's skew signal: on owner-lane hardware (amo_apply
    > 0) a skewed batch inflates the one-sided atomics by amo_apply×skew
    per phase while the AM side only scales its (much smaller) handler
    term; with a calibrated set where RDMA wins uniform batches, skew=P
    must flip the chooser to the AM arm."""
    p = cm.TPU_V5E_ICI
    uni = OpStats(skew=1.0)
    hot = OpStats(skew=8.0)
    d_rdma = (cm.predict(cm.DSOp.HT_INSERT, Promise.CRW, Backend.RDMA, hot,
                         p, fused=True)
              - cm.predict(cm.DSOp.HT_INSERT, Promise.CRW, Backend.RDMA,
                           uni, p, fused=True))
    d_rpc = (cm.predict(cm.DSOp.HT_INSERT, Promise.CRW, Backend.RPC, hot, p)
             - cm.predict(cm.DSOp.HT_INSERT, Promise.CRW, Backend.RPC, uni,
                          p))
    assert d_rdma > d_rpc > 0
    # calibrated host where the fused one-sided insert wins uniform batches
    cal = cm.calibrate({"W": 1.0, "R": 1.5, "A_cas": 1.8, "A_fao": 1.8,
                        "am_rt": 2.6, "handler": 0.1, "amo_apply": 0.3},
                       base=cm.TPU_V5E_ICI)
    assert cm.predict_arm(cm.DSOp.HT_INSERT, Promise.CW, "rdma_fused",
                          uni, cal) < cm.predict_arm(
        cm.DSOp.HT_INSERT, Promise.CW, "am", uni, cal)
    assert cm.predict_arm(cm.DSOp.HT_INSERT, Promise.CW, "rdma_fused",
                          hot, cal) > cm.predict_arm(
        cm.DSOp.HT_INSERT, Promise.CW, "am", hot, cal)


def test_predict_arm_covers_all_arms_and_matches_predict():
    s = OpStats(target_busy_us=4.0)
    for params in PARAMS:
        assert cm.predict_arm(cm.DSOp.Q_POP, Promise.CR, "rdma", s,
                              params) == cm.predict(
            cm.DSOp.Q_POP, Promise.CR, Backend.RDMA, s, params)
        assert cm.predict_arm(cm.DSOp.HT_FIND, Promise.CRW, "rdma_fused",
                              s, params) == cm.predict(
            cm.DSOp.HT_FIND, Promise.CRW, Backend.RDMA, s, params,
            fused=True)
        am = cm.predict_arm(cm.DSOp.HT_INSERT, Promise.CRW, "am", s, params)
        pt = cm.predict_arm(cm.DSOp.HT_INSERT, Promise.CRW, "am_pt", s,
                            params)
        assert am == cm.predict(
            cm.DSOp.HT_INSERT, Promise.CRW, Backend.RPC, s, params)
        assert pt == cm.predict(
            cm.DSOp.HT_INSERT, Promise.CRW, Backend.RPC,
            dataclasses.replace(s, progress_thread=True), params)
        assert am != pt  # busy target: the PT arm actually differs
    with pytest.raises(ValueError):
        cm.predict_arm(cm.DSOp.HT_FIND, Promise.CR, "nope")


# ---------------------------------------------------------------------------
# Coalescing pricing (DESIGN.md §6): the distinct-row factor
# ---------------------------------------------------------------------------
def test_coalesced_prediction_cheaper_under_duplicates():
    """With real duplicate traffic (dedup well below 1) the coalesced
    prediction undercuts the uncoalesced one for every RDMA formula, on
    both parameter sets; monotone: fewer distinct rows -> cheaper."""
    cases = [(cm.DSOp.HT_INSERT, Promise.CRW), (cm.DSOp.HT_INSERT,
                                                Promise.CW),
             (cm.DSOp.HT_FIND, Promise.CRW), (cm.DSOp.HT_FIND, Promise.CR)]
    for params in PARAMS:
        for op, promise in cases:
            prev = None
            for rho in (0.8, 0.5, 0.2, 0.05):
                s = OpStats(expected_probes=2.0, skew=4.0, dedup=rho)
                co = cm.predict(op, promise, Backend.RDMA, s, params,
                                fused=True, coalesce=True)
                unc = cm.predict(op, promise, Backend.RDMA, s, params,
                                 fused=True, coalesce=False)
                assert co < unc, (op, promise, rho, params.name)
                if prev is not None:
                    assert co <= prev
                prev = co


def test_predict_arm_prices_dedup_signal():
    """predict_arm: dedup < 1 turns the distinct-row factor on for the
    fused/AM arms and leaves the seed rdma arm untouched."""
    dup = OpStats(expected_probes=2.0, skew=4.0, dedup=0.25)
    uni = dataclasses.replace(dup, dedup=1.0)
    for params in PARAMS:
        for op, promise in ((cm.DSOp.HT_INSERT, Promise.CRW),
                            (cm.DSOp.HT_FIND, Promise.CR)):
            assert cm.predict_arm(op, promise, "rdma_fused", dup,
                                  params) < cm.predict_arm(
                op, promise, "rdma_fused", uni, params)
            assert cm.predict_arm(op, promise, "rdma", dup,
                                  params) == cm.predict_arm(
                op, promise, "rdma", uni, params)
            assert cm.predict_arm(op, promise, "am", dup,
                                  params) < cm.predict_arm(
                op, promise, "am", uni, params)


# ---------------------------------------------------------------------------
# P-dependence (DESIGN.md §9): exch_per_rank / fanout_per_rank make scale a
# model axis. Orderings pinned against the measured BENCH_scaling.json
# shapes: one-sided queue ops and probe-heavy inserts collapse toward AM at
# P=64/256 while the light CR find keeps the fused arm through P=64.
# ---------------------------------------------------------------------------
_SCALED = cm.calibrate(
    {"W": 1.0, "R": 1.8, "A_cas": 1.6, "A_fao": 1.6, "am_rt": 2.8,
     "handler": 0.1, "amo_apply": 0.2,
     "exch_per_rank": 0.025, "fanout_per_rank": 0.001},
    base=cm.TPU_V5E_ICI)


def test_p_scaling_zero_slope_is_bit_identical():
    """Both slopes default to 0.0: every prediction at any nranks equals
    the P-blind model exactly, and nranks=0 (unknown) never scales even
    with slopes set — fixed-P repos see no numeric drift from this axis."""
    for params in PARAMS:
        for op, promise, arm in ((cm.DSOp.HT_INSERT, Promise.CRW,
                                  "rdma_fused"),
                                 (cm.DSOp.HT_FIND, Promise.CR, "rdma"),
                                 (cm.DSOp.Q_PUSH, Promise.CRW, "am")):
            blind = cm.predict_arm(op, promise, arm, OpStats(nranks=0),
                                   params)
            for p in (8, 64, 256):
                assert cm.predict_arm(op, promise, arm,
                                      OpStats(nranks=p), params) == blind
    assert cm.predict_arm(cm.DSOp.HT_FIND, Promise.CR, "rdma_fused",
                          OpStats(nranks=0), _SCALED) == cm.predict_arm(
        cm.DSOp.HT_FIND, Promise.CR, "rdma_fused", OpStats(nranks=1),
        _SCALED)


def test_p_scaling_monotone_in_ranks():
    """With positive slopes every arm's cost is non-decreasing in P, and
    strictly increasing for the arms the slope actually touches."""
    for arm in cm.ARMS:
        prev = None
        for p in (1, 8, 64, 256):
            got = cm.predict_arm(cm.DSOp.HT_INSERT, Promise.CRW, arm,
                                 OpStats(nranks=p), _SCALED)
            if prev is not None:
                assert got > prev, (arm, p)
            prev = got


def test_scaling_insert_arm_flips_to_am_at_p64():
    """The measured weak-scaling insert ordering: the fused one-sided
    insert wins at P=8 but loses to the aggregated AM insert at P=64 and
    P=256 (its occupancy exchange and atomic lanes widen with every
    owner; the AM round trip amortizes the fan-out)."""
    def ins(arm, p):
        return cm.predict_arm(cm.DSOp.HT_INSERT, Promise.CRW, arm,
                              OpStats(nranks=p), _SCALED)
    assert ins("rdma_fused", 8) < ins("am", 8)
    assert ins("am", 64) < ins("rdma_fused", 64) < ins("rdma", 64)
    assert ins("am", 256) < ins("rdma_fused", 256) < ins("rdma", 256)


def test_scaling_find_keeps_fused_push_goes_am():
    """The other two measured shapes: the bare CR find's single wire term
    grows too slowly to flip before P=64 (rdma_fused stays the fastest
    find arm, as in BENCH_scaling.json), while the hosted queue push is
    AM-won at EVERY P with a margin that widens as P grows — the paper's
    single-host pathology, now priced by the model."""
    gentle = cm.calibrate({"exch_per_rank": 0.005,
                           "fanout_per_rank": 0.002},
                          base=cm.TPU_V5E_ICI)

    def arm_us(op, promise, arm, p, params):
        return cm.predict_arm(op, promise, arm, OpStats(nranks=p), params)

    for p in (8, 64):
        assert (arm_us(cm.DSOp.HT_FIND, Promise.CR, "rdma_fused", p, gentle)
                < arm_us(cm.DSOp.HT_FIND, Promise.CR, "am", p, gentle)), p
    prev_margin = 0.0
    for p in (8, 64, 256):
        fused = arm_us(cm.DSOp.Q_PUSH, Promise.CRW, "rdma_fused", p, gentle)
        am = arm_us(cm.DSOp.Q_PUSH, Promise.CRW, "am", p, gentle)
        assert am < fused, p
        assert fused / am > prev_margin, p
        prev_margin = fused / am


def test_p_scaling_calibrate_roundtrips_slopes():
    assert _SCALED.exch_per_rank == 0.025
    assert _SCALED.fanout_per_rank == 0.001
    # predict_arm applied twice at the same stats is deterministic (the
    # internal scaling is idempotent, not compounding)
    s = OpStats(nranks=64)
    a = cm.predict_arm(cm.DSOp.HT_FIND, Promise.CRW, "rdma_fused", s,
                       _SCALED)
    b = cm.predict_arm(cm.DSOp.HT_FIND, Promise.CRW, "rdma_fused", s,
                       _SCALED)
    assert a == b


def test_choose_depth_model_pins():
    """The §9 auto-depth prior: the bare CR find (no owner-side share)
    stays at depth 1; owner-heavy ops take depth 2; the regressed depth 4
    is NEVER chosen from the default ladder; max_depth clamps the
    answer."""
    for params in PARAMS:
        assert cm.choose_depth(cm.DSOp.HT_FIND, Promise.CR, "rdma_fused",
                               OpStats(), params) == 1
        for op, promise, arm in ((cm.DSOp.HT_INSERT, Promise.CRW, "am"),
                                 (cm.DSOp.Q_PUSH, Promise.CRW, "am")):
            d = cm.choose_depth(op, promise, arm,
                                OpStats(skew=4.0, target_busy_us=4.0),
                                params)
            assert d == 2, (op, arm, params.name)
        for op in (cm.DSOp.HT_INSERT, cm.DSOp.HT_FIND, cm.DSOp.Q_PUSH,
                   cm.DSOp.Q_POP):
            for arm in cm.ARMS:
                assert cm.choose_depth(op, Promise.CRW, arm,
                                       OpStats(skew=4.0), params) != 4
        assert cm.choose_depth(cm.DSOp.HT_INSERT, Promise.CRW, "am",
                               OpStats(skew=4.0, target_busy_us=4.0),
                               params, max_depth=1) == 1


def test_calibrate_roundtrips_combine_term():
    cal = cm.calibrate({"combine": 0.5}, base=cm.TPU_V5E_ICI)
    assert cal.combine == 0.5
    s = OpStats(dedup=0.5)
    cheap = cm.predict(cm.DSOp.HT_FIND, Promise.CR, Backend.RDMA, s,
                       cm.TPU_V5E_ICI, coalesce=True)
    dear = cm.predict(cm.DSOp.HT_FIND, Promise.CR, Backend.RDMA, s, cal,
                      coalesce=True)
    assert dear - cheap == pytest.approx(0.5 - cm.TPU_V5E_ICI.combine)
