"""Per-architecture smoke tests (reduced configs, CPU) + model-level
correctness: decode == forward, MoE backend equivalence, vocab padding."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import ArchConfig
from repro.data import SyntheticLM
from repro.models import lm

KEY = jax.random.PRNGKey(0)


def _batch_for(cfg, B, S, rng):
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    batch = {"tokens": toks}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(0, 1, (B, S, cfg.d_model)), cfg.compute_dtype)
    if cfg.family == "vlm":
        npt = cfg.n_patch_tokens
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(0, 1, (B, npt, cfg.d_model)), cfg.compute_dtype)
    return batch


@pytest.mark.parametrize("arch", registry.list_archs())
def test_arch_smoke_train_step(arch):
    """Reduced config: one forward + one grad step; shapes + finiteness."""
    cfg = registry.get(arch).reduced()
    rng = np.random.default_rng(1)
    params = lm.init_params(cfg, KEY)
    batch = _batch_for(cfg, 2, 16, rng)
    loss, grads = jax.value_and_grad(lm.loss_fn)(params, cfg, batch)
    assert jnp.isfinite(loss), arch
    assert float(loss) < jnp.log(cfg.vocab) + 1.5
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.isfinite(leaf).all()), arch


@pytest.mark.parametrize("arch", registry.list_archs())
def test_arch_smoke_decode_step(arch):
    cfg = registry.get(arch).reduced()
    rng = np.random.default_rng(2)
    params = lm.init_params(cfg, KEY)
    state = lm.init_decode_state(cfg, 2, 32)
    if cfg.family == "encdec":
        state["enc"] = jnp.asarray(
            rng.normal(0, 1, state["enc"].shape), state["enc"].dtype)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2,)), jnp.int32)
    logits, state2 = lm.decode_step(params, cfg, state, toks)
    assert logits.shape == (2, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits).all())
    assert int(state2["pos"][0]) == 1
    # padded vocab entries can never win argmax
    assert int(logits.argmax(-1).max()) < cfg.vocab


@pytest.mark.parametrize("arch", ["granite-3-8b", "recurrentgemma-9b",
                                  "xlstm-1.3b", "deepseek-moe-16b"])
def test_decode_matches_forward(arch):
    """Step-by-step decode reproduces the training forward exactly — the
    strongest serving-correctness invariant (KV rings, recurrent states,
    MoE all agree with the parallel path)."""
    cfg = registry.get(arch).reduced()
    cfg = dataclasses.replace(cfg, capacity_factor=16.0)  # no MoE drops
    rng = np.random.default_rng(3)
    params = lm.init_params(cfg, KEY)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 10)), jnp.int32)
    x = lm._forward(params, cfg, toks)
    full = lm.logits_fn(params, cfg, x)
    state = lm.init_decode_state(cfg, 2, 16)
    errs = []
    for t in range(10):
        lg, state = lm.decode_step(params, cfg, state, toks[:, t])
        errs.append(float(jnp.abs(lg - full[:, t]).max()))
    assert max(errs) < 1e-4, (arch, errs)


def test_moe_local_vs_gathered_equivalence():
    cfg = registry.get("deepseek-moe-16b").reduced()
    cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    rng = np.random.default_rng(4)
    params = lm.init_params(cfg, KEY)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 12)), jnp.int32)
    l_rdma = lm.loss_fn(params, dataclasses.replace(cfg, moe_backend="rdma"),
                        {"tokens": toks})
    l_auto = lm.loss_fn(params, dataclasses.replace(cfg, moe_backend="auto"),
                        {"tokens": toks})
    assert abs(float(l_rdma) - float(l_auto)) < 1e-5


def test_moe_capacity_drops_degrade_gracefully():
    cfg = registry.get("deepseek-moe-16b").reduced()
    tight = dataclasses.replace(cfg, capacity_factor=0.5)
    rng = np.random.default_rng(5)
    params = lm.init_params(cfg, KEY)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 12)), jnp.int32)
    loss = lm.loss_fn(params, tight, {"tokens": toks})
    assert bool(jnp.isfinite(loss))


def test_flash_vs_reference_attention_in_model():
    """chunked_flash (block_k smaller than seq) == single-chunk result."""
    cfg = registry.get("granite-3-8b").reduced()
    rng = np.random.default_rng(6)
    q = jnp.asarray(rng.normal(size=(2, 24, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 24, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 24, 2, 16)), jnp.float32)
    o1 = lm.chunked_flash(q, k, v, causal=True, block_k=8)
    o2 = lm.chunked_flash(q, k, v, causal=True, block_k=1024)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


def test_data_pipeline_determinism():
    d1 = SyntheticLM(vocab=100, seq_len=32, seed=7)
    d2 = SyntheticLM(vocab=100, seq_len=32, seed=7)
    np.testing.assert_array_equal(d1.batch(5, 2, 4), d2.batch(5, 2, 4))
    assert not np.array_equal(d1.batch(5, 2, 4), d1.batch(6, 2, 4))
    assert not np.array_equal(d1.batch(5, 2, 4), d1.batch(5, 3, 4))


def test_param_specs_match_param_tree():
    """Every arch: the logical-spec tree has exactly the param tree's
    structure (the dry-run's sharding contract)."""
    for arch in registry.list_archs():
        cfg = registry.get(arch)
        specs = lm.param_specs(cfg)
        shapes = registry.params_specs(cfg)
        flat_specs = jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, tuple) and
            all(n is None or isinstance(n, str) for n in x))
        flat_shapes = jax.tree.leaves(shapes)
        assert len(flat_specs) == len(flat_shapes), arch
        for sp, sh in zip(flat_specs, flat_shapes):
            assert len(sp) == len(sh.shape), (arch, sp, sh.shape)


def test_decode_state_specs_match_state_tree():
    for arch in registry.list_archs():
        cfg = registry.get(arch)
        shape = cfg.shapes[0]
        st = jax.eval_shape(lambda: lm.init_decode_state(cfg, 4, 64))
        specs = lm.decode_state_logical_specs(cfg)
        flat_specs = jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, tuple) and
            all(n is None or isinstance(n, str) for n in x))
        flat_state = jax.tree.leaves(st)
        assert len(flat_specs) == len(flat_state), arch
