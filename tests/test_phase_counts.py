"""Phase-count regression: pin the DESIGN.md §2 exchange table so future
refactors cannot silently add network phases.

Two mechanisms:
  * in-process: a sharding hook counts `routing.exchange` calls per role
    (the same counter tests/test_datastructures.py uses) — put=1,
    get/cas/fao=2, AM dispatch=2, reply-elided dispatch=1, and exactly ONE
    occupancy (mask) exchange per planned batch;
  * subprocess (tests/phase_count_probe.py): the engine lowered under a
    real 8-way sharded mesh, all-to-alls counted in the optimized HLO by
    the launch/hlo_stats collective counter, plus the planner's
    one-argsort claim (make_plan HLO has exactly 1 sort, route_with_plan
    has 0). XLA_FLAGS must precede jax init, hence the subprocess.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import am as am_mod
from repro.core import costmodel as cm
from repro.core import queue as q_mod
from repro.core import routing, window
from repro.core.types import AmoKind, Backend, Promise

P = 4


class ExchangeCounter:
    """Counts exchanges by role via the sharding hook (each exchange calls
    the hook twice: role_pre and role_post)."""

    def __init__(self):
        self.roles = []

    def hook(self, x, role):
        if role.endswith("_pre"):
            self.roles.append(role[:-4])
        return x

    def run(self, fn):
        self.roles = []
        with routing.sharding_hook(self.hook):
            jax.block_until_ready(fn())
        return len(self.roles)

    def mask_exchanges(self):
        return sum(1 for r in self.roles if r.endswith("_mask"))


def _fixtures():
    rng = np.random.default_rng(0)
    dst = jnp.asarray(rng.integers(0, P, (P, 6)), jnp.int32)
    off = jnp.asarray(rng.integers(0, 32, (P, 6)), jnp.int32)
    win = window.make_window(P, 64)
    vals = jnp.ones((P, 6, 2), jnp.int32)
    return dst, off, win, vals


def test_component_op_exchange_table_planned():
    """The §2 component table on the planned engine: put=1, get=2, cas=2,
    fao=2 exchanges — and none of them is a mask exchange."""
    dst, off, win, vals = _fixtures()
    plan = routing.make_plan(dst, cap=6)
    c = ExchangeCounter()
    assert c.run(lambda: window.rdma_put(win, dst, off, vals,
                                         plan=plan)) == 1
    assert c.mask_exchanges() == 0
    assert c.run(lambda: window.rdma_get(win, dst, off, 2, plan=plan)) == 2
    assert c.run(lambda: window.rdma_cas(win, dst, off, 0, 1,
                                         plan=plan)) == 2
    assert c.run(lambda: window.rdma_fao(win, dst, off, 1, AmoKind.FAA,
                                         plan=plan)) == 2
    # fused descriptors are ordinary two-exchange component ops
    assert c.run(lambda: window.rdma_cas_put(win, dst, off, 0, 1, off + 1,
                                             vals, plan=plan)) == 2
    assert c.run(lambda: window.rdma_fao_get(win, dst, off, 1, AmoKind.FAA,
                                             off, 2, plan=plan)) == 2


def test_component_op_exchange_table_unplanned():
    """Unplanned route() pays one extra occupancy-mask exchange per phase
    (engine-level 2 for put, 3 for two-phase ops)."""
    dst, off, win, vals = _fixtures()
    c = ExchangeCounter()
    assert c.run(lambda: window.rdma_put(win, dst, off, vals)) == 2
    assert c.mask_exchanges() == 1
    assert c.run(lambda: window.rdma_cas(win, dst, off, 0, 1)) == 3
    assert c.mask_exchanges() == 1


def test_am_dispatch_exchange_table():
    """AM dispatch = 2 exchanges; reply-elided (reply_width=0) = 1; the
    plan's occupancy exchange happens once at plan time, not per dispatch."""
    dst, off, win, vals = _fixtures()
    eng = am_mod.AMEngine(P)
    echo = eng.register("echo", lambda l, p, m: (l, p[:, :1]),
                        reply_width=1)
    fire = eng.register("fire", lambda l, p, m: (l + p.sum(),
                                                 p[:, :0]), reply_width=0)
    state = jnp.zeros((P, 4), jnp.int32)
    c = ExchangeCounter()
    plan = routing.make_plan(dst, cap=6)
    assert c.run(lambda: eng.dispatch(echo, state, dst, vals,
                                      plan=plan)) == 2
    assert c.run(lambda: eng.dispatch(fire, state, dst, vals,
                                      plan=plan)) == 1
    # unplanned: +1 mask exchange riding with the request
    assert c.run(lambda: eng.dispatch(echo, state, dst, vals)) == 3
    assert c.mask_exchanges() == 1


def test_planned_batch_has_one_occupancy_exchange():
    """A planned probe loop exchanges the occupancy mask exactly ONCE per
    batch (at plan time); every subsequent phase ships payload only."""
    from repro.core import hashtable as ht_mod
    keys = jnp.arange(P * 4, dtype=jnp.int32).reshape(P, 4) + 1
    vals = jnp.stack([keys, keys], axis=-1)
    ht, _, _ = ht_mod.insert_rdma(ht_mod.make_hashtable(P, 32, 2), keys,
                                  vals, promise=Promise.CRW)
    c = ExchangeCounter()
    c.run(lambda: ht_mod.find_rdma(ht, keys, promise=Promise.CRW,
                                   max_probes=1, fused=True)[1])
    assert c.mask_exchanges() == cm.PLAN_EXCHANGES == 1
    c.run(lambda: ht_mod.insert_rdma(ht_mod.make_hashtable(P, 32, 2), keys,
                                     vals, promise=Promise.CRW,
                                     max_probes=1, fused=True)[0].win.data)
    assert c.mask_exchanges() == 1
    # unfused engine: one mask exchange per phase instead
    c.run(lambda: ht_mod.find_rdma(ht, keys, promise=Promise.CRW,
                                   max_probes=1, fused=False)[1])
    assert c.mask_exchanges() == 3  # lock FAO + get + unlock FAO


def test_coalescing_adds_zero_exchanges():
    """The §6 pin: sender-side coalescing is pure local compute. A
    coalesced component phase issues exactly the planned engine's
    exchange counts (put=1, get/cas/fao=2), a coalesce_plan pays the same
    ONE occupancy exchange as make_plan, and a coalesced AM dispatch stays
    at 2 exchanges."""
    dst, off, win, vals = _fixtures()
    hot = jnp.zeros_like(off)  # everything duplicates onto one word
    c = ExchangeCounter()
    # phase-local coalescing, unplanned: same counts as the unplanned
    # engine (payload + mask [+ reply])
    assert c.run(lambda: window.rdma_put(win, dst, hot, vals,
                                         coalesce=True)) == 2
    assert c.run(lambda: window.rdma_fao(win, dst, hot, 1, AmoKind.FAA,
                                         coalesce=True)[1].data) == 3
    # coalesce_plan: ONE occupancy exchange, exactly PLAN_EXCHANGES
    assert c.run(lambda: routing.coalesce_plan(dst, hot, cap=6).plan.mask
                 ) == 1
    assert c.mask_exchanges() == cm.PLAN_EXCHANGES == 1
    cplan = routing.coalesce_plan(dst, hot, cap=6)
    assert c.run(lambda: window.rdma_get(win, dst, hot, 2,
                                         plan=cplan)) == 2
    assert c.mask_exchanges() == 0
    assert c.run(lambda: window.rdma_cas(win, dst, hot, 0, 1,
                                         plan=cplan)[1].data) == 2
    assert c.run(lambda: window.rdma_fao_get(win, dst, hot, 1, AmoKind.FAA,
                                             hot, 2, plan=cplan)[2].data
                 ) == 2
    # coalesced AM dispatch: the paper's 2-exchange round trip, unchanged
    eng = am_mod.AMEngine(P)
    echo = eng.register("echo", lambda l, p, m: (l, p[:, :1]),
                        reply_width=1)
    state = jnp.zeros((P, 4), jnp.int32)
    plan = routing.make_plan(dst, cap=6)
    assert c.run(lambda: eng.dispatch(echo, state, dst, vals, plan=plan,
                                      coalesce=True)) == 2


def test_coalesced_fused_insert_exchanges_match_uncoalesced():
    """A whole coalesced fused C_RW insert traces the same phase
    structure as the uncoalesced one — ONE plan occupancy exchange + the
    probe request/reply pair — while on duplicate-heavy batches the
    adaptive while_loop runs FEWER probe phases at runtime (every
    duplicate group resolves with its representative's first claim,
    visible in the returned probe counts)."""
    from repro.core import hashtable as ht_mod
    keys = jnp.broadcast_to(jnp.arange(1, P + 1, dtype=jnp.int32)[:, None],
                            (P, 6)).astype(jnp.int32)  # 6 dups per origin
    vals = jnp.stack([keys, keys], axis=-1)
    c = ExchangeCounter()
    got_unc = c.run(lambda: ht_mod.insert_rdma(
        ht_mod.make_hashtable(P, 64, 2), keys, vals, promise=Promise.CRW,
        max_probes=8, fused=True)[0].win.data)
    _, _, probes_co = ht_mod.insert_rdma(
        ht_mod.make_hashtable(P, 64, 2), keys, vals, promise=Promise.CRW,
        max_probes=8, fused=True, coalesce=True)
    got_co = c.run(lambda: ht_mod.insert_rdma(
        ht_mod.make_hashtable(P, 64, 2), keys, vals, promise=Promise.CRW,
        max_probes=8, fused=True, coalesce=True)[0].win.data)
    assert c.mask_exchanges() == 1  # still ONE plan occupancy exchange
    assert got_co == got_unc        # zero extra exchanges, trace-level
    _, _, probes_unc = ht_mod.insert_rdma(
        ht_mod.make_hashtable(P, 64, 2), keys, vals, promise=Promise.CRW,
        max_probes=8, fused=True)
    assert int(probes_co.max()) == 1      # every dup rides the rep's claim
    assert int(probes_unc.max()) == 6     # uncoalesced dups probe onward


def test_queue_exchange_counts_agree_with_costmodel():
    """Queue push/pop engine exchanges match costmodel.exchange_count (the
    §2 table), extending the hash-table cross-check in
    tests/test_datastructures.py to the hosted queue."""
    vals = jnp.ones((P, 5, 2), jnp.int32)
    c = ExchangeCounter()
    for promise in (Promise.CRW, Promise.CW):
        for planned in (False, True):
            q = q_mod.make_queue(P, 0, 64, 2)
            got = c.run(lambda: q_mod.push_rdma(
                q, vals, promise=promise, planned=planned,
                max_cas_rounds=1)[0].win.data)
            want = cm.exchange_count(cm.DSOp.Q_PUSH, promise, Backend.RDMA,
                                     fused=planned)
            if planned:
                want += cm.PLAN_EXCHANGES
            assert got == want, (promise, planned, got, want)
    for promise in (Promise.CRW, Promise.CR):
        for planned in (False, True):
            q = q_mod.make_queue(P, 0, 64, 2)
            q, _ = q_mod.push_rdma(q, vals, promise=Promise.CW)
            got = c.run(lambda: q_mod.pop_rdma(
                q, 5, promise=promise, planned=planned,
                max_cas_rounds=1)[0].win.data)
            want = cm.exchange_count(cm.DSOp.Q_POP, promise, Backend.RDMA,
                                     fused=planned)
            if planned:
                want += cm.PLAN_EXCHANGES
            assert got == want, (promise, planned, got, want)


def test_rpc_exchange_count_constant_in_handler_complexity():
    """The paper's central RPC property at the engine level: dispatch costs
    the same 2 exchanges whether the handler is an echo or a full
    sequential hash-table probe loop."""
    from repro.core import hashtable as ht_mod
    keys = jnp.arange(P * 4, dtype=jnp.int32).reshape(P, 4) + 1
    vals = keys[..., None]
    ht = ht_mod.make_hashtable(P, 64, 1)
    eng = am_mod.AMEngine(P)
    ht_mod.build_am_handlers(ht, eng)
    c = ExchangeCounter()
    got_insert = c.run(lambda: ht_mod.insert_rpc(ht, eng, keys,
                                                 vals)[0].win.data)
    got_find = c.run(lambda: ht_mod.find_rpc(ht, eng, keys)[0])
    # unplanned dispatch: request + mask + reply = 3 engine exchanges,
    # independent of what the handler does
    assert got_insert == got_find == cm.exchange_count(
        cm.DSOp.HT_INSERT, Promise.CRW, Backend.RPC, fused=False) == 3


# ---------------------------------------------------------------------------
# Scale-parameterized phase counts (DESIGN.md §9): the §2 exchange table
# is P-INDEPENDENT — growing the shard count widens each exchange's lanes
# but never adds a network phase. Pinned at P=16 and P=64 so the scaling
# benches measure wider exchanges, not silently more of them.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("scale_p", (16, 64))
def test_exchange_counts_p_independent(scale_p):
    """Planned put=1, get=2, cas=2, fao=2, AM dispatch=2, plan occupancy=1
    at P=16/64 — identical to the P=4 table above."""
    rng = np.random.default_rng(scale_p)
    dst = jnp.asarray(rng.integers(0, scale_p, (scale_p, 4)), jnp.int32)
    off = jnp.asarray(rng.integers(0, 32, (scale_p, 4)), jnp.int32)
    win = window.make_window(scale_p, 64)
    vals = jnp.ones((scale_p, 4, 2), jnp.int32)
    plan = routing.make_plan(dst, cap=4)
    c = ExchangeCounter()
    assert c.run(lambda: window.rdma_put(win, dst, off, vals, plan=plan)) == 1
    assert c.run(lambda: window.rdma_get(win, dst, off, 2, plan=plan)) == 2
    assert c.run(lambda: window.rdma_cas(win, dst, off, 0, 1, plan=plan)) == 2
    assert c.run(lambda: window.rdma_fao(win, dst, off, 1, AmoKind.FAA,
                                         plan=plan)) == 2
    assert c.run(lambda: routing.make_plan(dst, cap=4).mask) == 1
    assert c.mask_exchanges() == cm.PLAN_EXCHANGES == 1
    eng = am_mod.AMEngine(scale_p)
    echo = eng.register("echo", lambda l, p, m: (l, p[:, :1]),
                        reply_width=1)
    state = jnp.zeros((scale_p, 4), jnp.int32)
    assert c.run(lambda: eng.dispatch(echo, state, dst, vals,
                                      plan=plan)) == 2


@pytest.mark.parametrize("scale_p", (16, 64))
def test_planned_ht_batch_one_occupancy_exchange_at_scale(scale_p):
    """A fused hash-table batch at P=16/64 still exchanges the occupancy
    mask exactly ONCE (at plan time) — the §9 scaling claim that per-batch
    phase structure is flat in P, and the coalesce plan's occupancy is
    bit-identical to the plain plan's on distinct traffic."""
    from repro.core import hashtable as ht_mod
    keys = (jnp.arange(scale_p * 4, dtype=jnp.int32).reshape(scale_p, 4)
            + 1)
    vals = jnp.stack([keys, keys], axis=-1)
    ht, _, _ = ht_mod.insert_rdma(ht_mod.make_hashtable(scale_p, 64, 2),
                                  keys, vals, promise=Promise.CRW)
    c = ExchangeCounter()
    c.run(lambda: ht_mod.find_rdma(ht, keys, promise=Promise.CRW,
                                   max_probes=1, fused=True)[1])
    assert c.mask_exchanges() == cm.PLAN_EXCHANGES == 1
    c.run(lambda: ht_mod.insert_rdma(
        ht_mod.make_hashtable(scale_p, 64, 2), keys, vals,
        promise=Promise.CRW, max_probes=1, fused=True)[0].win.data)
    assert c.mask_exchanges() == 1
    # occupancy bit-exactness across the plan paths
    rng = np.random.default_rng(scale_p + 1)
    dst = jnp.asarray(rng.integers(0, scale_p, (scale_p, 5)), jnp.int32)
    off = jnp.asarray(rng.integers(0, 64, (scale_p, 5)), jnp.int32)
    plain = routing.make_plan(dst, cap=5)
    co = routing.coalesce_plan(dst, off, cap=5)
    np.testing.assert_array_equal(np.asarray(plain.mask),
                                  np.asarray(co.plan.mask))


# ---------------------------------------------------------------------------
# Sharded-HLO cross-check (the roofline collective counter sees the same
# phase structure the hook counts).
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def hlo_counts():
    probe = os.path.join(os.path.dirname(__file__), "phase_count_probe.py")
    env = dict(os.environ, PYTHONPATH=os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         os.environ.get("PYTHONPATH", "")]))
    try:
        out = subprocess.run([sys.executable, probe], capture_output=True,
                             text=True, timeout=900, env=env)
    except subprocess.TimeoutExpired:
        pytest.skip("phase_count_probe timed out")
    if out.returncode != 0:
        pytest.skip(f"sharded lowering unavailable: {out.stderr[-500:]}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_hlo_all_to_all_counts_pin_exchange_table(hlo_counts):
    """Lowered, SPMD-partitioned HLO emits exactly the §2 table's
    all-to-alls: put=1, get/cas/fao=2, dispatch=2, reply-elided=1, plan=1,
    and the unplanned engine's extra mask exchange shows up as +1."""
    c = hlo_counts
    assert c["put"]["a2a"] == 1
    assert c["get"]["a2a"] == 2
    assert c["cas"]["a2a"] == 2
    assert c["fao"]["a2a"] == 2
    assert c["cas_unplanned"]["a2a"] == 3
    assert c["dispatch"]["a2a"] == 2
    assert c["dispatch_elided"]["a2a"] == 1
    assert c["make_plan"]["a2a"] == 1
    assert c["route_with_plan"]["a2a"] == 1


def test_hlo_planned_probe_loop_is_one_argsort(hlo_counts):
    """The route-plan claim in HLO: make_plan lowers to exactly ONE sort
    (the stable argsort by destination) and a plan-reusing payload phase
    contains NO sort at all."""
    c = hlo_counts
    assert c["make_plan"]["sorts"] == 1
    assert c["route_with_plan"]["sorts"] == 0


def test_hlo_fused_insert_matches_costmodel(hlo_counts):
    """Whole fused C_RW insert at max_probes=1: probe exchanges + the one
    plan exchange, agreeing with costmodel.exchange_count."""
    want = cm.exchange_count(cm.DSOp.HT_INSERT, Promise.CRW, Backend.RDMA,
                             fused=True, probes=1) + cm.PLAN_EXCHANGES
    assert hlo_counts["ht_insert_fused"]["a2a"] == want == 3
