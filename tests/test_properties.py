"""Property-based tests of the system's invariants.

`hypothesis` is an OPTIONAL dev dependency: when absent (e.g. the minimal
CI container) this module skips instead of failing collection."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import am as am_mod
from repro.core import costmodel as cm
from repro.core import hashtable as ht_mod
from repro.core import queue as q_mod
from repro.core import window
from repro.core.types import AmoKind, Backend, OpStats, Promise
from repro.kernels import ref
from repro.optim import compress_int8, decompress_int8

SET = settings(max_examples=20, deadline=None)


# ---------------------------------------------------------------------------
# AMO serialization: batched apply == some sequential order (linearizable),
# and equal to the independently-written ref oracle under the same order.
# ---------------------------------------------------------------------------
@SET
@given(st.data())
def test_amo_apply_linearizable(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    L, m = 16, data.draw(st.integers(1, 24))
    local = jnp.asarray(rng.integers(0, 50, (L,)), jnp.int32)
    ops = np.zeros((m, 4), np.int32)
    ops[:, 0] = rng.integers(0, L, m)
    ops[:, 1] = rng.integers(0, 7, m)
    ops[:, 2] = rng.integers(-4, 5, m)
    ops[:, 3] = rng.integers(-4, 5, m)
    mask = jnp.asarray(rng.random(m) > 0.2)
    old, new = ref.amo_apply(local, jnp.asarray(ops), mask)
    # python re-execution in the same serialized order
    state = np.asarray(local).copy()
    for j in range(m):
        if not bool(mask[j]):
            continue
        o, code, a, b = ops[j]
        cur = state[o]
        if code == 0:
            state[o] = b
        elif code == 2:
            state[o] = b if cur == a else cur
        elif code == 3:
            state[o] = cur + a
        elif code == 4:
            state[o] = cur | a
        elif code == 5:
            state[o] = cur & a
        elif code == 6:
            state[o] = cur ^ a
        assert int(old[j]) == cur
    np.testing.assert_array_equal(np.asarray(new), state)


# ---------------------------------------------------------------------------
# Hash table == python dict under random op streams, both backends
# ---------------------------------------------------------------------------
@SET
@given(st.data())
def test_hashtable_vs_dict(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    P = 2
    nops = data.draw(st.integers(1, 4))
    n = 4
    ht_r = ht_mod.make_hashtable(P, 64, 1)
    ht_p = ht_mod.make_hashtable(P, 64, 1)
    eng = am_mod.AMEngine(P)
    ht_mod.build_am_handlers(ht_p, eng)
    oracle = {}
    for _ in range(nops):
        keys = rng.choice(np.arange(1, 40), size=P * n, replace=False)
        keys = jnp.asarray(keys.reshape(P, n), jnp.int32)
        vals = keys[..., None] * 3 + 1
        new = ~np.isin(np.asarray(keys), list(oracle))
        ht_r, ok_r, _ = ht_mod.insert_rdma(ht_r, keys, vals,
                                           promise=Promise.CW,
                                           valid=jnp.asarray(new),
                                           max_probes=64)
        ht_p, ok_p, _ = ht_mod.insert_rpc(ht_p, eng, keys, vals,
                                          valid=jnp.asarray(new))
        for k in np.asarray(keys).ravel():
            oracle[int(k)] = int(k) * 3 + 1
        probe = jnp.asarray(
            rng.integers(1, 45, (P, n)), jnp.int32)
        ht_r, f_r, v_r = ht_mod.find_rdma(ht_r, probe, promise=Promise.CR,
                                          max_probes=64)
        f_p, v_p = ht_mod.find_rpc(ht_p, eng, probe)
        for idx in np.ndindex(P, n):
            k = int(probe[idx])
            want = oracle.get(k)
            for f, v in ((f_r, v_r), (f_p, v_p)):
                if want is None:
                    assert not bool(f[idx])
                else:
                    assert bool(f[idx]) and int(v[idx][0]) == want


# ---------------------------------------------------------------------------
# Fused component phases == unfused per-component sequences (DESIGN.md §2)
# on randomized contended batches, at every promise level
# ---------------------------------------------------------------------------
@SET
@given(st.data())
def test_fused_insert_find_bit_exact(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    P = 2
    n = data.draw(st.integers(1, 6))
    nslots = data.draw(st.sampled_from([4, 8, 16]))  # tiny -> contention
    keys = rng.choice(np.arange(1, 2000), size=P * n, replace=False)
    keys = jnp.asarray(keys.reshape(P, n), jnp.int32)
    vals = keys[..., None] * 5 - 1
    promise = data.draw(st.sampled_from([Promise.CRW, Promise.CW]))
    ht_a = ht_mod.make_hashtable(P, nslots, 1)
    ht_b = ht_mod.make_hashtable(P, nslots, 1)
    ht_a, ok_a, pr_a = ht_mod.insert_rdma(ht_a, keys, vals, promise=promise,
                                          max_probes=nslots, fused=False)
    ht_b, ok_b, pr_b = ht_mod.insert_rdma(ht_b, keys, vals, promise=promise,
                                          max_probes=nslots, fused=True)
    np.testing.assert_array_equal(np.asarray(ht_a.win.data),
                                  np.asarray(ht_b.win.data))
    np.testing.assert_array_equal(np.asarray(ok_a), np.asarray(ok_b))
    np.testing.assert_array_equal(np.asarray(pr_a), np.asarray(pr_b))
    probe = jnp.asarray(rng.integers(1, 2200, (P, n)), jnp.int32)
    find_p = data.draw(st.sampled_from([Promise.CR, Promise.CRW]))
    ht_a2, f_a, v_a = ht_mod.find_rdma(ht_a, probe, promise=find_p,
                                       max_probes=nslots, fused=False)
    ht_b2, f_b, v_b = ht_mod.find_rdma(ht_b, probe, promise=find_p,
                                       max_probes=nslots, fused=True)
    np.testing.assert_array_equal(np.asarray(f_a), np.asarray(f_b))
    np.testing.assert_array_equal(np.asarray(v_a), np.asarray(v_b))
    np.testing.assert_array_equal(np.asarray(ht_a2.win.data),
                                  np.asarray(ht_b2.win.data))


@SET
@given(st.data())
def test_planned_route_reuse_bit_exact(data):
    """route_with_plan under a shrinking active mask delivers exactly the
    active ops, in the plan's serialization slots."""
    from repro.core import routing
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    P = data.draw(st.integers(2, 4))
    n = data.draw(st.integers(1, 8))
    dst = jnp.asarray(rng.integers(0, P, (P, n)), jnp.int32)
    payload = jnp.asarray(rng.integers(1, 1000, (P, n, 1)), jnp.int32)
    plan = routing.make_plan(dst, cap=n)
    active = jnp.asarray(rng.random((P, n)) > rng.random())
    planned = routing.route_with_plan(plan, payload, active=active)
    flat, mask = routing.flatten_owner_view(planned)
    got = np.sort(np.asarray(flat[np.asarray(mask)])[:, 0])
    want = np.sort(np.asarray(payload[..., 0])[np.asarray(active)].ravel())
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# Coalescing (DESIGN.md §6) == uncoalesced engine on randomized
# duplicate-heavy batches, at the window AND data-structure level
# ---------------------------------------------------------------------------
@SET
@given(st.data())
def test_window_coalesce_bit_exact(data):
    """Every coalescible window op: sender-side combining returns the
    exact per-op fetched values and final window state of the serialized
    uncoalesced engine, on batches drawn over a tiny offset space (heavy
    duplicate runs) with random valid masks."""
    from repro.core import window as win_mod
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    P, n = 3, data.draw(st.integers(1, 10))
    win = win_mod.make_window(P, 32)
    dst = jnp.asarray(rng.integers(0, P, (P, n)), jnp.int32)
    off = jnp.asarray(rng.integers(0, 4, (P, n)), jnp.int32)
    valid = jnp.asarray(rng.random((P, n)) > 0.25)
    kind = data.draw(st.sampled_from([AmoKind.FAA, AmoKind.FOR,
                                      AmoKind.FAND, AmoKind.FXOR]))
    operand = jnp.asarray(rng.integers(-3, 4, (P, n)), jnp.int32)
    o1, w1 = win_mod.rdma_fao(win, dst, off, operand, kind, valid=valid)
    o2, w2 = win_mod.rdma_fao(win, dst, off, operand, kind, valid=valid,
                              coalesce=True)
    np.testing.assert_array_equal(np.asarray(w1.data), np.asarray(w2.data))
    v = np.asarray(valid)
    np.testing.assert_array_equal(np.asarray(o1)[v], np.asarray(o2)[v])
    cmp = jnp.asarray(rng.integers(0, 2, (P, n)), jnp.int32)
    new = jnp.asarray(rng.integers(1, 4, (P, n)), jnp.int32)
    c1, x1 = win_mod.rdma_cas(win, dst, off, cmp, new, valid=valid)
    c2, x2 = win_mod.rdma_cas(win, dst, off, cmp, new, valid=valid,
                              coalesce=True)
    np.testing.assert_array_equal(np.asarray(x1.data), np.asarray(x2.data))
    np.testing.assert_array_equal(np.asarray(c1)[v], np.asarray(c2)[v])
    vals = jnp.asarray(rng.integers(1, 99, (P, n, 2)), jnp.int32)
    p1 = win_mod.rdma_put(win, dst, off * 2, vals, valid=valid)
    p2 = win_mod.rdma_put(win, dst, off * 2, vals, valid=valid,
                          coalesce=True)
    np.testing.assert_array_equal(np.asarray(p1.data), np.asarray(p2.data))
    g1 = win_mod.rdma_get(p1, dst, off, 3, valid=valid)
    g2 = win_mod.rdma_get(p2, dst, off, 3, valid=valid, coalesce=True)
    np.testing.assert_array_equal(np.asarray(g1)[v], np.asarray(g2)[v])


@SET
@given(st.data())
def test_ht_coalesced_duplicate_stream_conformant(data):
    """Duplicate-heavy (zipfian-ish) insert+find: the coalesced fused
    engine is visibly conformant with the uncoalesced one — identical ok
    flags and identical find results for every key, at both promises."""
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    P, n = 2, data.draw(st.integers(2, 8))
    universe = rng.choice(np.arange(1, 3000), size=4, replace=False)
    keys = jnp.asarray(rng.choice(universe, size=(P, n)), jnp.int32)
    vals = ((keys * 13 + 5) & 0xFFFF)[..., None]
    promise = data.draw(st.sampled_from([Promise.CRW, Promise.CW]))
    ht_a = ht_mod.make_hashtable(P, 64, 1)
    ht_b = ht_mod.make_hashtable(P, 64, 1)
    ht_a, ok_a, _ = ht_mod.insert_rdma(ht_a, keys, vals, promise=promise,
                                       max_probes=32, fused=True)
    ht_b, ok_b, _ = ht_mod.insert_rdma(ht_b, keys, vals, promise=promise,
                                       max_probes=32, fused=True,
                                       coalesce=True)
    np.testing.assert_array_equal(np.asarray(ok_a), np.asarray(ok_b))
    probe = jnp.asarray(rng.choice(np.concatenate([universe,
                                                   np.arange(5000, 5004)]),
                                   size=(P, n)), jnp.int32)
    find_p = data.draw(st.sampled_from([Promise.CR, Promise.CRW]))
    _, f_a, v_a = ht_mod.find_rdma(ht_a, probe, promise=find_p,
                                   max_probes=32, fused=True)
    _, f_b, v_b = ht_mod.find_rdma(ht_b, probe, promise=find_p,
                                   max_probes=32, fused=True, coalesce=True)
    np.testing.assert_array_equal(np.asarray(f_a), np.asarray(f_b))
    np.testing.assert_array_equal(np.asarray(v_a), np.asarray(v_b))


@SET
@given(st.data())
def test_kernel_duplicate_run_combining_bit_exact(data):
    """ops.amo_apply(combine_runs=True) == plain serialized apply on
    random op lists with heavy duplicate runs, on both lanes."""
    from repro.kernels import ops as kops
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    m = data.draw(st.integers(1, 24))
    local = jnp.asarray(rng.integers(0, 50, (2, 16)), jnp.int32)
    ops = np.zeros((2, m, 4), np.int32)
    ops[..., 0] = rng.integers(0, 3, (2, m))
    ops[..., 1] = rng.integers(0, 7, (2, m))
    ops[..., 2] = rng.integers(-4, 5, (2, m))
    ops[..., 3] = rng.integers(-4, 5, (2, m))
    mask = jnp.asarray(rng.random((2, m)) > 0.2)
    o1, l1 = kops.amo_apply(local, jnp.asarray(ops), mask,
                            use_pallas=False)
    o2, l2 = kops.amo_apply(local, jnp.asarray(ops), mask,
                            use_pallas=False, combine_runs=True)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


# ---------------------------------------------------------------------------
# Queue FIFO + conservation under random push/pop batches
# ---------------------------------------------------------------------------
@SET
@given(st.data())
def test_queue_fifo_conservation(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    P = 2
    q = q_mod.make_queue(P, host=0, capacity=256, val_words=1)
    pushed, popped = [], []
    counter = 1
    for _ in range(data.draw(st.integers(1, 5))):
        if rng.random() < 0.6:
            n = int(rng.integers(1, 5))
            vals = np.arange(counter, counter + P * n)
            counter += P * n
            q, ok = q_mod.push_rdma(
                q, jnp.asarray(vals.reshape(P, n, 1), jnp.int32),
                promise=Promise.CW)
            pushed += list(vals[np.asarray(ok).ravel()])
        else:
            n = int(rng.integers(1, 5))
            q, got, out = q_mod.pop_rdma(q, n, promise=Promise.CR)
            popped += list(np.asarray(out[np.asarray(got)]).ravel())
    q, got, out = q_mod.pop_rdma(q, 64, promise=Promise.CR)
    popped += list(np.asarray(out[np.asarray(got)]).ravel())
    assert sorted(popped) == sorted(pushed)        # conservation


# ---------------------------------------------------------------------------
# Cost model properties
# ---------------------------------------------------------------------------
@SET
@given(st.sampled_from(list(cm.DSOp)),
       st.floats(0.1, 10.0), st.floats(0.1, 10.0))
def test_costmodel_promise_ordering(op, probes, contention):
    """Stronger promises never cost less: C_RW >= phasal variant."""
    s = OpStats(expected_probes=probes, contention=contention)
    weak = {cm.DSOp.HT_INSERT: Promise.CW, cm.DSOp.HT_FIND: Promise.CR,
            cm.DSOp.Q_PUSH: Promise.CW, cm.DSOp.Q_POP: Promise.CR}[op]
    full = cm.predict(op, Promise.CRW, Backend.RDMA, s)
    phasal = cm.predict(op, weak, Backend.RDMA, s)
    assert full >= phasal


@SET
@given(st.floats(0.0, 50.0))
def test_costmodel_attentiveness_monotone(busy):
    s0 = OpStats(target_busy_us=busy)
    s1 = OpStats(target_busy_us=busy + 1.0)
    c0 = cm.predict(cm.DSOp.Q_PUSH, Promise.CW, Backend.RPC, s0)
    c1 = cm.predict(cm.DSOp.Q_PUSH, Promise.CW, Backend.RPC, s1)
    assert c1 >= c0
    # RDMA is attentiveness-independent (paper Fig. 6)
    r0 = cm.predict(cm.DSOp.Q_PUSH, Promise.CW, Backend.RDMA, s0)
    r1 = cm.predict(cm.DSOp.Q_PUSH, Promise.CW, Backend.RDMA, s1)
    assert r0 == r1


def test_costmodel_network_phases_table():
    assert cm.network_phases(cm.DSOp.HT_INSERT, Promise.CRW,
                             Backend.RDMA) == 3
    assert cm.network_phases(cm.DSOp.HT_INSERT, Promise.CW,
                             Backend.RDMA) == 2
    assert cm.network_phases(cm.DSOp.HT_FIND, Promise.CR, Backend.RDMA) == 1
    assert cm.network_phases(cm.DSOp.Q_PUSH, Promise.CL, Backend.RDMA) == 0
    for op in cm.DSOp:
        assert cm.network_phases(op, Promise.CRW, Backend.RPC) == 1
    # fused engine: insert claim+write+publish is ONE phase, C_RW find is 2
    assert cm.network_phases(cm.DSOp.HT_INSERT, Promise.CRW, Backend.RDMA,
                             fused=True) == 1
    assert cm.network_phases(cm.DSOp.HT_INSERT, Promise.CW, Backend.RDMA,
                             fused=True) == 1
    assert cm.network_phases(cm.DSOp.HT_FIND, Promise.CRW, Backend.RDMA,
                             fused=True) == 2


@SET
@given(st.sampled_from([(cm.DSOp.HT_INSERT, Promise.CRW),
                        (cm.DSOp.HT_INSERT, Promise.CW),
                        (cm.DSOp.HT_FIND, Promise.CRW)]),
       st.floats(0.1, 10.0))
def test_costmodel_fused_never_costs_more(op_promise, probes):
    """Fusing removes whole phases, so the fused prediction is never more
    expensive than the unfused one (at derived-default fused costs)."""
    op, promise = op_promise
    s = OpStats(expected_probes=probes)
    fused = cm.predict(op, promise, Backend.RDMA, s, fused=True)
    unfused = cm.predict(op, promise, Backend.RDMA, s, fused=False)
    assert fused <= unfused


@SET
@given(st.integers(1, 10**7), st.integers(1, 10**5))
def test_moe_chooser_consistent(tokens, expert_kb):
    b = cm.choose_moe_backend(tokens_per_rank=tokens, d_model=1024,
                              expert_bytes_per_rank=expert_kb * 1024)
    rpc = cm.moe_dispatch_bytes(Backend.RPC, tokens_per_rank=tokens,
                                d_model=1024,
                                expert_bytes_per_rank=expert_kb * 1024)
    rdma = cm.moe_dispatch_bytes(Backend.RDMA, tokens_per_rank=tokens,
                                 d_model=1024,
                                 expert_bytes_per_rank=expert_kb * 1024)
    assert (b == Backend.RPC) == (rpc <= rdma)


# ---------------------------------------------------------------------------
# Compression round trip
# ---------------------------------------------------------------------------
@SET
@given(st.data())
def test_int8_compression_bounded_error(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    shape = data.draw(st.sampled_from([(64,), (33,), (16, 24), (3, 5, 7)]))
    x = jnp.asarray(rng.normal(0, 1, shape), jnp.float32)
    codes, scales = compress_int8(x)
    y = decompress_int8(codes, scales, x.shape, x.dtype)
    blockmax = float(jnp.abs(x).max())
    assert float(jnp.abs(y - x).max()) <= blockmax / 127.0 + 1e-6
