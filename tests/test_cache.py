"""Hot-bucket cache tier (DESIGN.md §8): correctness of the publish-based
version protocol and the zero-exchange property of cache hits.

Three layers of checks:
  * phase-count pins (the ExchangeCounter idiom of test_phase_counts.py):
    an all-hit find issues ZERO exchanges, a mixed batch plans exactly the
    miss subset;
  * directed invalidation ordering: stale-version eviction, write-then-read
    of the same key in one round, deferred-fill drop on a racing write,
    write-heavy read suspension;
  * randomized mixed read/write sequences against the dict oracle and the
    uncached arms (hypothesis when available, a seeded fallback always).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import adaptive as ad_mod
from repro.core import cache as cache_mod
from repro.core import hashtable as ht_mod
from repro.core import routing
from repro.core import window as win_mod
from repro.core.types import Promise

P = 4
VW = 1
NSLOTS = 64


def _val_of(keys):
    return ((keys * 31 + 7) & 0x7FFFFF)[..., None]


class ExchangeCounter:
    """Counts exchanges by role via the sharding hook (each exchange calls
    the hook twice: role_pre and role_post)."""

    def __init__(self):
        self.roles = []

    def hook(self, x, role):
        if role.endswith("_pre"):
            self.roles.append(role[:-4])
        return x

    def run(self, fn):
        self.roles = []
        with routing.sharding_hook(self.hook):
            out = fn()
            jax.block_until_ready(out)
        return len(self.roles)


def _fresh(rng, shape, used):
    out = np.empty(int(np.prod(shape)), np.int64)
    i = 0
    while i < out.size:
        k = int(rng.integers(1, 1 << 30))
        if k not in used:
            used.add(k)
            out[i] = k
            i += 1
    return jnp.asarray(out.reshape(shape), jnp.int32)


def _engine(nslots=NSLOTS, capacity=256, max_probes=8):
    eng = ad_mod.AdaptiveEngine(P, arms=("rdma_fused",))
    eng.attach_cache(cache_mod.BucketCache(P, nslots, VW, capacity=capacity,
                                           max_probes=max_probes))
    return eng


# ---------------------------------------------------------------------------
# Zero-exchange pins
# ---------------------------------------------------------------------------
def test_all_hit_find_issues_zero_exchanges():
    """A fully-cached find batch never touches the network — the §8
    headline property, pinned at the exchange level."""
    rng = np.random.default_rng(0)
    used: set = set()
    eng = _engine()
    ht = ht_mod.make_hashtable(P, NSLOTS, VW)
    keys = _fresh(rng, (P, 8), used)
    ht, ok, _ = eng.ht_insert(ht, keys, _val_of(keys))
    assert bool(np.asarray(ok).all())
    ht, f1, v1 = eng.ht_find(ht, keys)     # miss pass: fills the cache
    assert bool(np.asarray(f1).all())

    ctr = ExchangeCounter()
    n = ctr.run(lambda: eng.ht_find(ht, keys)[1:])
    assert n == 0, f"all-hit find issued {n} exchanges: {ctr.roles}"
    # and the answers it produced are still exact
    ht, f2, v2 = eng.ht_find(ht, keys)
    np.testing.assert_array_equal(np.asarray(f2), np.asarray(f1))
    np.testing.assert_array_equal(np.asarray(v2), np.asarray(v1))


def test_mixed_batch_plans_only_the_miss_subset():
    """A half-cached batch pays the same exchanges as a batch of just the
    misses — the miss-subset plan is bit-identical occupancy, and hits add
    zero exchanges on top."""
    rng = np.random.default_rng(1)
    used: set = set()
    eng = _engine()
    ht = ht_mod.make_hashtable(P, NSLOTS, VW)
    warm = _fresh(rng, (P, 4), used)
    cold = _fresh(rng, (P, 4), used)
    both = jnp.concatenate([warm, cold], axis=1)
    ht, _, _ = eng.ht_insert(ht, both, _val_of(both))
    ht, _, _ = eng.ht_find(ht, warm)       # cache the warm half
    ht, _, _ = eng.ht_find(ht, warm)       # confirmed hot
    assert eng.cache.last_hit_rate == 1.0

    # reference: the same engine state finding ONLY the cold keys after a
    # full flush (so nothing is cached) — the pure miss-subset cost
    ctr = ExchangeCounter()
    mixed = ctr.run(lambda: eng.ht_find(ht, both)[1:])
    eng.cache.invalidate_all()
    cold_only = ctr.run(lambda: eng.ht_find(ht, cold)[1:])
    assert mixed == cold_only, (
        f"mixed batch paid {mixed} exchanges vs {cold_only} for the bare "
        "miss subset")


def test_cache_events_logged_without_extra_phases():
    """drain_phase_log carries cache_hit events for cached finds; the
    routed-phase entries (the exchange-bearing ones) stay untouched."""
    rng = np.random.default_rng(2)
    used: set = set()
    eng = _engine()
    ht = ht_mod.make_hashtable(P, NSLOTS, VW)
    keys = _fresh(rng, (P, 6), used)
    ht, _, _ = eng.ht_insert(ht, keys, _val_of(keys))
    ht, _, _ = eng.ht_find(ht, keys)
    win_mod.drain_phase_log()
    ht, f, v = eng.ht_find(ht, keys)       # all-hit
    log = win_mod.drain_phase_log()
    roles = [r for r, _, _ in log]
    assert "cache_hit" in roles
    assert not any(r.startswith(("get", "ht_find", "fao")) for r in roles), (
        f"all-hit find logged routed phases: {roles}")


# ---------------------------------------------------------------------------
# Invalidation ordering
# ---------------------------------------------------------------------------
def test_stale_version_eviction():
    """Bumping a cached slot's version (what any insert in its probe window
    does) forces the next lookup to miss, evict, and refetch fresh."""
    rng = np.random.default_rng(3)
    used: set = set()
    eng = _engine()
    c = eng.cache
    ht = ht_mod.make_hashtable(P, NSLOTS, VW)
    keys = _fresh(rng, (P, 4), used)
    ht, _, _ = eng.ht_insert(ht, keys, _val_of(keys))
    ht, _, _ = eng.ht_find(ht, keys)
    ht, f, v = eng.ht_find(ht, keys)
    assert c.last_hit_rate == 1.0
    c.versions += 1                        # every cached entry now stale
    before = c.counters["stale_evicted"]
    ht, f, v = eng.ht_find(ht, keys)
    assert c.last_hit_rate == 0.0
    assert c.counters["stale_evicted"] > before
    assert bool(np.asarray(f).all())       # refetched from the table
    np.testing.assert_array_equal(np.asarray(v), np.asarray(_val_of(keys)))


def test_write_then_read_same_round_sees_the_write():
    """Insert keys, then find the SAME keys immediately (the write-then-read
    in one round of the conformance bar): the pre-insert cache state must
    not answer — the probe-window bump runs before the write executes."""
    rng = np.random.default_rng(4)
    used: set = set()
    eng = _engine()
    ht = ht_mod.make_hashtable(P, NSLOTS, VW)
    k1 = _fresh(rng, (P, 4), used)
    ht, _, _ = eng.ht_insert(ht, k1, _val_of(k1))
    ht, _, _ = eng.ht_find(ht, k1)         # warm
    k2 = _fresh(rng, (P, 4), used)
    ht, ok, _ = eng.ht_insert(ht, k2, _val_of(k2))
    assert bool(np.asarray(ok).all())
    ht, f, v = eng.ht_find(ht, k2)         # same-round read of the write
    assert bool(np.asarray(f).all())
    np.testing.assert_array_equal(np.asarray(v), np.asarray(_val_of(k2)))


def test_racing_write_drops_deferred_fill():
    """A fill enqueued before a write (tick snapshot) must be dropped at
    drain, not stamped fresh — the conservative race rule."""
    c = cache_mod.BucketCache(P, NSLOTS, VW, capacity=64)
    keys = jnp.asarray(np.arange(1, 1 + P * 4).reshape(P, 4), jnp.int32)
    look = c.lookup(keys)
    assert look is not None and not look.hit.any()
    slot = jnp.zeros((P, 4), jnp.int32)
    found = jnp.ones((P, 4), bool)
    vals = jnp.ones((P, 4, VW), jnp.int32)
    c._pending.append((look.tick, look.keys, look.miss,
                       slot, found, vals))   # enqueue without auto-drain
    c.on_insert_keys(keys)                   # the racing write
    c.drain_fills(force=True)
    assert c.counters["fill_drops"] >= 1
    look2 = c.lookup(keys)
    assert not look2.hit.any(), "racing fill was stamped fresh"


def test_write_heavy_stream_disables_cache_reads():
    """The chooser's fourth-signal guard: a write-heavy stream pushes the
    write EWMA past the threshold and cache reads switch off (decisions
    stop being cached); invalidation keeps running."""
    rng = np.random.default_rng(5)
    used: set = set()
    eng = _engine(nslots=512)
    ht = ht_mod.make_hashtable(P, 512, VW)
    for _ in range(12):
        k = _fresh(rng, (P, 2), used)
        ht, _, _ = eng.ht_insert(ht, k, _val_of(k))
    assert eng.write_ewma > eng.WRITE_HEAVY
    assert not eng.cache_reads_on()
    k = _fresh(rng, (P, 2), used)
    ht, _, _ = eng.ht_insert(ht, k, _val_of(k))
    ht, f, v = eng.ht_find(ht, k)
    assert not eng.last_decision.cached
    assert bool(np.asarray(f).all())
    # a read-heavy stretch re-enables reads
    for _ in range(12):
        ht, _, _ = eng.ht_find(ht, k)
    assert eng.cache_reads_on()
    assert eng.last_decision.cached


def test_tracer_write_invalidates_everything():
    """Writes whose keys are tracers (a jitted insert) cannot bump precise
    probe windows — they must flush the whole cache (correct, never
    fast)."""
    c = cache_mod.BucketCache(P, NSLOTS, VW, capacity=64)
    keys = jnp.asarray(np.arange(1, 1 + P * 4).reshape(P, 4), jnp.int32)
    look = c.lookup(keys)
    c.note_fill(look, jnp.zeros((P, 4), jnp.int32),
                jnp.ones((P, 4), bool), jnp.ones((P, 4, VW), jnp.int32))
    assert c.lookup(keys).hit.all()
    epoch = c.epoch

    @jax.jit
    def traced_write(k):
        c.on_insert_keys(k)   # keys are tracers inside jit
        return k

    traced_write(keys)
    assert c.epoch == epoch + 1
    assert not c.lookup(keys).hit.any()


# ---------------------------------------------------------------------------
# Randomized mixed read/write conformance (oracle == uncached == cached)
# ---------------------------------------------------------------------------
def _mixed_sequence(seed: int, rounds: int = 5):
    rng = np.random.default_rng(seed)
    used: set = set()
    cached = _engine(nslots=128)
    ht_c = ht_mod.make_hashtable(P, 128, VW)
    ht_u = ht_mod.make_hashtable(P, 128, VW)
    oracle = {}
    inserted = []
    for _ in range(rounds):
        k = _fresh(rng, (P, 3), used)
        inserted.append(k)
        ht_c, okc, _ = cached.ht_insert(ht_c, k, _val_of(k))
        ht_u, oku, _ = ht_mod.insert_rdma(ht_u, k, _val_of(k))
        for key in np.asarray(k).ravel().tolist():
            oracle[key] = (key * 31 + 7) & 0x7FFFFF
        np.testing.assert_array_equal(np.asarray(okc), np.asarray(oku))
        # probe: previously inserted + fresh-missing keys, duplicates too
        old = inserted[int(rng.integers(0, len(inserted)))]
        probe = jnp.concatenate([old, old[:, :1], _fresh(rng, (P, 2), used)],
                                axis=1)
        ht_c, fc, vc = cached.ht_find(ht_c, probe)
        ht_u, fu, vu = ht_mod.find_rdma(ht_u, probe)
        np.testing.assert_array_equal(np.asarray(fc), np.asarray(fu))
        np.testing.assert_array_equal(np.asarray(vc), np.asarray(vu))
        pk = np.asarray(probe)
        exp_f = np.vectorize(lambda x: x in oracle)(pk)
        np.testing.assert_array_equal(np.asarray(fc), exp_f)
        exp_v = np.where(exp_f, (pk * 31 + 7) & 0x7FFFFF, 0)
        np.testing.assert_array_equal(np.asarray(vc)[..., 0], exp_v)
    assert cached.cache.counters["hits"] > 0, "sequence never hit the cache"


def test_mixed_read_write_sequences_conformant():
    for seed in (0, 1, 2):
        _mixed_sequence(seed)


# Property-based deepening when hypothesis is available (optional dev dep,
# as in test_properties.py — the seeded loop above always runs). Module-
# level importorskip would skip the whole file, so guard just this test.
try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(min_value=0, max_value=10_000))
    def test_mixed_sequences_property(seed):
        _mixed_sequence(seed, rounds=3)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_mixed_sequences_property():
        pass
