"""End-to-end behaviour: the data pipeline's phasal DQueue handoff, a real
reduced training run with decreasing loss, serve-path sanity, and the
paper-facing integration points (backend auto-chooser wired into models)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import costmodel as cm
from repro.core.types import Backend, OpStats, Promise
from repro.data import QueuedPipeline, SyntheticLM
from repro.launch import train as train_mod
from repro.models import lm


def test_pipeline_queue_phases():
    pipe = QueuedPipeline(nranks=4, host=0, capacity=256)
    ok = pipe.produce(steps=range(8), hosts_per_step=4)
    assert int(ok.sum()) == 32
    got, vals = pipe.consume(n_per_rank=8)
    descs = np.asarray(vals[np.asarray(got)])
    assert descs.shape == (32, 3)
    # every (step, host) descriptor delivered exactly once
    seen = {(int(s), int(h)) for s, h, _ in descs}
    assert seen == {(s, h) for s in range(8) for h in range(4)}


def test_training_reduces_loss():
    losses = train_mod.main(["--arch", "smollm-135m", "--reduced",
                             "--steps", "30", "--batch", "8",
                             "--seq", "64", "--lr", "3e-3"])
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.05, (first, last)


def test_serve_runs_and_is_deterministic():
    from repro.launch import serve as serve_mod
    g1 = serve_mod.main(["--arch", "smollm-135m", "--reduced",
                         "--batch", "2", "--prompt-len", "4",
                         "--gen-len", "6"])
    g2 = serve_mod.main(["--arch", "smollm-135m", "--reduced",
                         "--batch", "2", "--prompt-len", "4",
                         "--gen-len", "6"])
    np.testing.assert_array_equal(g1, g2)


def test_backend_chooser_prefers_rpc_when_target_attentive():
    """Paper Fig. 6 logic end-to-end: attentive target -> RPC wins the
    insert (1 round trip); busy target -> RDMA wins."""
    attentive = OpStats(target_busy_us=0.0)
    busy = OpStats(target_busy_us=30.0)
    assert cm.choose_backend(cm.DSOp.HT_INSERT, Promise.CRW,
                             attentive) == Backend.RPC
    assert cm.choose_backend(cm.DSOp.HT_INSERT, Promise.CRW,
                             busy) == Backend.RDMA


def test_moe_auto_backend_picks_rpc_at_scale():
    """At the assigned workloads the cost model always ships tokens
    (all_to_all), never gathers 1GB of expert weights — the paper's
    move-data-vs-move-compute tradeoff resolved at pod scale."""
    cfg = registry.get("deepseek-moe-16b")
    b = lm._moe_backend(cfg, tokens=4096 * 32)
    assert b == Backend.RPC


def test_decode_auto_backend_picks_rpc_for_long_caches():
    cfg = registry.get("granite-3-8b")
    assert lm._decode_backend(cfg, kv_len=32768, batch=128) == Backend.RPC


def test_runnable_cells_cover_assignment():
    cells = registry.runnable_cells()
    assert len(cells) == 32
    assert len(registry.skipped_cells()) == 8
    # every arch contributes
    archs = {a for a, _ in cells}
    assert len(archs) == 10
    # long_500k runs exactly for the sub-quadratic archs
    longs = {a for a, s in cells if s == "long_500k"}
    assert longs == {"recurrentgemma-9b", "xlstm-1.3b"}
