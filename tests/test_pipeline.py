"""Pipelined batch engine (DESIGN.md §7): async front-end conformance,
deferred-AM dispatch points, host-side plan construction, the slot-tagged
phase log, and the overlap cost model.

The §7 contracts pinned here:
  * submission order IS serialization order: async == sync == same values
    and same final window state, on randomized interleaved submit streams
    with out-of-order `result()` forcing, at any depth;
  * depth=1 degenerates to the synchronous lock-step engine bit-exactly;
  * deferred (AM-arm) batches wait for a dispatch point and drain FIFO —
    the paper's attentiveness as an explicit queue;
  * `routing.make_plan_np` (plan construction on the host thread) is
    bit-identical to `make_plan`;
  * pipelining changes the dependency structure, never the §2 exchange
    counts, and every slot's phases are attributable via the phase log;
  * the cost model's overlap term: T(1) == the flat sum exactly,
    max(A,B) <= T(d) <= A+B, and owner-heavy arms (AM under poor
    attentiveness) gain the most — the chooser can flip to AM at depth 2.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import adaptive as ad_mod
from repro.core import am as am_mod
from repro.core import costmodel as cm
from repro.core import hashtable as ht_mod
from repro.core import pipeline as pl_mod
from repro.core import queue as q_mod
from repro.core import routing, window
from repro.core.types import OpStats, Promise

P = 4
VW = 2


def _mk_ht(nslots=64):
    return ht_mod.make_hashtable(P, nslots, VW)


def _batch(rng, n=8, dup=False):
    if dup:
        universe = rng.integers(1, 1 << 20, 6).astype(np.int32)
        keys = rng.choice(universe, size=(P, n)).astype(np.int32)
    else:
        keys = rng.integers(1, (1 << 31) - 2, (P, n)).astype(np.int32)
    vals = (keys[..., None] * np.arange(1, VW + 1)).astype(np.int32)
    return jnp.asarray(keys), jnp.asarray(vals)


# ---------------------------------------------------------------------------
# Host-side plan construction / placement mirrors
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_make_plan_np_bitexact(seed):
    """make_plan_np == make_plan on every RoutePlan field, including
    capacity drops and invalid rows."""
    rng = np.random.default_rng(seed)
    n, cap = 10, 6   # cap < n forces capacity drops
    dst = jnp.asarray(rng.integers(0, P, (P, n)), jnp.int32)
    valid = jnp.asarray(rng.random((P, n)) < 0.8)
    a = routing.make_plan(dst, valid, cap=cap)
    b = routing.make_plan_np(np.asarray(dst), np.asarray(valid), cap=cap)
    for field in ("dst_eff", "op_slot", "op_ok", "mask", "dropped"):
        assert np.array_equal(np.asarray(getattr(a, field)),
                              np.asarray(getattr(b, field))), field
    assert a.cap == b.cap


def test_place_np_matches_engine():
    rng = np.random.default_rng(0)
    ht = _mk_ht()
    keys = rng.integers(1, (1 << 31) - 2, (P, 32)).astype(np.int32)
    o_np, s_np = ht_mod.place_np(ht.nranks, ht.nslots, keys)
    o_j, s_j = ht_mod._place(ht, jnp.asarray(keys))
    assert np.array_equal(o_np, np.asarray(o_j))
    assert np.array_equal(s_np, np.asarray(s_j))


# ---------------------------------------------------------------------------
# Async front-end conformance
# ---------------------------------------------------------------------------
def _sync_replay(ht, ops, engine=None):
    """Run an op stream through the synchronous front-ends, in order."""
    outs = []
    for kind, args, kw in ops:
        if kind == "insert":
            ht, ok, probes = ht_mod.insert(ht, *args, engine=engine, **kw)
            outs.append((ok, probes))
        else:
            ht, found, vals = ht_mod.find(ht, *args, engine=engine, **kw)
            outs.append((found, vals))
    return ht, outs


def _assert_tree_equal(a, b, msg=""):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb), msg
    for x, y in zip(la, lb):
        assert np.array_equal(np.asarray(x), np.asarray(y)), msg


@pytest.mark.parametrize("depth", [1, 2, 3])
def test_async_depth_bitexact_vs_sync(depth):
    """insert_async/find_async == insert/find in submission order, at any
    depth, including the final window state (depth-1 = lock-step)."""
    rng = np.random.default_rng(depth)
    ht0 = _mk_ht()
    k1, v1 = _batch(rng)
    k2, v2 = _batch(rng)
    ops = [("insert", (k1, v1), {"backend": "rdma"}),
           ("find", (k1,), {"backend": "rdma"}),
           ("insert", (k2, v2), {"backend": "rdma", "fused": False}),
           ("find", (k2,), {"backend": "rdma", "promise": Promise.CRW})]
    pipe = pl_mod.Pipeline(ht0, depth=depth)
    handles = []
    for kind, args, kw in ops:
        fn = ht_mod.insert_async if kind == "insert" else ht_mod.find_async
        handles.append(fn(pipe, *args, **kw))
    ht_sync, outs = _sync_replay(ht0, ops)
    for h, o in zip(handles, outs):
        _assert_tree_equal(h.result(), o, f"depth={depth} seq={h.seq}")
    assert np.array_equal(np.asarray(pipe.flush().win.data),
                          np.asarray(ht_sync.win.data))


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_async_randomized_out_of_order_forcing(seed):
    """Randomized interleaved submit stream (dup keys, mixed fused /
    coalesced arms), forced in RANDOM order: every handle's value and the
    final state match the in-order synchronous replay."""
    rng = np.random.default_rng(seed)
    ht0 = _mk_ht()
    ops = []
    for _ in range(6):
        dup = bool(rng.integers(0, 2))
        k, v = _batch(rng, dup=dup)
        kw = {"backend": "rdma", "fused": bool(rng.integers(0, 2))}
        if kw["fused"] and dup:
            kw["coalesce"] = bool(rng.integers(0, 2))
        if rng.integers(0, 2):
            ops.append(("insert", (k, v), kw))
        else:
            ops.append(("find", (k,), kw))
    pipe = pl_mod.Pipeline(ht0, depth=2)
    handles = []
    for kind, args, kw in ops:
        fn = ht_mod.insert_async if kind == "insert" else ht_mod.find_async
        handles.append(fn(pipe, *args, **kw))
    ht_sync, outs = _sync_replay(ht0, ops)
    order = rng.permutation(len(handles))
    for i in order:
        _assert_tree_equal(handles[i].result(), outs[i], f"op {i}")
    # repeated result() is idempotent
    _assert_tree_equal(handles[int(order[0])].result(), outs[int(order[0])])
    assert np.array_equal(np.asarray(pipe.flush().win.data),
                          np.asarray(ht_sync.win.data))


@pytest.mark.parametrize("depth", [1, 2])
def test_deferred_am_dispatch_points(depth):
    """AM-arm submissions queue on the AMEngine and drain at the next
    dispatch point (eager submit / result / flush); values and state match
    the in-order synchronous replay. At depth 1 every submit completes its
    batch — the lock-step engine is fully attentive by construction."""
    rng = np.random.default_rng(7)
    ht0 = _mk_ht()
    k1, v1 = _batch(rng)
    k2, _ = _batch(rng)

    eng = am_mod.AMEngine(P)
    ht_mod.build_am_handlers(ht0, eng)
    pipe = pl_mod.Pipeline(ht0, depth=depth, am_engine=eng)
    pts0 = eng.dispatch_points
    h1 = ht_mod.insert_async(pipe, k1, v1, backend="rpc")
    if depth == 1:
        assert pipe.pending_deferred == 0      # submit forced it already
        assert h1.done()
    else:
        assert pipe.pending_deferred == 1
        assert not h1.done()
    h2 = ht_mod.find_async(pipe, k1, backend="rdma")  # eager: dispatch point
    assert pipe.pending_deferred == 0
    assert eng.dispatch_points > pts0
    h3 = ht_mod.find_async(pipe, k2, backend="rpc")   # stays queued (depth>1)
    out3 = h3.result()                                # result = dispatch point
    assert pipe.pending_deferred == 0

    eng_s = am_mod.AMEngine(P)
    ht_mod.build_am_handlers(ht0, eng_s)
    ht_sync, outs = _sync_replay(
        ht0, [("insert", (k1, v1), {"backend": "rpc"}),
              ("find", (k1,), {"backend": "rdma"}),
              ("find", (k2,), {"backend": "rpc"})], engine=eng_s)
    _assert_tree_equal(h1.result(), outs[0])
    _assert_tree_equal(h2.result(), outs[1])
    _assert_tree_equal(out3, outs[2])
    assert np.array_equal(np.asarray(pipe.flush().win.data),
                          np.asarray(ht_sync.win.data))


def test_queue_async_conformance():
    rng = np.random.default_rng(3)
    q0 = q_mod.make_queue(P, 0, 64, VW)
    v1 = jnp.asarray(rng.integers(1, 100, (P, 6, VW)).astype(np.int32))
    v2 = jnp.asarray(rng.integers(1, 100, (P, 6, VW)).astype(np.int32))
    pipe = pl_mod.Pipeline(q0, depth=2)
    h1 = q_mod.push_async(pipe, v1, backend="rdma")
    h2 = q_mod.pop_async(pipe, 4, backend="rdma")
    h3 = q_mod.push_async(pipe, v2, backend="rdma")
    h4 = q_mod.pop_async(pipe, 8, backend="rdma")
    q_s, ok1 = q_mod.push(q0, v1, backend="rdma")
    q_s, got2, vals2 = q_mod.pop(q_s, 4, backend="rdma")
    q_s, ok3 = q_mod.push(q_s, v2, backend="rdma")
    q_s, got4, vals4 = q_mod.pop(q_s, 8, backend="rdma")
    _assert_tree_equal(h4.result(), (got4, vals4))   # out of order
    _assert_tree_equal(h1.result(), ok1)
    _assert_tree_equal(h3.result(), ok3)
    _assert_tree_equal(h2.result(), (got2, vals2))
    assert np.array_equal(np.asarray(pipe.flush().win.data),
                          np.asarray(q_s.win.data))


def test_auto_backend_async_conforms():
    """backend=AUTO through the pipeline (model-only decisions, depth
    pricing on): values match a synchronous AUTO replay with its own
    fresh chooser — the §4 conformance domain extended to §7."""
    rng = np.random.default_rng(11)
    ht0 = _mk_ht()
    k1, v1 = _batch(rng)

    eng = am_mod.AMEngine(P)
    ht_mod.build_am_handlers(ht0, eng)
    a = ad_mod.AdaptiveEngine(P, am_engine=eng)
    pipe = pl_mod.Pipeline(ht0, depth=2, am_engine=eng)
    h1 = ht_mod.insert_async(pipe, k1, v1, adaptive=a)
    h2 = ht_mod.find_async(pipe, k1, adaptive=a)
    ok, probes = h1.result()
    found, vals = h2.result()
    assert a.log, "AUTO submissions must log Decisions"
    assert all(d.skew >= 1.0 for d in a.log)

    eng_s = am_mod.AMEngine(P)
    ht_mod.build_am_handlers(ht0, eng_s)
    a_s = ad_mod.AdaptiveEngine(P, am_engine=eng_s)
    ht_s, ok_s, _ = ht_mod.insert(ht0, k1, v1, adaptive=a_s)
    _, found_s, vals_s = ht_mod.find(ht_s, k1, adaptive=a_s)
    assert np.array_equal(np.asarray(ok), np.asarray(ok_s))
    assert np.array_equal(np.asarray(found), np.asarray(found_s))
    assert np.array_equal(np.asarray(vals), np.asarray(vals_s))


def test_pipeline_depth_validation():
    with pytest.raises(ValueError):
        pl_mod.Pipeline(_mk_ht(), depth=0)


# ---------------------------------------------------------------------------
# Slot-tagged phase log + exchange counts
# ---------------------------------------------------------------------------
def test_slot_tagged_phase_log():
    """Every phase issued inside a pipeline slot carries {slot, seq}; two
    in-flight windows alternate slots 0/1 at depth 2 and each batch's
    per-slot phase sequence equals the synchronous engine's."""
    rng = np.random.default_rng(0)
    dst = jnp.asarray(rng.integers(0, P, (P, 6)), jnp.int32)
    off = jnp.asarray(rng.integers(0, 16, (P, 6)), jnp.int32)
    vals = jnp.ones((P, 6, 1), jnp.int32)
    win0 = window.make_window(P, 32)

    def op(w):
        w2 = window.rdma_put(w, dst, off, vals)
        out = window.rdma_get(w2, dst, off, 1)
        return w2, out

    window.drain_phase_log()
    pipe = pl_mod.Pipeline(win0, depth=2)
    pipe.submit(op)
    pipe.submit(op)
    pipe.flush()
    log = window.drain_phase_log()
    tags = [(role, info["slot"], info["seq"]) for role, _, info in log]
    assert tags == [("put", 0, 0), ("get", 0, 0),
                    ("put", 1, 1), ("get", 1, 1)]


def test_pipelining_adds_zero_exchanges():
    """The §2/§7 invariant: a depth-2 stream issues exactly the exchanges
    of the same batches run synchronously — overlap changes the dependency
    structure, never the phase count."""
    rng = np.random.default_rng(1)
    dst = jnp.asarray(rng.integers(0, P, (P, 6)), jnp.int32)
    off = jnp.asarray(rng.integers(0, 16, (P, 6)), jnp.int32)
    vals = jnp.ones((P, 6, 1), jnp.int32)
    win0 = window.make_window(P, 32)

    roles = []

    def hook(x, role):
        if role.endswith("_pre"):
            roles.append(role[:-4])
        return x

    def op(w):
        w2 = window.rdma_put(w, dst, off, vals)
        return w2, window.rdma_get(w2, dst, off, 1)

    with routing.sharding_hook(hook):
        w = win0
        for _ in range(2):
            w, out = op(w)
        jax.block_until_ready((w, out))
    sync_roles = list(roles)

    roles.clear()
    with routing.sharding_hook(hook):
        pipe = pl_mod.Pipeline(win0, depth=2)
        pipe.submit(op)
        pipe.submit(op)
        pipe.flush()
    assert roles == sync_roles


# ---------------------------------------------------------------------------
# Overlap cost model (§7)
# ---------------------------------------------------------------------------
ALL_OPS = [(cm.DSOp.HT_INSERT, Promise.CRW), (cm.DSOp.HT_FIND, Promise.CR),
           (cm.DSOp.HT_FIND, Promise.CRW), (cm.DSOp.Q_PUSH, Promise.CRW),
           (cm.DSOp.Q_POP, Promise.CR)]


@pytest.mark.parametrize("op,promise", ALL_OPS)
@pytest.mark.parametrize("arm", cm.ARMS)
def test_overlap_split_sums_to_flat(op, promise, arm):
    s = OpStats(skew=3.0, dedup=0.5, target_busy_us=5.0)
    for params in (cm.CORI_PHASE1, cm.TPU_V5E_ICI):
        flat = cm._predict_arm_flat(op, promise, arm, s, params)
        a, b = cm.overlap_split(op, promise, arm, s, params)
        assert a >= 0 and b >= 0
        assert abs((a + b) - flat) < 1e-9
        # depth-1 degenerates exactly; deeper pipelines are bounded by
        # [max(A,B), A+B] and monotone non-increasing in depth
        assert abs(cm.predict_pipelined(op, promise, arm, s, params,
                                        depth=1) - flat) < 1e-9
        prev = flat
        for d in (2, 3, 8):
            t = cm.predict_pipelined(op, promise, arm, s, params, depth=d)
            assert max(a, b) - 1e-9 <= t <= prev + 1e-9
            prev = t


def test_predict_arm_reads_depth_from_stats():
    s1 = OpStats(skew=4.0, target_busy_us=10.0)
    s2 = OpStats(skew=4.0, target_busy_us=10.0, pipeline_depth=2)
    flat = cm.predict_arm(cm.DSOp.HT_INSERT, Promise.CRW, "am", s1,
                          cm.TPU_V5E_ICI)
    piped = cm.predict_arm(cm.DSOp.HT_INSERT, Promise.CRW, "am", s2,
                           cm.TPU_V5E_ICI)
    assert piped < flat   # attentiveness + handler latency get hidden
    assert abs(piped - cm.predict_pipelined(
        cm.DSOp.HT_INSERT, Promise.CRW, "am", s2, cm.TPU_V5E_ICI)) < 1e-12


def test_overlap_flips_chooser_to_am():
    """The §7 headline: an owner-heavy AM arm (big attentiveness wait,
    handler work) loses to fused RDMA lock-step but wins once depth-2
    overlap hides its owner-side latency behind the next batch's
    route+send."""
    p = cm.ComponentCosts(W=1, R=2, A_cas=2.3, A_fao=2.3, am_rt=6.0,
                          handler=0.5, amo_apply=1.0)
    flat = OpStats(skew=8.0, target_busy_us=4.0)
    piped = OpStats(skew=8.0, target_busy_us=4.0, pipeline_depth=2)
    args = (cm.DSOp.HT_INSERT, Promise.CRW)
    r1 = cm.predict_arm(*args, "rdma_fused", flat, p)
    a1 = cm.predict_arm(*args, "am", flat, p)
    r2 = cm.predict_arm(*args, "rdma_fused", piped, p)
    a2 = cm.predict_arm(*args, "am", piped, p)
    assert r1 < a1, "lock-step should prefer fused RDMA here"
    assert a2 < r2, "depth-2 overlap should flip the choice to AM"


def test_peek_arm_matches_decide_without_logging():
    a = ad_mod.AdaptiveEngine(P)   # one-sided arms only, model scores
    s = OpStats(skew=2.0, pipeline_depth=2)
    peeked = a.peek_arm(cm.DSOp.HT_INSERT, Promise.CRW, s)
    assert not a.log
    dec = a.decide(cm.DSOp.HT_INSERT, Promise.CRW, stats=s)
    assert dec.arm == peeked
    assert len(a.log) == 1
    a.force_arm = "rdma"
    assert a.peek_arm(cm.DSOp.HT_INSERT, Promise.CRW, s) == "rdma"
