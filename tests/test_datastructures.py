"""Distributed data-structure semantics: RDMA backend == RPC backend ==
python oracle, across promise levels (paper Tables II/III structures)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import am as am_mod
from repro.core import hashtable as ht_mod
from repro.core import queue as q_mod
from repro.core import routing, window
from repro.core.types import AmoKind, Promise


P = 4


# ---------------------------------------------------------------------------
# Routing engine
# ---------------------------------------------------------------------------
def test_route_delivers_every_valid_op():
    rng = np.random.default_rng(0)
    dst = jnp.asarray(rng.integers(0, P, (P, 9)), jnp.int32)
    payload = jnp.asarray(rng.integers(0, 100, (P, 9, 2)), jnp.int32)
    routed = routing.route(dst, payload, cap=9)
    assert int(routed.dropped.sum()) == 0
    assert bool(routed.op_ok.all())
    # every payload word appears exactly once at its owner
    flat, mask = routing.flatten_owner_view(routed)
    got = np.sort(np.asarray(flat[np.asarray(mask)])[:, 0])
    want = np.sort(np.asarray(payload[..., 0]).ravel())
    np.testing.assert_array_equal(got, want)


def test_route_capacity_drops_are_reported():
    dst = jnp.zeros((P, 8), jnp.int32)          # everyone targets rank 0
    payload = jnp.ones((P, 8, 1), jnp.int32)
    routed = routing.route(dst, payload, cap=3)
    # per-origin cap of 3 toward one destination -> 5 dropped per origin
    assert int(routed.dropped.sum()) == P * 5


def test_reply_routing_aligns_with_op_order():
    rng = np.random.default_rng(1)
    dst = jnp.asarray(rng.integers(0, P, (P, 6)), jnp.int32)
    off = jnp.asarray(rng.integers(0, 32, (P, 6)), jnp.int32)
    win = window.make_window(P, 32)
    # write rank*1000+off at each location, then get and check
    base = jnp.arange(P)[:, None] * 1000 + jnp.arange(32)[None]
    win = window.Window(data=base.astype(jnp.int32))
    got = window.rdma_get(win, dst, off, width=1)[..., 0]
    want = dst * 1000 + off
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# Route plans (DESIGN.md §2): reuse is bit-exact vs a fresh route per phase
# ---------------------------------------------------------------------------
def test_route_plan_matches_fresh_route():
    rng = np.random.default_rng(4)
    dst = jnp.asarray(rng.integers(0, P, (P, 9)), jnp.int32)
    payload = jnp.asarray(rng.integers(0, 100, (P, 9, 2)), jnp.int32)
    fresh = routing.route(dst, payload, cap=9)
    plan = routing.make_plan(dst, cap=9)
    planned = routing.route_with_plan(plan, payload)
    np.testing.assert_array_equal(np.asarray(fresh.at_owner),
                                  np.asarray(planned.at_owner))
    np.testing.assert_array_equal(np.asarray(fresh.mask),
                                  np.asarray(planned.mask))
    np.testing.assert_array_equal(np.asarray(fresh.op_slot),
                                  np.asarray(planned.op_slot))
    np.testing.assert_array_equal(np.asarray(fresh.op_ok),
                                  np.asarray(planned.op_ok))


def test_route_plan_shrinking_active_masks_out_ops():
    """A shrinking probe-loop mask ANDs into the plan occupancy: inactive
    ops vanish from the owner view but active ops keep their (src, slot)
    serialization positions."""
    rng = np.random.default_rng(5)
    dst = jnp.asarray(rng.integers(0, P, (P, 8)), jnp.int32)
    payload = jnp.asarray(rng.integers(1, 100, (P, 8, 1)), jnp.int32)
    active = jnp.asarray(rng.random((P, 8)) > 0.5)
    plan = routing.make_plan(dst, cap=8)
    planned = routing.route_with_plan(plan, payload, active=active)
    # owner view contains exactly the active payload words
    flat, mask = routing.flatten_owner_view(planned)
    got = np.sort(np.asarray(flat[np.asarray(mask)])[:, 0])
    want = np.sort(np.asarray(payload[..., 0])[np.asarray(active)].ravel())
    np.testing.assert_array_equal(got, want)
    # active ops occupy the same slots as in the full-batch plan
    np.testing.assert_array_equal(np.asarray(planned.op_slot),
                                  np.asarray(plan.op_slot))
    np.testing.assert_array_equal(
        np.asarray(planned.op_ok), np.asarray(plan.op_ok & active))


def test_planned_fao_matches_unplanned_under_shrinking_mask():
    rng = np.random.default_rng(6)
    dst = jnp.asarray(rng.integers(0, P, (P, 6)), jnp.int32)
    off = jnp.asarray(rng.integers(0, 16, (P, 6)), jnp.int32)
    masks = [jnp.asarray(rng.random((P, 6)) > t) for t in (0.0, 0.4, 0.8)]
    win_a = window.make_window(P, 16)
    win_b = window.make_window(P, 16)
    plan = routing.make_plan(dst, cap=6)
    for m in masks:
        old_a, win_a = window.rdma_fao(win_a, dst, off, 1, AmoKind.FAA,
                                       valid=m)
        old_b, win_b = window.rdma_fao(win_b, dst, off, 1, AmoKind.FAA,
                                       valid=m, plan=plan)
        np.testing.assert_array_equal(
            np.asarray(old_a)[np.asarray(m)], np.asarray(old_b)[np.asarray(m)])
        np.testing.assert_array_equal(np.asarray(win_a.data),
                                      np.asarray(win_b.data))


# ---------------------------------------------------------------------------
# One-sided AMOs
# ---------------------------------------------------------------------------
def test_faa_tickets_are_unique_and_dense():
    win = window.make_window(P, 4)
    dst = jnp.zeros((P, 3), jnp.int32)
    off = jnp.zeros((P, 3), jnp.int32)
    old, win = window.rdma_fao(win, dst, off, 1, AmoKind.FAA)
    tickets = np.sort(np.asarray(old).ravel())
    np.testing.assert_array_equal(tickets, np.arange(P * 3))
    assert int(win.data[0, 0]) == P * 3


def test_cas_exactly_one_winner():
    win = window.make_window(P, 2)
    dst = jnp.zeros((P, 2), jnp.int32)
    off = jnp.zeros((P, 2), jnp.int32)
    old, win = window.rdma_cas(win, dst, off, 0, 7)
    winners = int((np.asarray(old) == 0).sum())
    assert winners == 1
    assert int(win.data[0, 0]) == 7


def test_fao_variants_match_numpy():
    rng = np.random.default_rng(2)
    for kind, op in [(AmoKind.FOR, np.bitwise_or),
                     (AmoKind.FAND, np.bitwise_and),
                     (AmoKind.FXOR, np.bitwise_xor)]:
        init = rng.integers(0, 2**20, (P, 8)).astype(np.int32)
        win = window.Window(data=jnp.asarray(init))
        dst = jnp.asarray(rng.integers(0, P, (P, 5)), jnp.int32)
        off = jnp.asarray(rng.integers(0, 8, (P, 5)), jnp.int32)
        operand = jnp.asarray(rng.integers(0, 2**20, (P, 5)), jnp.int32)
        _, win2 = window.rdma_fao(win, dst, off, operand, kind)
        expect = init.copy()
        for p in range(P):
            for i in range(5):
                d, o = int(dst[p, i]), int(off[p, i])
                expect[d, o] = op(expect[d, o], int(operand[p, i]))
        np.testing.assert_array_equal(np.asarray(win2.data), expect)


# ---------------------------------------------------------------------------
# Hash table
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fused", [False, True])
@pytest.mark.parametrize("backend", ["rdma_crw", "rdma_cw", "rpc"])
def test_hashtable_insert_find_roundtrip(backend, fused):
    rng = np.random.default_rng(3)
    keys = jnp.asarray(rng.permutation(10000)[:P * 8].reshape(P, 8) + 1,
                       jnp.int32)
    vals = jnp.stack([keys * 2, keys + 5], axis=-1)
    ht = ht_mod.make_hashtable(P, 64, 2)
    if backend == "rpc":
        if fused:
            pytest.skip("no fused variant of the RPC path")
        eng = am_mod.AMEngine(P)
        ht_mod.build_am_handlers(ht, eng)
        ht, ok, probes = ht_mod.insert_rpc(ht, eng, keys, vals)
        assert bool((probes[np.asarray(ok)] >= 1).all())
        found, got = ht_mod.find_rpc(ht, eng, keys)
    else:
        promise = Promise.CRW if backend == "rdma_crw" else Promise.CW
        ht, ok, probes = ht_mod.insert_rdma(ht, keys, vals, promise=promise,
                                            fused=fused)
        ht, found, got = ht_mod.find_rdma(ht, keys, promise=Promise.CR,
                                          fused=fused)
    assert bool(ok.all()) and bool(found.all())
    np.testing.assert_array_equal(np.asarray(got[..., 0]),
                                  np.asarray(keys * 2))
    # misses stay misses
    if backend == "rpc":
        found2, _ = ht_mod.find_rpc(ht, eng, keys + 100000)
    else:
        ht, found2, _ = ht_mod.find_rdma(ht, keys + 100000,
                                         promise=Promise.CR, fused=fused)
    assert not bool(found2.any())


@pytest.mark.parametrize("fused", [False, True])
def test_hashtable_crw_find_with_lock(fused):
    keys = jnp.arange(P * 4, dtype=jnp.int32).reshape(P, 4) + 1
    vals = jnp.stack([keys, keys], axis=-1)
    ht = ht_mod.make_hashtable(P, 32, 2)
    ht, ok, _ = ht_mod.insert_rdma(ht, keys, vals, promise=Promise.CRW,
                                   fused=fused)
    ht, found, got = ht_mod.find_rdma(ht, keys, promise=Promise.CRW,
                                      fused=fused)
    assert bool(found.all())
    # read locks fully released: flag state back to READY with no readers
    recs = ht.win.data.reshape(P, ht.nslots, ht.rec_w)
    flags = np.asarray(recs[..., 0])
    assert ((flags == 0) | (flags == 2)).all()


def test_hashtable_rpc_insert_or_assign_updates():
    """RPC expressivity (paper §II-B): handler does insert-or-assign."""
    eng = am_mod.AMEngine(P)
    ht = ht_mod.make_hashtable(P, 32, 1)
    ht_mod.build_am_handlers(ht, eng)
    keys = jnp.arange(P * 2, dtype=jnp.int32).reshape(P, 2) + 1
    ht, ok1, _ = ht_mod.insert_rpc(ht, eng, keys, keys[..., None] * 10)
    ht, ok2, _ = ht_mod.insert_rpc(ht, eng, keys, keys[..., None] * 20)
    assert bool(ok1.all()) and bool(ok2.all())
    found, got = ht_mod.find_rpc(ht, eng, keys)
    np.testing.assert_array_equal(np.asarray(got[..., 0]),
                                  np.asarray(keys * 20))


# ---------------------------------------------------------------------------
# Fused component phases: bit-exact vs the unfused per-component sequences,
# and the exchange counts the cost model promises
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("promise", [Promise.CRW, Promise.CW])
def test_fused_insert_bit_exact_vs_unfused(promise):
    """Fused claim/write(/publish) == CAS + W (+ FXOR) on a contended batch
    (many keys collide into few slots, so probe chains interleave)."""
    rng = np.random.default_rng(8)
    keys = jnp.asarray(rng.permutation(4000)[:P * 8].reshape(P, 8) + 1,
                       jnp.int32)
    vals = jnp.stack([keys * 3, keys - 7], axis=-1)
    ht_a = ht_mod.make_hashtable(P, 8, 2)    # tiny table -> contention
    ht_b = ht_mod.make_hashtable(P, 8, 2)
    ht_a, ok_a, pr_a = ht_mod.insert_rdma(ht_a, keys, vals, promise=promise,
                                          max_probes=8, fused=False)
    ht_b, ok_b, pr_b = ht_mod.insert_rdma(ht_b, keys, vals, promise=promise,
                                          max_probes=8, fused=True)
    np.testing.assert_array_equal(np.asarray(ht_a.win.data),
                                  np.asarray(ht_b.win.data))
    np.testing.assert_array_equal(np.asarray(ok_a), np.asarray(ok_b))
    np.testing.assert_array_equal(np.asarray(pr_a), np.asarray(pr_b))


@pytest.mark.parametrize("promise", [Promise.CR, Promise.CRW])
def test_fused_find_bit_exact_vs_unfused(promise):
    rng = np.random.default_rng(9)
    keys = jnp.asarray(rng.permutation(4000)[:P * 6].reshape(P, 6) + 1,
                       jnp.int32)
    vals = jnp.stack([keys, keys * 2], axis=-1)
    ht = ht_mod.make_hashtable(P, 16, 2)
    ht, ok, _ = ht_mod.insert_rdma(ht, keys, vals, promise=Promise.CRW)
    probe = jnp.where(jnp.arange(P * 6).reshape(P, 6) % 2 == 0, keys,
                      keys + 100000)   # mix of hits and misses
    ht_a, f_a, v_a = ht_mod.find_rdma(ht, probe, promise=promise,
                                      fused=False)
    ht_b, f_b, v_b = ht_mod.find_rdma(ht, probe, promise=promise,
                                      fused=True)
    np.testing.assert_array_equal(np.asarray(f_a), np.asarray(f_b))
    np.testing.assert_array_equal(np.asarray(v_a), np.asarray(v_b))
    np.testing.assert_array_equal(np.asarray(ht_a.win.data),
                                  np.asarray(ht_b.win.data))


def _count_exchanges(fn):
    """Run fn under a sharding hook that counts routing.exchange calls."""
    count = [0]

    def hook(x, role):
        if role.endswith("_pre"):
            count[0] += 1
        return x

    with routing.sharding_hook(hook):
        jax.block_until_ready(fn())
    return count[0]


def test_exchange_counts_agree_with_costmodel():
    """The engine's actual all-to-all count matches costmodel.exchange_count
    — the roofline collective counter and the model see the same phase
    structure (C_RW find: 4 exchanges/probe fused, was 9 engine-level /
    6 paper-level)."""
    from repro.core import costmodel as cm
    from repro.core.types import Backend
    keys = jnp.arange(P * 4, dtype=jnp.int32).reshape(P, 4) + 1
    vals = jnp.stack([keys, keys], axis=-1)
    ht, _, _ = ht_mod.insert_rdma(ht_mod.make_hashtable(P, 32, 2), keys,
                                  vals, promise=Promise.CRW)

    for fused in (False, True):
        got = _count_exchanges(lambda: ht_mod.find_rdma(
            ht, keys, promise=Promise.CRW, max_probes=1,
            fused=fused)[1])
        want = cm.exchange_count(cm.DSOp.HT_FIND, Promise.CRW, Backend.RDMA,
                                 fused=fused, probes=1)
        if fused:
            want += cm.PLAN_EXCHANGES
        assert got == want, (fused, got, want)
    assert cm.exchange_count(cm.DSOp.HT_FIND, Promise.CRW, Backend.RDMA,
                             fused=True) <= 4

    for fused in (False, True):
        got = _count_exchanges(lambda: ht_mod.insert_rdma(
            ht_mod.make_hashtable(P, 32, 2), keys, vals,
            promise=Promise.CRW, max_probes=1, fused=fused)[0].win.data)
        want = cm.exchange_count(cm.DSOp.HT_INSERT, Promise.CRW,
                                 Backend.RDMA, fused=fused, probes=1)
        if fused:
            want += cm.PLAN_EXCHANGES
        assert got == want, (fused, got, want)


# ---------------------------------------------------------------------------
# Queue
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("promise", [Promise.CRW, Promise.CW])
def test_queue_push_pop_conservation(promise):
    q = q_mod.make_queue(P, host=1, capacity=128, val_words=1)
    vals = jnp.arange(P * 5, dtype=jnp.int32).reshape(P, 5, 1) + 1
    q, ok = q_mod.push_rdma(q, vals, promise=promise)
    assert bool(ok.all())
    q, got, out = q_mod.pop_rdma(q, 6, promise=Promise.CR)
    popped = np.asarray(out[np.asarray(got)]).ravel()
    np.testing.assert_array_equal(np.sort(popped),
                                  np.arange(P * 5) + 1)


def test_queue_checksum_crw_push_costs_no_ready_cas():
    """Checksum queue (paper Fig. 4): reader verifies payload checksum, so
    the push is FAO + W (phases reported by the cost model), yet pops are
    still safe."""
    q = q_mod.make_queue(P, host=0, capacity=64, val_words=2, checksum=True)
    vals = jnp.arange(P * 4 * 2, dtype=jnp.int32).reshape(P, 4, 2)
    q, ok = q_mod.push_rdma(q, vals, promise=Promise.CRW)
    assert bool(ok.all())
    q, got, out = q_mod.pop_rdma(q, 5, promise=Promise.CRW)
    assert int(got.sum()) == P * 4


def test_queue_overflow_reports_failure():
    q = q_mod.make_queue(P, host=0, capacity=6, val_words=1)
    vals = jnp.ones((P, 4, 1), jnp.int32)
    q, ok = q_mod.push_rdma(q, vals, promise=Promise.CW)
    assert int(ok.sum()) == 6                 # ring held exactly capacity
    assert int((~ok).sum()) == P * 4 - 6


def test_queue_rpc_matches_rdma():
    valsA = jnp.arange(P * 3, dtype=jnp.int32).reshape(P, 3, 1) + 1
    qa = q_mod.make_queue(P, host=2, capacity=64, val_words=1)
    qa, ok_a = q_mod.push_rdma(qa, valsA, promise=Promise.CW)
    qb = q_mod.make_queue(P, host=2, capacity=64, val_words=1)
    eng = am_mod.AMEngine(P)
    q_mod.build_am_handlers(qb, eng)
    qb, ok_b = q_mod.push_rpc(qb, eng, valsA)
    assert bool(ok_a.all()) and bool(ok_b.all())
    qa, got_a, out_a = q_mod.pop_rdma(qa, 3, promise=Promise.CR)
    qb, got_b, out_b = q_mod.pop_rpc(qb, eng, 3)
    a = np.sort(np.asarray(out_a[np.asarray(got_a)]).ravel())
    b = np.sort(np.asarray(out_b[np.asarray(got_b)]).ravel())
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("promise", [Promise.CRW, Promise.CW])
def test_queue_planned_bit_exact_vs_unplanned(promise):
    """One RoutePlan across the push/pop component phases == fresh routing
    per phase (delivered ops and final ring state identical)."""
    vals = jnp.arange(P * 5, dtype=jnp.int32).reshape(P, 5, 1) + 1
    qa = q_mod.make_queue(P, host=1, capacity=16, val_words=1)
    qb = q_mod.make_queue(P, host=1, capacity=16, val_words=1)
    qa, ok_a = q_mod.push_rdma(qa, vals, promise=promise, planned=False)
    qb, ok_b = q_mod.push_rdma(qb, vals, promise=promise, planned=True)
    np.testing.assert_array_equal(np.asarray(ok_a), np.asarray(ok_b))
    np.testing.assert_array_equal(np.asarray(qa.win.data),
                                  np.asarray(qb.win.data))
    qa, got_a, out_a = q_mod.pop_rdma(qa, 6, promise=Promise.CR,
                                      planned=False)
    qb, got_b, out_b = q_mod.pop_rdma(qb, 6, promise=Promise.CR,
                                      planned=True)
    np.testing.assert_array_equal(np.asarray(got_a), np.asarray(got_b))
    np.testing.assert_array_equal(
        np.asarray(out_a)[np.asarray(got_a)],
        np.asarray(out_b)[np.asarray(got_b)])
    np.testing.assert_array_equal(np.asarray(qa.win.data),
                                  np.asarray(qb.win.data))


def test_queue_local_promise_zero_phases():
    q = q_mod.make_queue(P, host=0, capacity=16, val_words=1)
    q, ok = q_mod.push_local(q, jnp.arange(5, dtype=jnp.int32)[:, None])
    assert bool(ok.all())
    q, got, vals = q_mod.pop_local(q, 8)
    assert int(got.sum()) == 5
    np.testing.assert_array_equal(np.asarray(vals[:5, 0]), np.arange(5))


# ---------------------------------------------------------------------------
# Diagnostic rings (ISSUE 4 satellite): explicit bounds + drain, and
# coalescing stats recorded into decision_scope entries.
# ---------------------------------------------------------------------------
def test_window_phase_log_bounded_and_drains(monkeypatch):
    win = window.make_window(P, 32)
    dst = jnp.zeros((P, 4), jnp.int32)
    off = jnp.zeros((P, 4), jnp.int32)
    window.drain_phase_log()
    monkeypatch.setattr(window, "PHASE_LOG_MAX", 5)
    with window.decision_scope("dec"):
        for _ in range(4):  # 4 FAOs x 1 logged phase each... (role="fao")
            _, win = window.rdma_fao(win, dst, off, 1, AmoKind.FAA)
    log = window.drain_phase_log()
    assert len(log) <= 5          # bounded: oldest entries dropped
    assert window.drain_phase_log() == []  # drained
    # outside a decision scope nothing is logged
    window.rdma_fao(win, dst, off, 1, AmoKind.FAA)
    assert window.drain_phase_log() == []


def test_phase_log_records_coalescing_stats():
    win = window.make_window(P, 32)
    dst = jnp.zeros((P, 6), jnp.int32)
    off = jnp.zeros((P, 6), jnp.int32)  # single hot word: 6 -> 1 per origin
    window.drain_phase_log()
    with window.decision_scope("dec"):
        window.rdma_fao(win, dst, off, 1, AmoKind.FAA, coalesce=True)
        window.rdma_fao(win, dst, off, 1, AmoKind.FAA)
    (role_a, dec_a, info_a), (role_b, dec_b, info_b) = \
        window.drain_phase_log()
    assert role_a == role_b == "fao" and dec_a == dec_b == "dec"
    assert info_b is None                       # uncoalesced phase
    assert info_a["coalesced"] is True
    assert info_a["rows_in"] == P * 6
    assert info_a["rows_out"] == P              # one rep per origin
    assert info_a["dedup_ratio"] == pytest.approx(1 / 6)


def test_am_dispatch_log_bounded_and_drains():
    eng = am_mod.AMEngine(P, dispatch_log_max=3)
    echo = eng.register("echo", lambda l, p, m: (l, p[:, :1]),
                        reply_width=1)
    state = jnp.zeros((P, 4), jnp.int32)
    dst = jnp.zeros((P, 2), jnp.int32)
    payload = jnp.ones((P, 2, 1), jnp.int32)
    for i in range(5):
        eng.dispatch(echo, state, dst, payload, decision=f"d{i}")
    assert len(eng.dispatch_log) == 3           # bounded ring
    names = [d for _, d, _ in eng.dispatch_log]
    assert names == ["d2", "d3", "d4"]          # oldest dropped
    drained = eng.drain_dispatch_log()
    assert len(drained) == 3
    assert len(eng.dispatch_log) == 0
    # coalesced dispatch records its combining stats
    eng.dispatch(echo, state, dst, payload, decision="dc", coalesce=True)
    (_, _, info), = eng.drain_dispatch_log()
    assert info["coalesced"] is True and info["rows_out"] == P
