"""Shared test harness configuration.

The only fixture here keeps the CPU XLA client healthy across the full
suite: each test module leaves its jitted executables cached, and by the
time the suite reaches the kernel sweeps (~170 compiles in) jaxlib
0.4.37's CPU compiler segfaults inside backend_compile — deterministic,
order-dependent, and reproducible with ANY extra ~50 jitted tests
inserted before tests/test_kernels.py. Dropping the compilation caches
at module boundaries bounds the number of live executables; the cost is
a handful of recompiles per module, the benefit is that adding new test
files cannot knock over unrelated ones.
"""
import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    yield
    jax.clear_caches()
