"""Fault-injection plane (DESIGN.md §10): deterministic chaos schedules,
exactly-once delivery, typed timeouts, and graceful degradation.

The §10 contract under test:

  * conformance by construction — for EVERY seeded fault schedule,
    idempotent ops under retry + dedup produce results bit-identical to
    the fault-free oracle on every arm (one-sided, fused, AM, auto,
    cached, pipelined): serialization order is fixed by the routing plan,
    so exactly-once delivery is sufficient;
  * determinism — the same FaultPlan seed reproduces the same drops,
    duplicates, and retry counts, run after run;
  * liveness — `Handle.result(timeout=)` on a permanently dead owner
    raises `faults.RemoteTimeout` instead of hanging, and a temporarily
    stalled owner recovers within its stall budget;
  * degradation — dead/inattentive owners are quarantined by the health
    signal (fault-plane pressure or the straggler-monitor bridge) and
    their AM traffic re-routes to the one-sided arms; bounded-staleness
    cache reads keep answering within `max_stale` publishes.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import adaptive as ad_mod
from repro.core import am as am_mod
from repro.core import cache as cache_mod
from repro.core import costmodel as cm
from repro.core import faults as flt
from repro.core import hashtable as ht_mod
from repro.core import pipeline as pl_mod
from repro.core import queue as q_mod
from repro.core.costmodel import DSOp
from repro.core.types import OpStats, Promise
from repro.runtime import elastic
from repro.runtime.straggler import StragglerMonitor

P = 4
VW = 2
NSLOTS = 128


def _val_of(keys):
    return jnp.concatenate([((keys * 31 + 7) & 0x7FFFFF)[..., None],
                            ((keys * 17 + 3) & 0x7FFFFF)[..., None]],
                           axis=-1).astype(jnp.int32)


def _batches(seed, nbatches, n=8, lo=1, hi=4000):
    """Insert streams draw globally DISTINCT keys: the one-sided insert
    is insert-only over distinct keys per batch (hashtable.insert_rdma's
    documented domain), while the AM handler is insert-or-assign — a
    cross-origin duplicate key is the one input where the two arms agree
    only on visible results, not raw slot bits. Bit-exact oracle compares
    therefore stay on the shared domain; duplicate-key batches get their
    own visible-conformance test below."""
    rng = np.random.default_rng(seed)
    flat = rng.choice(np.arange(lo, hi), size=nbatches * P * n,
                      replace=False)
    return [jnp.asarray(flat[i * P * n:(i + 1) * P * n].reshape(P, n),
                        jnp.int32) for i in range(nbatches)]


# Three seeded chaos schedules (the acceptance criterion's >= 3): heavy
# drops, heavy duplicates (lost acks), and a mixed schedule with delayed
# rows and one temporarily dead owner.
def _schedules():
    return [
        ("drops", 1001, dict(seed=101, drop_rate=0.30)),
        ("dups", 2002, dict(seed=202, dup_rate=0.40)),
        ("mixed", 3003, dict(seed=303, drop_rate=0.15, dup_rate=0.15,
                             delay_rate=0.20, delay_rounds=2,
                             dead_owners={1: 3})),
    ]


# ---------------------------------------------------------------------------
# Determinism primitives
# ---------------------------------------------------------------------------
class TestRetryPolicy:
    def test_capped_exponential_backoff(self):
        rp = flt.RetryPolicy(max_attempts=8, base_delay=1.0, max_delay=16.0)
        assert rp.delay(1) == 1.0
        assert rp.delay(2) == 2.0
        assert rp.delay(4) == 8.0
        assert rp.delay(7) == 16.0  # capped

    def test_defaults(self):
        rp = flt.RetryPolicy()
        assert rp.max_attempts >= 1 and rp.deadline >= 1


class TestDedupIndex:
    def test_seqs_contiguous_per_channel(self):
        d = flt.DedupIndex(P)
        dst = np.array([[1, 1, 2], [2, 2, 2], [0, 1, 2], [3, 3, 3]])
        active = np.ones_like(dst, bool)
        seqs = d.assign(dst, active)
        # channel (owner=1 <- origin=0) got seqs 0, 1
        assert sorted(seqs[0, :2].tolist()) == [0, 1]
        # channel (owner=2 <- origin=1) got 0, 1, 2
        assert sorted(seqs[1].tolist()) == [0, 1, 2]
        seqs2 = d.assign(dst, active)
        assert sorted(seqs2[1].tolist()) == [3, 4, 5]

    def test_admit_filters_redelivery(self):
        d = flt.DedupIndex(P)
        assert d.admit(1, 0, 0) is True
        assert d.admit(1, 0, 0) is False   # duplicate delivery
        assert d.admit(1, 0, 1) is True
        assert d.dup_filtered == 1

    def test_watermark_advances_over_reordered_tags(self):
        d = flt.DedupIndex(P)
        assert d.admit(2, 0, 1) is True    # out of order
        assert d.admit(2, 0, 0) is True    # fills the gap
        assert d.watermark[2, 0] == 1      # contiguous run absorbed
        assert not d.out_of_order.get((2, 0))
        assert d.admit(2, 0, 1) is False   # below watermark now


class TestDeterminism:
    def test_same_seed_same_schedule(self):
        keys = _batches(7, 1)[0]
        vals = _val_of(keys)
        stats = []
        for _ in range(2):
            ht = ht_mod.make_hashtable(P, NSLOTS, VW)
            plan = flt.FaultPlan(P, seed=42, drop_rate=0.25, dup_rate=0.25)
            plan.reset()
            with flt.fault_scope(plan):
                ht_mod.insert_rdma(ht, keys, vals)
            stats.append(plan.stats())
        assert stats[0] == stats[1]
        assert stats[0]["dropped"] > 0

    def test_different_seed_different_schedule(self):
        keys = _batches(7, 1)[0]
        vals = _val_of(keys)
        out = []
        for seed in (1, 2):
            ht = ht_mod.make_hashtable(P, NSLOTS, VW)
            plan = flt.FaultPlan(P, seed=seed, drop_rate=0.25)
            plan.reset()
            with flt.fault_scope(plan):
                ht_mod.insert_rdma(ht, keys, vals)
            out.append(plan.stats()["dropped"])
        assert out[0] != out[1]


# ---------------------------------------------------------------------------
# Chaos conformance: every schedule x every arm == the fault-free oracle
# ---------------------------------------------------------------------------
class _ArmRunner:
    """Run a mixed insert/find stream on one arm, optionally under a
    FaultPlan; the fault-free instance IS the oracle (arm conformance
    across arms is pinned by tests/test_conformance.py)."""

    def __init__(self, arm):
        self.arm = arm
        self.ht = ht_mod.make_hashtable(P, NSLOTS, VW)
        self.eng = am_mod.AMEngine(P)
        self.auto = ad_mod.AdaptiveEngine(P, am_engine=self.eng,
                                          policy="round_robin")
        if arm == "cached":
            self.auto.policy = "cost"
            self.auto.force_arm = "rdma_fused"
            self.auto.attach_cache(cache_mod.BucketCache(
                P, NSLOTS, VW, capacity=256, max_probes=8))
        elif arm != "auto":
            self.auto.policy = "cost"
            self.auto.force_arm = arm

    def insert(self, keys):
        self.ht, ok, _ = self.auto.ht_insert(self.ht, keys, _val_of(keys))
        return np.asarray(ok)

    def find(self, keys):
        self.ht, found, vals = self.auto.ht_find(self.ht, keys)
        return np.asarray(found), np.asarray(vals)


@pytest.mark.parametrize("arm", ["rdma", "rdma_fused", "am", "auto",
                                 "cached"])
@pytest.mark.parametrize("name,kseed,cfg", _schedules())
def test_chaos_conformance(arm, name, kseed, cfg):
    batches = _batches(seed=kseed, nbatches=4)
    oracle = _ArmRunner(arm)
    chaos = _ArmRunner(arm)
    plan = flt.FaultPlan(P, **cfg)
    plan.reset()
    for i, keys in enumerate(batches):
        ok_o = oracle.insert(keys)
        f_o, v_o = oracle.find(keys)
        with flt.fault_scope(plan):
            ok_c = chaos.insert(keys)
            f_c, v_c = chaos.find(keys)
        assert np.array_equal(ok_o, ok_c), (arm, name, i, "ok")
        assert np.array_equal(f_o, f_c), (arm, name, i, "found")
        assert np.array_equal(v_o, v_c), (arm, name, i, "vals")
    if arm == "auto":
        # the §10 quarantine re-route may legitimately execute a batch on
        # a different (conformant) arm than the fault-free run, so the
        # raw slot layout can differ — final-state conformance is the
        # visible contract: every key reads back identically
        for keys in batches:
            f_o, v_o = oracle.find(keys)
            f_c, v_c = chaos.find(keys)
            assert np.array_equal(f_o, f_c), (arm, name, "final-found")
            assert np.array_equal(v_o, v_c), (arm, name, "final-vals")
    else:
        assert np.array_equal(np.asarray(oracle.ht.win.data),
                              np.asarray(chaos.ht.win.data)), (arm, name)
    s = plan.stats()
    assert s["dropped"] + s["dup_filtered"] + s["stall_hits"] > 0 \
        or plan.dead_owners, (name, s)


def test_chaos_duplicate_keys_visible_conformance():
    """Cross-origin duplicate keys under a dead owner: the AM oracle's
    insert-or-assign and the one-sided failover differ in raw slot bits
    (a sender-side coalescer cannot merge rows from two origins), but
    every visible read is identical — the §10 contract on the full input
    domain."""
    rng = np.random.default_rng(17)
    keys = jnp.asarray(rng.integers(1, 40, size=(P, 8)), jnp.int32)  # dense
    oracle = _ArmRunner("am")
    chaos = _ArmRunner("am")
    plan = flt.FaultPlan(P, seed=19, drop_rate=0.2, dup_rate=0.2,
                         dead_owners={1: None})
    plan.reset()
    ok_o = oracle.insert(keys)
    f_o, v_o = oracle.find(keys)
    with flt.fault_scope(plan):
        ok_c = chaos.insert(keys)
        f_c, v_c = chaos.find(keys)
    assert np.array_equal(ok_o, ok_c)
    assert np.array_equal(f_o, f_c)
    assert np.array_equal(v_o, v_c)


@pytest.mark.parametrize("arm", ["rdma", "am", "auto"])
def test_chaos_conformance_queue(arm):
    rng = np.random.default_rng(9)
    vals = [jnp.asarray(rng.integers(0, 99, size=(P, 4, VW)), jnp.int32)
            for _ in range(3)]

    def run(plan):
        q = q_mod.make_queue(P, host=1, capacity=256, val_words=VW)
        eng = am_mod.AMEngine(P)
        auto = ad_mod.AdaptiveEngine(P, am_engine=eng)
        if arm != "auto":
            auto.force_arm = arm
        out = []
        for v in vals:
            if plan is None:
                q, ok = auto.q_push(q, v)
                q, got, pv = auto.q_pop(q, 4)
            else:
                with flt.fault_scope(plan):
                    q, ok = auto.q_push(q, v)
                    q, got, pv = auto.q_pop(q, 4)
            out.append((np.asarray(ok), np.asarray(got), np.asarray(pv)))
        return q, out

    q_o, out_o = run(None)
    plan = flt.FaultPlan(P, seed=77, drop_rate=0.25, dup_rate=0.25)
    plan.reset()
    q_c, out_c = run(plan)
    for (a, b, c), (x, y, z) in zip(out_o, out_c):
        assert np.array_equal(a, x)
        assert np.array_equal(b, y)
        assert np.array_equal(c, z)
    assert np.array_equal(np.asarray(q_o.win.data),
                          np.asarray(q_c.win.data))


def test_chaos_conformance_pipelined():
    """The pipelined engine under wire faults + a briefly stalled queue:
    deferred AM batches wait out the stall, results stay bit-exact."""
    batches = _batches(5, 4)

    def run(plan):
        ht = ht_mod.make_hashtable(P, NSLOTS, VW)
        eng = am_mod.AMEngine(P)
        outs = []

        def step(keys):
            def op(st):
                st2, ok, pr = ht_mod.insert_rdma(st, keys, _val_of(keys))
                return st2, (ok, pr)
            return op

        def go():
            with pl_mod.Pipeline(ht, depth=2, am_engine=eng) as pipe:
                hs = [pipe.submit(step(k), deferred=(i % 2 == 1),
                                  label=f"b{i}")
                      for i, k in enumerate(batches)]
                for h in hs:
                    ok, _ = h.result(timeout=32)
                    outs.append(np.asarray(ok))
                return pipe.flush()

        if plan is None:
            return go(), outs
        with flt.fault_scope(plan):
            return go(), outs

    ht_o, outs_o = run(None)
    plan = flt.FaultPlan(P, seed=11, drop_rate=0.2, dup_rate=0.2,
                         stall_rounds=2)
    plan.reset()
    ht_c, outs_c = run(plan)
    for a, b in zip(outs_o, outs_c):
        assert np.array_equal(a, b)
    assert np.array_equal(np.asarray(ht_o.win.data),
                          np.asarray(ht_c.win.data))


# ---------------------------------------------------------------------------
# Timeouts and liveness
# ---------------------------------------------------------------------------
class TestTimeout:
    def _pipe(self, plan):
        ht = ht_mod.make_hashtable(P, NSLOTS, VW)
        keys = _batches(3, 1)[0]

        def op(st):
            st2, ok, pr = ht_mod.insert_rdma(st, keys, _val_of(keys))
            return st2, (ok, pr)

        eng = am_mod.AMEngine(P)
        return pl_mod.Pipeline(ht, depth=4, am_engine=eng), op

    def test_dead_owner_raises_remote_timeout(self):
        plan = flt.FaultPlan(P, seed=1, stall_forever=True)
        plan.reset()
        with flt.fault_scope(plan):
            pipe, op = self._pipe(plan)
            h = pipe.submit(op, deferred=True, label="ins")
            with pytest.raises(flt.RemoteTimeout):
                h.result(timeout=8)
            # the failure is sticky: the batch is guaranteed dropped
            with pytest.raises(flt.RemoteTimeout):
                h.result()
            assert h.done()

    def test_timeout_is_typed_timeout_error(self):
        assert issubclass(flt.RemoteTimeout, TimeoutError)

    def test_slow_owner_recovers_within_deadline(self):
        plan = flt.FaultPlan(P, seed=2, stall_rounds=3)
        plan.reset()
        with flt.fault_scope(plan):
            pipe, op = self._pipe(plan)
            h = pipe.submit(op, deferred=True, label="ins")
            ok, _ = h.result(timeout=16)
        assert plan.stall_hits == 3
        assert np.asarray(ok).all()

    def test_deadline_default_from_retry_policy(self):
        plan = flt.FaultPlan(P, seed=3, stall_forever=True,
                             retry=flt.RetryPolicy(deadline=4))
        plan.reset()
        with flt.fault_scope(plan):
            pipe, op = self._pipe(plan)
            h = pipe.submit(op, deferred=True)
            with pytest.raises(flt.RemoteTimeout):
                h.result()  # no explicit timeout: plan deadline applies


class TestPipelineContextManager:
    def test_clean_exit_flushes(self):
        ht = ht_mod.make_hashtable(P, NSLOTS, VW)
        keys = _batches(4, 1)[0]
        eng = am_mod.AMEngine(P)

        def op(st):
            st2, ok, pr = ht_mod.insert_rdma(st, keys, _val_of(keys))
            return st2, (ok, pr)

        with pl_mod.Pipeline(ht, depth=4, am_engine=eng) as pipe:
            h = pipe.submit(op, deferred=True)
        assert h.done()
        assert eng.pending_dispatches == 0
        ht1, _, _ = ht_mod.insert_rdma(ht, keys, _val_of(keys))
        assert np.array_equal(np.asarray(pipe.staged_state.win.data),
                              np.asarray(ht1.win.data))

    def test_exception_path_fails_outstanding_handles(self):
        ht = ht_mod.make_hashtable(P, NSLOTS, VW)
        keys = _batches(4, 1)[0]
        eng = am_mod.AMEngine(P)
        plan = flt.FaultPlan(P, seed=5, stall_forever=True)
        plan.reset()
        with pytest.raises(RuntimeError, match="boom"):
            with flt.fault_scope(plan):
                with pl_mod.Pipeline(ht, depth=4, am_engine=eng) as pipe:
                    h = pipe.submit(
                        lambda st: ht_mod.insert_rdma(
                            st, keys, _val_of(keys))[:1] + ((),),
                        deferred=True)
                    raise RuntimeError("boom")
        # the stranded batch is failed, not silently lost...
        with pytest.raises(flt.RemoteTimeout):
            h.result()
        # ...and its queued thunk is a no-op for later engine users
        eng.drain_dispatch_queue()
        assert eng.pending_dispatches == 0


# ---------------------------------------------------------------------------
# Graceful degradation: quarantine, straggler bridge, bounded staleness
# ---------------------------------------------------------------------------
class TestQuarantine:
    def test_dead_owner_quarantined_after_one_batch(self):
        keys = _batches(6, 1)[0]
        eng = am_mod.AMEngine(P)
        auto = ad_mod.AdaptiveEngine(P, am_engine=eng)
        auto.force_arm = "am"
        ht = ht_mod.make_hashtable(P, NSLOTS, VW)
        oracle, ok_o, _ = ad_mod.AdaptiveEngine(
            P, am_engine=am_mod.AMEngine(P)).ht_insert(
                ht, keys, _val_of(keys))
        plan = flt.FaultPlan(P, seed=8, dead_owners={2: None})
        plan.reset()
        with flt.fault_scope(plan):
            ht2, ok_c, _ = auto.ht_insert(ht, keys, _val_of(keys))
        assert 2 in auto.quarantined
        assert auto.health[2] == 1.0
        # failover kept the batch conformant despite the dead owner
        assert np.array_equal(np.asarray(ok_o), np.asarray(ok_c))
        assert np.array_equal(np.asarray(oracle.win.data),
                              np.asarray(ht2.win.data))

    def test_decision_reroutes_off_quarantined_owner(self):
        auto = ad_mod.AdaptiveEngine(P, am_engine=am_mod.AMEngine(P))
        auto.quarantine(2)
        dst = jnp.full((P, 8), 2, jnp.int32)
        # bias the model so an AM arm would win outright
        auto.ewma[(DSOp.HT_INSERT, "am")] = 0.1
        auto.ewma[(DSOp.HT_INSERT, "am_pt")] = 0.2
        dec = auto.decide(DSOp.HT_INSERT, Promise.CRW, dst=dst)
        assert dec.arm not in ("am", "am_pt")
        assert dec.source == "quarantine"
        assert dec.quarantined

    def test_untargeted_batches_keep_am(self):
        auto = ad_mod.AdaptiveEngine(P, am_engine=am_mod.AMEngine(P))
        auto.quarantine(2)
        auto.ewma[(DSOp.HT_INSERT, "am")] = 0.1
        dst = jnp.zeros((P, 8), jnp.int32)  # rank 0 only: not quarantined
        dec = auto.decide(DSOp.HT_INSERT, Promise.CRW, dst=dst)
        assert not dec.quarantined

    def test_owner_hint_used_for_hosted_queue(self):
        auto = ad_mod.AdaptiveEngine(P, am_engine=am_mod.AMEngine(P))
        auto.quarantine(1)
        auto.ewma[(DSOp.Q_PUSH, "am")] = 0.1
        dec = auto.decide(DSOp.Q_PUSH, Promise.CRW, owners=(1,))
        assert dec.quarantined and dec.arm not in ("am", "am_pt")

    def test_release_hysteresis(self):
        auto = ad_mod.AdaptiveEngine(P, am_engine=am_mod.AMEngine(P),
                                     alpha=0.5)
        auto.quarantine(3)
        assert 3 in auto.quarantined
        # healthy verdicts decay the EWMA; release only below ON/2
        for _ in range(10):
            auto.quarantine_from_monitor({3: "healthy"})
        assert 3 not in auto.quarantined
        assert auto.health[3] < auto.QUARANTINE_ON / 2


class TestStragglerBridge:
    def test_classify_verdicts_feed_quarantine(self):
        mon = StragglerMonitor(n_hosts=P, threshold=2.0, patience=2,
                               dead_after=3)
        base = 0.1
        for step in range(4):
            for h in range(P):
                if h == 2:
                    continue  # host 2 stops heartbeating -> dead
                mon.heartbeat(h, step, base * (8.0 if h == 1 else 1.0))
        classes = mon.classify()
        assert classes[2] == "dead"
        assert classes[1] in ("slow", "replace")
        auto = ad_mod.AdaptiveEngine(P, am_engine=am_mod.AMEngine(P))
        auto.quarantine_from_monitor(classes)
        assert 2 in auto.quarantined          # dead host quarantined
        assert 1 in auto.quarantined          # chronic straggler too
        assert 0 not in auto.quarantined and 3 not in auto.quarantined

    def test_ranks_per_host_expansion(self):
        auto = ad_mod.AdaptiveEngine(4, am_engine=am_mod.AMEngine(4))
        auto.quarantine_from_monitor({1: "dead"}, ranks_per_host=2)
        assert auto.quarantined == {2, 3}


class TestBoundedStaleness:
    def _cached(self):
        eng = ad_mod.AdaptiveEngine(P)
        eng.attach_cache(cache_mod.BucketCache(P, NSLOTS, VW, capacity=256,
                                               max_probes=8))
        return eng

    def test_max_stale_serves_lagging_entries(self):
        keys = _batches(12, 1, n=4)[0]
        c = cache_mod.BucketCache(P, NSLOTS, VW, capacity=256, max_probes=8)
        ht = ht_mod.make_hashtable(P, NSLOTS, VW)
        ht, _, _ = ht_mod.insert_rdma(ht, keys, _val_of(keys))
        ht, f, v = ht_mod.find_rdma(ht, keys, cache=c)      # fill
        look = c.lookup(keys)
        assert look is not None and look.all_hit
        # one invalidation round: overlapping probe windows may bump a
        # bucket several times, so tolerate the max observed lag
        c.on_insert_keys(keys, None, 8)
        assert c.lookup(keys, max_stale=16).all_hit         # tolerated
        strict = c.lookup(keys, max_stale=0)                # strict: stale
        assert strict is None or not strict.hit.any()       # ...and evicted

    def test_stale_past_tolerance_evicted(self):
        keys = _batches(13, 1, n=4)[0]
        c = cache_mod.BucketCache(P, NSLOTS, VW, capacity=256, max_probes=8)
        ht = ht_mod.make_hashtable(P, NSLOTS, VW)
        ht, _, _ = ht_mod.insert_rdma(ht, keys, _val_of(keys))
        ht, _, _ = ht_mod.find_rdma(ht, keys, cache=c)
        for _ in range(3):
            c.on_insert_keys(keys, None, 8)                 # lag >= 3
        look = c.lookup(keys, max_stale=1)
        assert look is None or not look.hit.any()
        assert c.counters["stale_evicted"] > 0

    def test_ht_find_threads_max_stale(self):
        keys = _batches(14, 1, n=4)[0]
        eng = self._cached()
        ht = ht_mod.make_hashtable(P, NSLOTS, VW)
        ht, _, _ = eng.ht_insert(ht, keys, _val_of(keys))
        eng.force_arm = "rdma_fused"
        ht, f0, v0 = eng.ht_find(ht, keys)                  # fills cache
        eng.cache.on_insert_keys(keys, None, 8)             # age entries
        ht, f1, v1 = eng.ht_find(ht, keys, max_stale=1)
        assert np.array_equal(np.asarray(f0), np.asarray(f1))
        assert np.array_equal(np.asarray(v0), np.asarray(v1))


# ---------------------------------------------------------------------------
# Cost model: retry/loss terms
# ---------------------------------------------------------------------------
class TestCostRetryTerms:
    def test_lossless_predictions_bit_identical(self):
        for op, pr in ((DSOp.HT_INSERT, Promise.CRW),
                       (DSOp.HT_FIND, Promise.CR),
                       (DSOp.Q_PUSH, Promise.CRW)):
            for arm in cm.ARMS:
                a = cm.predict_arm(op, pr, arm, OpStats())
                b = cm.predict_arm(op, pr, arm, OpStats(loss_rate=0.0))
                assert a == b, (op, arm)

    def test_loss_charges_am_more_than_rdma(self):
        s = OpStats(loss_rate=0.3)
        for op, pr in ((DSOp.HT_FIND, Promise.CR),
                       (DSOp.HT_INSERT, Promise.CRW)):
            d_am = (cm.predict_arm(op, pr, "am", s)
                    - cm.predict_arm(op, pr, "am", OpStats()))
            d_rd = (cm.predict_arm(op, pr, "rdma", s)
                    - cm.predict_arm(op, pr, "rdma", OpStats()))
            assert d_am > d_rd > 0.0, (op, d_am, d_rd)

    def test_trade_flips_toward_rdma_under_loss(self):
        # a parameter point where AM wins lossless (huge one-sided W, fast
        # AM round trip) loses once the per-attempt loss prices each AM
        # retry at a full round trip
        params = cm.ComponentCosts(W=6.0, R=6.0, A_cas=6.0, A_fao=6.0,
                                   am_rt=5.0, handler=0.05,
                                   retry_penalty=1.0, name="flip")
        op, pr = DSOp.HT_FIND, Promise.CR
        lossless = {a: cm.predict_arm(op, pr, a, OpStats(), params)
                    for a in ("am", "rdma")}
        assert lossless["am"] < lossless["rdma"]
        lossy = {a: cm.predict_arm(op, pr, a, OpStats(loss_rate=0.6),
                                   params)
                 for a in ("am", "rdma")}
        assert lossy["rdma"] < lossy["am"]

    def test_calibrate_accepts_retry_penalty(self):
        p = cm.calibrate({"retry_penalty": 2.5})
        assert p.retry_penalty == 2.5

    def test_loss_ewma_feeds_scores(self):
        auto = ad_mod.AdaptiveEngine(P, am_engine=am_mod.AMEngine(P))
        s0, _ = auto.scores(DSOp.HT_FIND, Promise.CR)
        auto.loss_ewma = 0.4
        s1, _ = auto.scores(DSOp.HT_FIND, Promise.CR)
        assert s1["am"] > s0["am"]
        # pre-set loss_rate wins over the EWMA
        s2, _ = auto.scores(DSOp.HT_FIND, Promise.CR,
                            OpStats(loss_rate=0.1))
        assert s2["am"] < s1["am"]


# ---------------------------------------------------------------------------
# Elastic rehash under faults (satellite: runtime/elastic.rehash_table)
# ---------------------------------------------------------------------------
class TestElasticRehash:
    def _filled(self, nkeys=48, seed=21):
        rng = np.random.default_rng(seed)
        keys_np = rng.choice(np.arange(1, 5000), size=nkeys, replace=False)
        keys = jnp.asarray(keys_np.reshape(P, -1), jnp.int32)
        ht = ht_mod.make_hashtable(P, NSLOTS, VW)
        ht, ok, _ = ht_mod.insert_rdma(ht, keys, _val_of(keys))
        assert np.asarray(ok).all()
        return ht, keys

    def _assert_all_found(self, ht, keys):
        kq = jnp.asarray(np.asarray(keys).reshape(ht.nranks, -1), jnp.int32)
        ht, found, vals = ht_mod.find_rdma(ht, kq)
        assert np.asarray(found).all()
        assert np.array_equal(np.asarray(vals), np.asarray(_val_of(kq)))

    def test_grow_round_trip(self):
        ht, keys = self._filled()
        big = elastic.rehash_table(ht, 8)
        assert big.nranks == 8
        self._assert_all_found(big, keys)

    def test_shrink_round_trip(self):
        ht, keys = self._filled()
        big = elastic.rehash_table(ht, 8)
        small = elastic.rehash_table(big, 4)
        self._assert_all_found(small, keys)
        # shrink back equals a direct rehash at 4: same insert order per
        # placement, so the record bits agree wherever both are live
        direct = elastic.rehash_table(ht, 4)
        s_live = np.asarray(small.win.data) != 0
        d_live = np.asarray(direct.win.data) != 0
        assert np.array_equal(s_live.sum(), d_live.sum())

    def test_empty_table(self):
        ht = ht_mod.make_hashtable(P, NSLOTS, VW)
        new = elastic.rehash_table(ht, 8)
        recs = np.asarray(new.win.data).reshape(8, new.nslots, new.rec_w)
        assert ((recs[..., 0] & 255) != 2).all()  # nothing live

    def test_duplicate_keys_preserved(self):
        """Duplicate keys sit outside insert_rdma's distinct-key domain
        (insert-only + per-origin coalescing: cross-origin duplicates
        each claim a slot), so the rehash invariant is conservation, not
        collapse: the drain+reinsert never multiplies records, and
        reads stay visibly correct."""
        keys = jnp.asarray(np.full((P, 8), 123), jnp.int32)
        ht = ht_mod.make_hashtable(P, NSLOTS, VW)
        ht, _, _ = ht_mod.insert_rdma(ht, keys, _val_of(keys))
        recs0 = np.asarray(ht.win.data).reshape(P, ht.nslots, ht.rec_w)
        n_old = int(((recs0[..., 0] & 255) == 2).sum())
        new = elastic.rehash_table(ht, 8)
        recs = np.asarray(new.win.data).reshape(8, new.nslots, new.rec_w)
        live = (recs[..., 0] & 255) == 2
        assert 1 <= live.sum() <= n_old
        self._assert_all_found(new, jnp.asarray(np.full((8, 1), 123),
                                                jnp.int32))

    def test_kill_then_rehash_conformant_reads(self):
        """An injected dead owner does not perturb the rehash: drain +
        reinsert are one-sided phases, which owner faults never touch."""
        ht, keys = self._filled()
        plan = flt.FaultPlan(P, seed=31, dead_owners={3: None},
                             drop_rate=0.2)
        plan.reset()
        with flt.fault_scope(plan):
            new = elastic.rehash_table(ht, 8)
            self._assert_all_found(new, keys)
        clean = elastic.rehash_table(ht, 8)
        assert np.array_equal(np.asarray(new.win.data),
                              np.asarray(clean.win.data))
