"""Subprocess helper for tests/test_phase_counts.py: lower the phase
engine under a real 8-way sharded mesh (fake CPU devices) and count the
all-to-all collectives / sorts in the optimized HLO with the
launch/hlo_stats trip-count-aware analyzer.

Runs as `python tests/phase_count_probe.py` (XLA_FLAGS must be set before
jax initializes, which is why this is a subprocess and not a fixture) and
prints one JSON dict on the last line.
"""
import json
import os
import sys

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax                                                    # noqa: E402
import jax.numpy as jnp                                       # noqa: E402
import numpy as np                                            # noqa: E402
from jax.sharding import Mesh, NamedSharding, PartitionSpec   # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import am as am_mod                           # noqa: E402
from repro.core import hashtable as ht_mod                    # noqa: E402
from repro.core import routing, window                        # noqa: E402
from repro.core.types import AmoKind, Promise                 # noqa: E402
from repro.launch import hlo_stats                            # noqa: E402

P, N = 8, 8
MESH = Mesh(jax.devices(), ("p",))
SHARD = NamedSharding(MESH, PartitionSpec("p"))


def hook(x, role):
    return jax.lax.with_sharding_constraint(x, SHARD)


def counts(fn, *args) -> dict:
    """{'a2a': trip-weighted all-to-all count, 'sorts': trip-weighted sort
    count} of the optimized sharded HLO of jit(fn)(*args), both from the
    hlo_stats analyzer."""
    with routing.sharding_hook(hook):
        compiled = jax.jit(fn).lower(*args).compile()
    st = hlo_stats.HloStats(compiled.as_text(), world=P).summary()
    a2a = st["collectives"].get("all-to-all", {"count": 0})["count"]
    return {"a2a": a2a, "sorts": st["op_counts"].get("sort", 0)}


def main():
    rng = np.random.default_rng(0)
    dst = jnp.asarray(rng.integers(0, P, (P, N)), jnp.int32)
    off = jnp.asarray(rng.integers(0, 32, (P, N)), jnp.int32)
    win = window.make_window(P, 64)
    vals = jnp.ones((P, N, 2), jnp.int32)
    plan = routing.make_plan(dst, cap=N)

    out = {}
    # planned component ops (full results used — nothing DCE-able)
    out["put"] = counts(
        lambda w, v: window.rdma_put(w, dst, off, v, plan=plan), win, vals)
    out["get"] = counts(
        lambda w: window.rdma_get(w, dst, off, 2, plan=plan), win)
    out["cas"] = counts(
        lambda w: window.rdma_cas(w, dst, off, 0, 1, plan=plan), win)
    out["fao"] = counts(
        lambda w: window.rdma_fao(w, dst, off, 1, AmoKind.FAA, plan=plan),
        win)
    # unplanned engine-level counts (per-phase occupancy-mask exchange)
    out["cas_unplanned"] = counts(
        lambda w: window.rdma_cas(w, dst, off, 0, 1), win)
    # the plan itself: ONE argsort + ONE occupancy exchange
    out["make_plan"] = counts(lambda d: routing.make_plan(d, cap=N).mask,
                              dst)
    out["route_with_plan"] = counts(
        lambda p: routing.route_with_plan(plan, p).at_owner, vals)

    # AM dispatch: 2 exchanges; reply-elided dispatch: 1
    eng = am_mod.AMEngine(P)
    echo = eng.register(
        "echo", lambda local, pay, mask: (local, pay[:, :1]), reply_width=1)
    fire = eng.register(
        "fire",
        lambda local, pay, mask:
            (local + jnp.sum(pay * mask[:, None].astype(jnp.int32)),
             jnp.zeros((pay.shape[0], 0), jnp.int32)),
        reply_width=0)
    state = jnp.zeros((P, 4), jnp.int32)
    out["dispatch"] = counts(
        lambda s, pay: eng.dispatch(echo, s, dst, pay, plan=plan),
        state, vals)
    out["dispatch_elided"] = counts(
        lambda s, pay: eng.dispatch(fire, s, dst, pay, plan=plan)[0],
        state, vals)

    # whole fused C_RW insert at max_probes=1: 2 probe exchanges + 1 plan
    keys = jnp.asarray(rng.integers(1, 1 << 20, (P, N)), jnp.int32)
    kvals = jnp.stack([keys], axis=-1)
    ht = ht_mod.make_hashtable(P, 64, 1)
    out["ht_insert_fused"] = counts(
        lambda d, k, v: ht_mod.insert_rdma(
            ht_mod.DHashTable(win=window.Window(data=d), nslots=64,
                              val_words=1),
            k, v, promise=Promise.CRW, max_probes=1,
            fused=True)[0].win.data,
        ht.win.data, keys, kvals)

    print(json.dumps(out))


if __name__ == "__main__":
    main()
